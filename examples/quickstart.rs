//! Quickstart: load the AOT artifacts, generate with HASS, print stats.
//!
//! ```sh
//! make artifacts && make train   # once
//! cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use hass::engine::generate_once;
use hass::runtime::Runtime;
use hass::sampling::SampleParams;
use hass::spec::MethodCfg;

fn main() -> anyhow::Result<()> {
    let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
    println!("PJRT platform: {}", rt.platform());

    let prompt = "User: Can you tell me about growing tomatoes?\nAssistant:";
    for method in ["vanilla", "hass"] {
        let (text, out) = generate_once(
            &rt,
            method,
            &MethodCfg::default(),
            prompt,
            64,
            &SampleParams { temperature: 0.0, ..Default::default() },
        )?;
        println!("\n== {method} ==\n{prompt}{text}");
        println!(
            "tau={:.2}  cycles={}  target_calls={}  draft_calls={}",
            out.metrics.tau(),
            out.metrics.cycles,
            out.metrics.target_calls,
            out.metrics.draft_calls
        );
    }
    Ok(())
}
