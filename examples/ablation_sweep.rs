//! Decode-time ablation: dynamic-tree depth × total-token sweep (the
//! Table 9 experiment) without retraining anything.
//!
//! ```sh
//! cargo run --release --example ablation_sweep -- [method]
//! ```

use std::rc::Rc;

use hass::engine::{calibrate, run_suite, build_method};
use hass::runtime::Runtime;
use hass::sampling::SampleParams;
use hass::spec::MethodCfg;
use hass::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let method = std::env::args().nth(1).unwrap_or_else(|| "hass".to_string());
    let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
    let wl = Workloads::load(&hass::artifact_dir()).unwrap_or_else(|_| Workloads::embedded());
    let prompts = wl.suite("dialogue")?[..4.min(wl.suite("dialogue")?.len())].to_vec();
    let cost = calibrate(&rt, 16)?;
    println!("t_ar = {:.2} ms; sweeping {method} depth x total", cost.t_ar * 1e3);
    println!("{:<7} {:>9} {:>9} {:>9}", "depth", "#40", "#60", "#80");
    for depth in [4usize, 6, 8] {
        print!("{depth:<7}");
        for total in [40usize, 60, 80] {
            let cfg = MethodCfg { depth, total_tokens: total, ..Default::default() };
            let mut m = build_method(&rt, &method, &cfg)?;
            let r = run_suite(
                m.as_mut(), "dialogue", &prompts, 48,
                &SampleParams { temperature: 0.0, ..Default::default() },
            )?;
            let speedup = cost.modeled_speedup(&r.metrics, r.metrics.phases.host_s);
            print!(" {:>5.2}x({:.1})", speedup, r.tau);
        }
        println!();
    }
    Ok(())
}
