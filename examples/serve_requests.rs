//! End-to-end serving driver (the repo's E2E validation workload):
//! for each worker count, starts a scheduler pool + TCP server
//! in-process, replays a mixed-suite request trace from concurrent
//! client connections, fetches the pool's `{"stats": true}` snapshot
//! over the wire, and reports latency percentiles plus the aggregate
//! throughput per worker count.
//!
//! ```sh
//! cargo run --release --example serve_requests -- \
//!     [--requests 12] [--method hass] [--clients 3] [--workers 1,2] \
//!     [--max-active 2]
//! ```
//!
//! `--max-active` sets how many jobs each engine worker interleaves
//! (cycle-granular continuous batching with fused cross-session
//! verification); the run ends with a streamed request that counts
//! per-cycle delta lines, followed by a fused-vs-solo verification
//! comparison (one worker, `--max-active 1` vs `4`, same jobs) whose
//! numbers are written to `BENCH_fused_verify.json`, a paged-KV
//! shared-prompt scenario (host pack bytes/cycle and fusion capacity,
//! paged vs. contiguous, plus scheduler pack counters) written to
//! `BENCH_paged_kv.json`, a shared-page-pool scenario (physical vs
//! logical prompt pages across 2 worker threads, plus a 2-worker fleet
//! with prefix-affinity routing on vs off) written to
//! `BENCH_page_pool.json`, and an OPEN-LOOP load scenario (Poisson and
//! bursty arrivals fired on a wall-clock schedule regardless of
//! completions at 0.5x/1x/2x estimated capacity; p50/p95/p99 latency,
//! TTFT, goodput, shed/preempt/breaker counts) written to
//! `BENCH_load.json`, and a CHAOS scenario (the same open-loop trace
//! replayed under injected worker panics and decode errors at >= 1%
//! rates via `util::failpoint`; asserts zero lost, duplicated or
//! token-corrupted responses vs. a fault-free baseline and reports
//! worker deaths, requeues/replays, recovery latency and per-point
//! trigger counts) written to `BENCH_chaos.json`.

use std::sync::Arc;

use hass::server::{Client, ReqOpts};
use hass::spec::MethodCfg;
use hass::util::cli::Args;
use hass::util::stats::summarize;
use hass::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    // legacy positional form `serve_requests 12 hass` still works: the
    // leading count parses as Args' subcommand, the method as positional 0
    let n_requests = args.usize_or("requests", args.subcommand.parse().unwrap_or(12));
    let method = args.get_or("method", &args.pos_or(0, "hass"));
    let n_clients = args.usize_or("clients", 3).max(1);
    let worker_counts = args.usize_list_or("workers", &[1, 2]);
    let max_active = args.usize_or("max-active", 2).max(1);

    let dir = hass::artifact_dir();
    let wl = Workloads::load(&dir).unwrap_or_else(|_| Workloads::embedded());

    let mut summary = Vec::new();
    for &workers in &worker_counts {
        let workers = workers.max(1); // Scheduler::start clamps the same way
        let sched = Arc::new(hass::scheduler::Scheduler::start(
            dir.clone(),
            MethodCfg::default(),
            64,
            workers,
            max_active,
        ));
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        {
            let sched = sched.clone();
            std::thread::spawn(move || hass::server::serve(listener, sched));
        }
        println!(
            "\n== {workers} worker(s) on {addr}: {n_requests} requests over \
             {n_clients} connections, method '{method}' =="
        );

        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for (ci, chunk) in wl.trace_split(n_requests, 123, n_clients).into_iter().enumerate() {
            if chunk.is_empty() {
                continue;
            }
            let method = method.clone();
            let addr = addr.to_string();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let mut out = Vec::new();
                for (suite, prompt, max_new) in chunk {
                    let resp = c.request(&method, &prompt, max_new, 0.0).expect("request");
                    if let Some(err) = resp.str_at("error") {
                        println!("  client{ci} {suite:<9} error: {err}");
                        continue;
                    }
                    let lat = resp.f64_at("latency_ms").unwrap_or(0.0);
                    let q = resp.f64_at("queue_ms").unwrap_or(0.0);
                    let tau = resp.f64_at("tau").unwrap_or(0.0);
                    let toks = resp.usize_at("tokens").unwrap_or(0);
                    let w = resp.usize_at("worker").unwrap_or(0);
                    println!(
                        "  client{ci} {suite:<9} worker={w} tokens={toks:<3} \
                         tau={tau:<5} lat={lat:.0}ms queue={q:.0}ms"
                    );
                    out.push((lat, q, tau, toks));
                }
                out
            }));
        }
        let mut lats = Vec::new();
        let mut taus = Vec::new();
        let mut total_tokens = 0usize;
        for h in handles {
            for (lat, _q, tau, toks) in h.join().expect("client thread") {
                lats.push(lat);
                taus.push(tau);
                total_tokens += toks;
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        let mut c = Client::connect(&addr.to_string())?;
        // streamed request demo: per-cycle deltas over the same pool
        let mut n_deltas = 0usize;
        let fin = c.generate(
            "User: stream demo please\nAssistant:",
            &ReqOpts { method: method.clone(), max_tokens: 16, stream: true, ..Default::default() },
            |_| n_deltas += 1,
        )?;
        match fin.str_at("error") {
            Some(e) => println!("  stream demo: error: {e}"),
            None => println!(
                "  stream demo: {n_deltas} delta lines -> {} tokens",
                fin.usize_at("tokens").unwrap_or(0)
            ),
        }
        let stats = c.stats()?;
        if let Some(agg) = stats.get("stats").and_then(|s| s.get("aggregate")) {
            println!(
                "  pool: jobs={} ok={} err={} tokens={} tau={}",
                agg.usize_at("jobs").unwrap_or(0),
                agg.usize_at("jobs_ok").unwrap_or(0),
                agg.usize_at("jobs_err").unwrap_or(0),
                agg.usize_at("tokens").unwrap_or(0),
                agg.f64_at("tau").unwrap_or(0.0),
            );
        }
        sched.shutdown();

        let s = summarize(&lats);
        println!(
            "  completed: {}   tokens: {}   wall: {:.1}s   mean tau: {:.2}",
            lats.len(),
            total_tokens,
            wall,
            taus.iter().sum::<f64>() / taus.len().max(1) as f64
        );
        summary.push(format!(
            "workers={workers}: {:.1} tok/s  {:.2} req/s  lat p50={:.0}ms p90={:.0}ms p99={:.0}ms",
            total_tokens as f64 / wall,
            lats.len() as f64 / wall,
            s.p50,
            s.p90,
            s.p99,
        ));
    }

    println!("\n== aggregate throughput by pool size ==");
    for line in summary {
        println!("{line}");
    }

    fused_verify_bench(&dir, &wl, &method, n_requests)?;
    paged_kv_bench(&dir, &method)?;
    draft_batch_bench(&dir, &wl, &method, n_requests)?;
    page_pool_bench(&dir, &method)?;
    load_bench(&dir, &wl, &method)?;
    chaos_bench(&dir, &wl, &method)?;
    Ok(())
}

/// Resolve a runnable method: probe `method` through a 1-worker pool and
/// fall back to the runtime-free `mock` when the backend cannot execute
/// it (no artifacts / stand-in xla), so the comparison scenarios always
/// demonstrate their path.
fn resolve_runnable(dir: &std::path::Path, method: &str) -> anyhow::Result<String> {
    use hass::scheduler::{Job, Scheduler};
    let probe = Scheduler::start(dir.to_path_buf(), MethodCfg::default(), 4, 1, 1);
    let job = Job {
        id: 1,
        method: method.to_string(),
        prompt: "probe".into(),
        max_new: 2,
        temperature: 0.0,
        seed: 0,
        stream: false,
        deadline_ms: None,
        priority: 0,
    };
    let rx = probe.submit(job, true)?;
    let ok = loop {
        match rx.recv() {
            Ok(ev) => {
                if let Some(r) = ev.into_result() {
                    break r.error.is_none();
                }
            }
            Err(_) => break false,
        }
    };
    probe.shutdown();
    Ok(if ok { method.to_string() } else { "mock".to_string() })
}

/// Fused-vs-solo verification comparison: the same jobs through one
/// worker at `--max-active 1` (every session verifies alone) and
/// `--max-active 4` (co-active sessions share fused target forwards).
/// Results go to stdout and `BENCH_fused_verify.json`.
fn fused_verify_bench(
    dir: &std::path::Path,
    wl: &Workloads,
    method: &str,
    n_requests: usize,
) -> anyhow::Result<()> {
    use hass::scheduler::{Job, Scheduler};
    use hass::util::json::Json;

    // preflight: without an executable backend, fall back to the
    // runtime-free mock so the comparison still demonstrates the path
    let method = {
        let resolved = resolve_runnable(dir, method)?;
        if resolved != method {
            println!("\n(fused-verify bench: '{method}' unavailable, using 'mock')");
        }
        resolved
    };

    let trace: Vec<(String, String, usize)> = wl
        .trace_split(n_requests.max(8), 321, 1)
        .into_iter()
        .flatten()
        .collect();
    println!("\n== fused-vs-solo verification ({} jobs, method '{method}') ==", trace.len());
    let mut report: Vec<(&str, Json)> = Vec::new();
    let mut tok_per_s = [0.0f64; 2];
    for (pass, &(label, max_active)) in [("solo", 1usize), ("fused", 4usize)].iter().enumerate() {
        let sched = Scheduler::start(dir.to_path_buf(), MethodCfg::default(), 64, 1, max_active);
        let (rtx, rrx) = std::sync::mpsc::channel();
        let t0 = std::time::Instant::now();
        for (i, (_suite, prompt, max_new)) in trace.iter().enumerate() {
            let job = Job {
                id: i as u64 + 1,
                method: method.clone(),
                prompt: prompt.clone(),
                max_new: *max_new,
                temperature: 0.0,
                seed: i as u64,
                stream: false,
                deadline_ms: None,
                priority: 0,
            };
            sched.submit_to(job, true, rtx.clone())?;
        }
        drop(rtx);
        let mut tokens = 0usize;
        let mut errors = 0usize;
        for r in rrx.iter().filter_map(hass::scheduler::JobEvent::into_result) {
            match r.error {
                Some(_) => errors += 1,
                None => tokens += r.tokens,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = sched.stats();
        sched.shutdown();
        tok_per_s[pass] = if wall > 0.0 { tokens as f64 / wall } else { 0.0 };
        println!(
            "  {label:<5} (max-active {max_active}): {tokens} tokens in {wall:.2}s \
             ({:.1} tok/s)  verify_calls={} fused={} solo={} mean_rows_per_fused={:.1} errors={errors}",
            tok_per_s[pass],
            stats.verify_calls(),
            stats.fused_calls(),
            stats.solo_calls(),
            stats.mean_fused_rows(),
        );
        report.push((
            label,
            Json::obj(vec![
                ("max_active", Json::num(max_active as f64)),
                ("jobs", Json::num(trace.len() as f64)),
                ("errors", Json::num(errors as f64)),
                ("tokens", Json::num(tokens as f64)),
                ("wall_s", Json::num(wall)),
                ("tok_per_s", Json::num(tok_per_s[pass])),
                ("verify_calls", Json::num(stats.verify_calls() as f64)),
                ("fused_calls", Json::num(stats.fused_calls() as f64)),
                ("solo_calls", Json::num(stats.solo_calls() as f64)),
                ("mean_fused_rows", Json::num(stats.mean_fused_rows())),
            ]),
        ));
    }
    let speedup = if tok_per_s[0] > 0.0 { tok_per_s[1] / tok_per_s[0] } else { 0.0 };
    println!("  fused/solo throughput: {speedup:.2}x");
    let mut kv = vec![("method", Json::str(method))];
    kv.extend(report);
    kv.push(("fused_over_solo_tok_per_s", Json::num(speedup)));
    let out = Json::obj(kv).to_string();
    std::fs::write("BENCH_fused_verify.json", &out)?;
    println!("  wrote BENCH_fused_verify.json");
    Ok(())
}

/// Paged-KV shared-prompt scenario (PR 4): N sessions share one prompt,
/// then run fused verify cycles.
///
/// Two parts:
/// * a host-level packing microbench over real `KvCache`/`FusedScratch`
///   state (no artifacts needed): steady-state pack bytes per cycle under
///   paged staging vs. the contiguous gather the old packer did, plus the
///   fusion-capacity ceiling (max co-active sessions) old vs. new;
/// * the same shared-prompt fleet through a 1-worker scheduler pool, so
///   the wire counters (`pack_pages_copied` / `pack_pages_reused` /
///   `shared_pages`) land in the report when a runnable method exists.
///
/// Results go to stdout and `BENCH_paged_kv.json`.
fn paged_kv_bench(dir: &std::path::Path, method: &str) -> anyhow::Result<()> {
    use hass::engine::sessions::pick_block;
    use hass::kvcache::{FusedScratch, KvCache, PackMember, PackedLayout};
    use hass::runtime::TensorF;
    use hass::scheduler::{Job, Scheduler};
    use hass::spec::MethodCfg;
    use hass::util::json::Json;

    // ---- host microbench: paged vs contiguous pack cost ----
    let (layers, slots, heads, hd) = (2usize, 512usize, 2usize, 8usize);
    let rs = heads * hd;
    let page = KvCache::new(layers, slots, heads, hd).page_size();
    // 8 sessions x 128-slot shared prompt: the contiguous packer's bound
    // ((slots - block) / prompt = 3 sessions) is exceeded, the paged one
    // holds the prompt pages once + one private tail page per session
    let (n_sessions, prompt_len, rows_per, cycles) = (8usize, 128usize, 4usize, 8usize);

    let full_tensors = |seed: u32| -> (TensorF, TensorF) {
        let n = layers * slots * rs;
        let f =
            |i: usize| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) % 9973) as f32 * 0.1;
        (
            TensorF { dims: vec![layers, slots, heads, hd], data: (0..n).map(f).collect() },
            TensorF { dims: vec![layers, slots, heads, hd], data: (0..n).map(|i| -f(i)).collect() },
        )
    };
    // identical prompt KV -> prefill dedup shares the prompt pages
    let mut sessions: Vec<KvCache> = (0..n_sessions)
        .map(|_| {
            let mut c = KvCache::new(layers, slots, heads, hd);
            let (k, v) = full_tensors(7);
            c.absorb(k, v, prompt_len).expect("absorb prompt");
            c.committed = prompt_len;
            c
        })
        .collect();

    let mut scratch = FusedScratch::new();
    let width = pick_block(n_sessions * rows_per);
    let mut copied_per_cycle = Vec::new();
    let mut reused_per_cycle = Vec::new();
    let mut shared_last = 0usize;
    let mut fused_ok = true;
    for cycle in 0..cycles {
        let mut handles = Vec::new();
        let mut members = Vec::new();
        for c in sessions.iter_mut() {
            let pages = c.committed_pages();
            members.push(PackMember {
                page_ids: pages.iter().map(|p| p.id()).collect(),
                prefix_len: c.committed,
                rows: rows_per,
            });
            handles.push(pages);
        }
        let layout = match PackedLayout::plan(&members, slots, page, width) {
            Ok(l) => l,
            Err(e) => {
                println!("  paged pack stopped at cycle {cycle}: {e:#}");
                fused_ok = false;
                break;
            }
        };
        let st = scratch.pack(&layout, &handles, layers, rs)?;
        // release handles before the writes below (as fused_decode does)
        drop(handles);
        copied_per_cycle.push(st.pages_copied);
        reused_per_cycle.push(st.pages_reused);
        shared_last = st.shared_pages;
        // each session accepts 2 rows: write at committed, then commit
        for (si, c) in sessions.iter_mut().enumerate() {
            let (k, v) = full_tensors(1000 + (cycle * n_sessions + si) as u32);
            let at = c.committed;
            c.write_rows_from(&k, &v, at, at, 2)?;
            c.commit(2)?;
        }
    }
    let page_bytes = 2 * layers * page * rs * 4; // k + v, f32
    let steady_copied = copied_per_cycle.last().copied().unwrap_or(0);
    let paged_bytes_cycle = steady_copied * page_bytes;
    // the old packer gathered every member's whole committed prefix
    let contiguous_bytes_cycle: usize =
        sessions.iter().map(|c| 2 * layers * c.committed * rs * 4).sum();
    // fusion capacity for this shared-prompt fleet: old counted each
    // member's full prefix; paged counts the shared pages once + each
    // member's private tail page(s)
    let prompt_pages = prompt_len.div_ceil(page);
    let old_capacity = (slots.saturating_sub(width)) / prompt_len;
    let mut new_capacity = 0usize;
    while (prompt_pages + (new_capacity + 1)) * page + width <= slots {
        new_capacity += 1; // shared prompt pages + one private tail each
    }
    println!("\n== paged KV: shared-prompt pack cost (host microbench) ==");
    println!(
        "  {n_sessions} sessions x {prompt_len}-slot shared prompt, page={page}, \
         {rows_per} rows/cycle"
    );
    println!(
        "  steady-state pack: {steady_copied} pages copied/cycle ({paged_bytes_cycle} B) vs \
         contiguous gather {contiguous_bytes_cycle} B; shared_pages={shared_last}"
    );
    println!(
        "  fusion capacity (shared prompt): {old_capacity} sessions (contiguous bound) -> \
         {new_capacity} (paged bound)"
    );

    // ---- the same fleet through a scheduler pool (wire counters) ----
    let shared_prompt = "User: Summarize the history of container shipping.\nAssistant:";
    let sched = Scheduler::start(dir.to_path_buf(), MethodCfg::default(), 64, 1, n_sessions);
    let (rtx, rrx) = std::sync::mpsc::channel();
    for i in 0..n_sessions {
        let job = Job {
            id: i as u64 + 1,
            method: method.to_string(),
            prompt: shared_prompt.to_string(),
            max_new: 24,
            temperature: 0.0,
            seed: i as u64,
            stream: false,
            deadline_ms: None,
            priority: 0,
        };
        sched.submit_to(job, true, rtx.clone())?;
    }
    drop(rtx);
    let mut sched_errors = 0usize;
    for r in rrx.iter().filter_map(hass::scheduler::JobEvent::into_result) {
        if r.error.is_some() {
            sched_errors += 1;
        }
    }
    let pool = sched.stats();
    sched.shutdown();
    println!(
        "  scheduler fleet ('{method}', {n_sessions} shared-prompt jobs): \
         pack_copied={} pack_reused={} shared_pages={} errors={sched_errors}",
        pool.pack_pages_copied(),
        pool.pack_pages_reused(),
        pool.shared_pages(),
    );

    let report = Json::obj(vec![
        ("page_size", Json::num(page as f64)),
        ("sessions", Json::num(n_sessions as f64)),
        ("prompt_len", Json::num(prompt_len as f64)),
        ("rows_per_cycle", Json::num(rows_per as f64)),
        ("fused_ok", Json::Bool(fused_ok)),
        (
            "pages_copied_per_cycle",
            Json::Arr(copied_per_cycle.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        (
            "pages_reused_per_cycle",
            Json::Arr(reused_per_cycle.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        ("shared_pages", Json::num(shared_last as f64)),
        ("paged_pack_bytes_per_cycle", Json::num(paged_bytes_cycle as f64)),
        ("contiguous_pack_bytes_per_cycle", Json::num(contiguous_bytes_cycle as f64)),
        ("fused_capacity_sessions_contiguous", Json::num(old_capacity as f64)),
        ("fused_capacity_sessions_paged", Json::num(new_capacity as f64)),
        ("scheduler_pack_pages_copied", Json::num(pool.pack_pages_copied() as f64)),
        ("scheduler_pack_pages_reused", Json::num(pool.pack_pages_reused() as f64)),
        ("scheduler_shared_pages", Json::num(pool.shared_pages() as f64)),
        ("scheduler_errors", Json::num(sched_errors as f64)),
    ]);
    std::fs::write("BENCH_paged_kv.json", report.to_string())?;
    println!("  wrote BENCH_paged_kv.json");
    Ok(())
}

/// Draft-side batching scenario (PR 5): the same jobs through one worker
/// at `--max-active 1` (every draft level runs solo inside `plan`) and
/// `--max-active 4` (co-active sessions' levels fuse into one draft call
/// per level), reporting draft executions per cycle and throughput.
/// Results go to stdout and `BENCH_draft_batch.json`.
fn draft_batch_bench(
    dir: &std::path::Path,
    wl: &Workloads,
    method: &str,
    n_requests: usize,
) -> anyhow::Result<()> {
    use hass::scheduler::{Job, Scheduler};
    use hass::util::json::Json;

    let method = {
        let resolved = resolve_runnable(dir, method)?;
        if resolved != method {
            println!("\n(draft-batch bench: '{method}' unavailable, using 'mock')");
        }
        resolved
    };
    let trace: Vec<(String, String, usize)> = wl
        .trace_split(n_requests.max(8), 555, 1)
        .into_iter()
        .flatten()
        .collect();
    println!("\n== draft-side batching ({} jobs, method '{method}') ==", trace.len());
    let mut report: Vec<(&str, Json)> = Vec::new();
    let mut tok_per_s = [0.0f64; 2];
    for (pass, &(label, max_active)) in [("solo", 1usize), ("fused", 4usize)].iter().enumerate() {
        let sched = Scheduler::start(dir.to_path_buf(), MethodCfg::default(), 64, 1, max_active);
        let (rtx, rrx) = std::sync::mpsc::channel();
        let t0 = std::time::Instant::now();
        for (i, (_suite, prompt, max_new)) in trace.iter().enumerate() {
            let job = Job {
                id: i as u64 + 1,
                method: method.clone(),
                prompt: prompt.clone(),
                max_new: *max_new,
                temperature: 0.0,
                seed: i as u64,
                stream: false,
                deadline_ms: None,
                priority: 0,
            };
            sched.submit_to(job, true, rtx.clone())?;
        }
        drop(rtx);
        let mut tokens = 0usize;
        let mut errors = 0usize;
        for r in rrx.iter().filter_map(hass::scheduler::JobEvent::into_result) {
            match r.error {
                Some(_) => errors += 1,
                None => tokens += r.tokens,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = sched.stats();
        sched.shutdown();
        tok_per_s[pass] = if wall > 0.0 { tokens as f64 / wall } else { 0.0 };
        let cycles = stats.metrics().cycles.max(1);
        let drafts_per_cycle = stats.draft_execs() as f64 / cycles as f64;
        println!(
            "  {label:<5} (max-active {max_active}): {tokens} tokens in {wall:.2}s \
             ({:.1} tok/s)  draft_execs={} fused={} solo={} \
             drafts/cycle={drafts_per_cycle:.2} mean_rows_per_fused={:.1} errors={errors}",
            tok_per_s[pass],
            stats.draft_execs(),
            stats.draft_fused_calls(),
            stats.draft_solo_calls(),
            stats.mean_draft_fused_rows(),
        );
        report.push((
            label,
            Json::obj(vec![
                ("max_active", Json::num(max_active as f64)),
                ("jobs", Json::num(trace.len() as f64)),
                ("errors", Json::num(errors as f64)),
                ("tokens", Json::num(tokens as f64)),
                ("wall_s", Json::num(wall)),
                ("tok_per_s", Json::num(tok_per_s[pass])),
                ("cycles", Json::num(cycles as f64)),
                ("draft_execs", Json::num(stats.draft_execs() as f64)),
                ("draft_fused_calls", Json::num(stats.draft_fused_calls() as f64)),
                ("draft_solo_calls", Json::num(stats.draft_solo_calls() as f64)),
                ("draft_calls_per_cycle", Json::num(drafts_per_cycle)),
                ("mean_draft_fused_rows", Json::num(stats.mean_draft_fused_rows())),
                (
                    "draft_pack_pages_copied",
                    Json::num(stats.draft_pack_pages_copied() as f64),
                ),
                (
                    "draft_pack_pages_reused",
                    Json::num(stats.draft_pack_pages_reused() as f64),
                ),
            ]),
        ));
    }
    let speedup = if tok_per_s[0] > 0.0 { tok_per_s[1] / tok_per_s[0] } else { 0.0 };
    println!("  fused/solo throughput: {speedup:.2}x");
    let mut kv = vec![("method", Json::str(method))];
    kv.extend(report);
    kv.push(("fused_over_solo_tok_per_s", Json::num(speedup)));
    std::fs::write("BENCH_draft_batch.json", Json::obj(kv).to_string())?;
    println!("  wrote BENCH_draft_batch.json");
    Ok(())
}

/// Shared-page-pool scenario (PR 8): the pool-wide `Arc` page registry
/// dedups identical prompt pages ACROSS worker threads, and prefix-
/// affinity dispatch routes same-prefix sessions to the worker whose
/// pages are already hot.
///
/// Two parts:
/// * a host microbench: 2 OS threads ("workers") each absorb the same
///   prompt KV into 4 caches; physical pages = distinct page ids
///   pool-wide vs logical pages = Σ per-cache prompt pages.  Under the
///   old per-thread `Rc` registry the threads could never share, so
///   physical was ~2x one prompt's pages; the shared pool holds them
///   once (~1x);
/// * a same-prefix fleet through a 2-worker scheduler pool with
///   prefix-affinity routing on vs off: tok/s plus the routing counters
///   (`affinity_hits`/`affinity_misses`/`cross_worker_shared_pages`)
///   and the registry gauges.
///
/// Results go to stdout and `BENCH_page_pool.json`.
fn page_pool_bench(dir: &std::path::Path, method: &str) -> anyhow::Result<()> {
    use std::collections::HashSet;

    use hass::kvcache::KvCache;
    use hass::runtime::TensorF;
    use hass::scheduler::{Job, Scheduler};
    use hass::util::json::Json;

    // ---- host microbench: cross-thread prompt-page dedup ----
    let (layers, slots, heads, hd) = (2usize, 128usize, 2usize, 8usize);
    let rs = heads * hd;
    let (n_threads, caches_per, prompt_len) = (2usize, 4usize, 96usize);
    // no captures: the tensor builder must cross the spawn boundary
    fn prompt_tensors(layers: usize, slots: usize, heads: usize, hd: usize) -> (TensorF, TensorF) {
        let n = layers * slots * heads * hd;
        let f =
            |i: usize| ((i as u32).wrapping_mul(2654435761).wrapping_add(7) % 9973) as f32 * 0.1;
        (
            TensorF { dims: vec![layers, slots, heads, hd], data: (0..n).map(f).collect() },
            TensorF { dims: vec![layers, slots, heads, hd], data: (0..n).map(|i| -f(i)).collect() },
        )
    }
    let threads: Vec<_> = (0..n_threads)
        .map(|_| {
            std::thread::spawn(move || {
                (0..caches_per)
                    .map(|_| {
                        let mut c = KvCache::new(layers, slots, heads, hd);
                        let (k, v) = prompt_tensors(layers, slots, heads, hd);
                        c.absorb(k, v, prompt_len).expect("absorb prompt");
                        c.committed = prompt_len;
                        c
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    let mut caches: Vec<KvCache> = Vec::new();
    for h in threads {
        caches.extend(h.join().expect("worker thread"));
    }
    let page = caches[0].page_size();
    let mut physical: HashSet<u64> = HashSet::new();
    let mut logical = 0usize;
    for c in caches.iter_mut() {
        let pages = c.committed_pages();
        logical += pages.len();
        physical.extend(pages.iter().map(|p| p.id()));
    }
    let page_bytes = 2 * layers * page * rs * 4; // k + v, f32
    let physical_bytes = physical.len() * page_bytes;
    let logical_bytes = logical * page_bytes;
    println!("\n== shared page pool: physical vs logical prompt pages ==");
    println!(
        "  {n_threads} threads x {caches_per} caches, {prompt_len}-slot shared prompt, \
         page={page}"
    );
    println!(
        "  physical={} pages ({physical_bytes} B) vs logical={logical} pages \
         ({logical_bytes} B) -> {:.2}x dedup",
        physical.len(),
        logical as f64 / physical.len().max(1) as f64,
    );
    drop(caches);

    // ---- 2-worker fleet: prefix-affinity routing on vs off ----
    let method = {
        let resolved = resolve_runnable(dir, method)?;
        if resolved != method {
            println!("  (page-pool bench: '{method}' unavailable, using 'mock')");
        }
        resolved
    };
    let shared_prompt = "User: Summarize the history of container shipping.\nAssistant:";
    let n_jobs = 8usize;
    println!("== shared page pool: 2-worker fleet, affinity off vs on ('{method}') ==");
    let mut report: Vec<(&str, Json)> = Vec::new();
    let mut tok_per_s = [0.0f64; 2];
    for (pass, &(label, affinity)) in
        [("affinity_off", false), ("affinity_on", true)].iter().enumerate()
    {
        let sched = Scheduler::start_with_affinity(
            dir.to_path_buf(),
            MethodCfg::default(),
            64,
            2,
            4,
            affinity,
        );
        let (rtx, rrx) = std::sync::mpsc::channel();
        let t0 = std::time::Instant::now();
        for i in 0..n_jobs {
            let job = Job {
                id: i as u64 + 1,
                method: method.clone(),
                prompt: shared_prompt.to_string(),
                max_new: 24,
                temperature: 0.0,
                seed: i as u64,
                stream: false,
                deadline_ms: None,
                priority: 0,
            };
            sched.submit_to(job, true, rtx.clone())?;
        }
        drop(rtx);
        let mut tokens = 0usize;
        let mut errors = 0usize;
        for r in rrx.iter().filter_map(hass::scheduler::JobEvent::into_result) {
            match r.error {
                Some(_) => errors += 1,
                None => tokens += r.tokens,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = sched.stats();
        sched.shutdown();
        tok_per_s[pass] = if wall > 0.0 { tokens as f64 / wall } else { 0.0 };
        let workers_used = stats.workers.iter().filter(|w| w.jobs() > 0).count();
        println!(
            "  {label:<12}: {tokens} tokens in {wall:.2}s ({:.1} tok/s)  \
             workers_used={workers_used} hits={} misses={} cross_shared={} \
             registry_entries={} errors={errors}",
            tok_per_s[pass],
            stats.affinity_hits(),
            stats.affinity_misses(),
            stats.cross_worker_shared_pages(),
            stats.registry_entries,
        );
        report.push((
            label,
            Json::obj(vec![
                ("jobs", Json::num(n_jobs as f64)),
                ("errors", Json::num(errors as f64)),
                ("tokens", Json::num(tokens as f64)),
                ("wall_s", Json::num(wall)),
                ("tok_per_s", Json::num(tok_per_s[pass])),
                ("workers_used", Json::num(workers_used as f64)),
                ("affinity_hits", Json::num(stats.affinity_hits() as f64)),
                ("affinity_misses", Json::num(stats.affinity_misses() as f64)),
                ("cross_worker_shared_pages", Json::num(stats.cross_worker_shared_pages() as f64)),
                ("registry_entries", Json::num(stats.registry_entries as f64)),
                ("registry_evictions", Json::num(stats.registry_evictions as f64)),
            ]),
        ));
    }
    let speedup = if tok_per_s[0] > 0.0 { tok_per_s[1] / tok_per_s[0] } else { 0.0 };
    println!("  affinity-on/off throughput: {speedup:.2}x");
    let mut kv = vec![
        ("method", Json::str(method)),
        ("physical_prompt_pages", Json::num(physical.len() as f64)),
        ("logical_prompt_pages", Json::num(logical as f64)),
        ("physical_prompt_bytes", Json::num(physical_bytes as f64)),
        ("logical_prompt_bytes", Json::num(logical_bytes as f64)),
        ("page_bytes", Json::num(page_bytes as f64)),
    ];
    kv.extend(report);
    kv.push(("affinity_on_over_off_tok_per_s", Json::num(speedup)));
    std::fs::write("BENCH_page_pool.json", Json::obj(kv).to_string())?;
    println!("  wrote BENCH_page_pool.json");
    Ok(())
}

/// Open-loop load scenario (PR 9): estimate the pool's closed-loop
/// capacity, then fire Poisson (0.5x/1x) and bursty (2x) arrival traces
/// on a wall-clock schedule REGARDLESS of completions through a pool
/// with a tight spill timeout, so sustained overload sheds explicitly
/// (`overloaded` + `retry_after_ms`) instead of queueing unboundedly.
/// Per load: p50/p95/p99 end-to-end latency, TTFT (first streamed
/// delta), goodput, and the shed/preempt/breaker counters, written to
/// `BENCH_load.json` and cross-checkable against the pool's stats wire.
fn load_bench(dir: &std::path::Path, wl: &Workloads, method: &str) -> anyhow::Result<()> {
    use std::collections::HashMap;

    use hass::scheduler::{Job, JobEvent, OverloadPolicy, Overloaded, Scheduler};
    use hass::util::json::Json;
    use hass::util::stats::percentile_sorted as pct;
    use hass::workload::Arrivals;

    let method = {
        let resolved = resolve_runnable(dir, method)?;
        if resolved != method {
            println!("\n(load bench: '{method}' unavailable, using 'mock')");
        }
        resolved
    };
    let (workers, max_active) = (2usize, 4usize);
    // throttle every admission + step so service time dominates submit
    // overhead — without it the mock backend is so fast that "2x
    // capacity" cannot be generated from one submitter thread
    std::env::set_var("HASS_TEST_JOB_DELAY_MS", "2");

    // ---- closed-loop capacity estimate (same pool shape) ----
    let capacity_req_s = {
        let sched =
            Scheduler::start(dir.to_path_buf(), MethodCfg::default(), 64, workers, max_active);
        let (rtx, rrx) = std::sync::mpsc::channel();
        let n = 32usize;
        let t0 = std::time::Instant::now();
        for i in 0..n {
            let job = Job {
                id: i as u64 + 1,
                method: method.clone(),
                prompt: "User: capacity probe\nAssistant:".into(),
                max_new: 16,
                temperature: 0.0,
                seed: i as u64,
                stream: false,
                deadline_ms: None,
                priority: 0,
            };
            sched.submit_to(job, true, rtx.clone())?;
        }
        drop(rtx);
        let ok = rrx
            .iter()
            .filter_map(JobEvent::into_result)
            .filter(|r| r.error.is_none())
            .count();
        let wall = t0.elapsed().as_secs_f64();
        sched.shutdown();
        ok.max(1) as f64 / wall.max(1e-6)
    };
    println!("\n== open-loop load ({workers} workers, method '{method}') ==");
    println!("  estimated closed-loop capacity: {capacity_req_s:.1} req/s");

    let loads: [(&str, f64); 3] = [("load_0_5x", 0.5), ("load_1x", 1.0), ("load_2x", 2.0)];
    let mut report: Vec<(&str, Json)> = Vec::new();
    let mut next_id = 1u64;
    for (label, factor) in loads {
        let rate = (capacity_req_s * factor).max(1.0);
        // ~2.5s of arrivals per load point, bounded for slow machines
        let n = ((rate * 2.5) as usize).clamp(16, 160);
        // 2x arrives in bursts — the pattern that actually trips shedding
        let arrivals = if factor > 1.0 {
            Arrivals::Bursty { rate_per_s: rate, burst: 8, every_ms: 250 }
        } else {
            Arrivals::Poisson { rate_per_s: rate }
        };
        let trace = wl.open_loop_trace(n, 42 + factor as u64, arrivals);

        // tight spill timeout: sustained overload sheds in ~50ms instead
        // of parking the submitter on the bounded channel for 2s
        let policy = OverloadPolicy {
            spill_timeout_ms: 50,
            retry_after_ms: 100,
            breaker_max_ms: Some(1500),
            ..OverloadPolicy::default()
        };
        let sched = Scheduler::start_with_policy(
            dir.to_path_buf(),
            MethodCfg::default(),
            8,
            workers,
            max_active,
            true,
            policy,
        );
        let t0 = std::time::Instant::now();
        let (rtx, rrx) = std::sync::mpsc::channel::<JobEvent>();
        // collector thread timestamps events on arrival (TTFT needs the
        // first delta's wall-clock offset, not its drain time)
        let collector = std::thread::spawn(move || {
            let mut first_delta: HashMap<u64, f64> = HashMap::new();
            let mut done: Vec<hass::scheduler::JobResult> = Vec::new();
            for ev in rrx {
                let now = t0.elapsed().as_secs_f64();
                match ev {
                    JobEvent::Delta { id, .. } => {
                        first_delta.entry(id).or_insert(now);
                    }
                    JobEvent::Done(r) => done.push(r),
                }
            }
            (first_delta, done)
        });

        // open-loop submitter: fire at each arrival offset no matter how
        // far behind the pool is
        let mut submit_at: HashMap<u64, f64> = HashMap::new();
        let (mut shed, mut submit_errors) = (0usize, 0usize);
        for req in trace {
            let due = std::time::Duration::from_millis(req.at_ms);
            let now = t0.elapsed();
            if due > now {
                std::thread::sleep(due - now);
            }
            let id = next_id;
            next_id += 1;
            submit_at.insert(id, t0.elapsed().as_secs_f64());
            let job = Job {
                id,
                method: method.clone(),
                prompt: req.prompt,
                max_new: req.max_new,
                temperature: 0.0,
                seed: id,
                stream: true, // deltas give TTFT
                deadline_ms: None,
                priority: req.priority,
            };
            if let Err(e) = sched.submit_to(job, true, rtx.clone()) {
                if Overloaded::parse(&format!("{e:#}")).is_some() {
                    shed += 1;
                } else {
                    submit_errors += 1;
                }
            }
        }
        drop(rtx);
        let (first_delta, done) = collector.join().expect("collector thread");
        let wall = t0.elapsed().as_secs_f64();
        let stats = sched.stats();
        sched.shutdown();

        let mut lats: Vec<f64> = Vec::new();
        let mut ttfts: Vec<f64> = Vec::new();
        let mut tokens = 0usize;
        let (mut ok, mut errored) = (0usize, 0usize);
        for r in &done {
            if r.error.is_some() {
                errored += 1;
                continue;
            }
            ok += 1;
            tokens += r.tokens;
            lats.push(r.latency_s * 1000.0);
            if let Some((t_first, t_sub)) = first_delta.get(&r.id).zip(submit_at.get(&r.id)) {
                ttfts.push((t_first - t_sub) * 1000.0);
            }
        }
        lats.sort_by(|a, b| a.total_cmp(b));
        ttfts.sort_by(|a, b| a.total_cmp(b));
        let goodput = ok as f64 / wall.max(1e-6);
        println!(
            "  {label:<9} rate={rate:.1} req/s n={n}: ok={ok} shed={shed} errored={errored} \
             goodput={goodput:.1} req/s  lat p50={:.0} p95={:.0} p99={:.0} ms  \
             ttft p50={:.0} p95={:.0} ms  preempt={} breaker={} rejects={}",
            pct(&lats, 0.50),
            pct(&lats, 0.95),
            pct(&lats, 0.99),
            pct(&ttfts, 0.50),
            pct(&ttfts, 0.95),
            stats.preemptions(),
            stats.breaker_trips(),
            stats.admission_rejects,
        );
        if submit_errors > 0 {
            println!("  {label:<9} non-overload submit errors: {submit_errors}");
        }
        report.push((
            label,
            Json::obj(vec![
                ("load_factor", Json::num(factor)),
                ("arrivals", Json::str(if factor > 1.0 { "bursty" } else { "poisson" })),
                ("rate_req_per_s", Json::num(rate)),
                ("requests", Json::num(n as f64)),
                ("ok", Json::num(ok as f64)),
                ("shed", Json::num(shed as f64)),
                ("errored", Json::num(errored as f64)),
                ("submit_errors", Json::num(submit_errors as f64)),
                ("tokens", Json::num(tokens as f64)),
                ("wall_s", Json::num(wall)),
                ("goodput_req_per_s", Json::num(goodput)),
                ("latency_ms_p50", Json::num(pct(&lats, 0.50))),
                ("latency_ms_p95", Json::num(pct(&lats, 0.95))),
                ("latency_ms_p99", Json::num(pct(&lats, 0.99))),
                ("ttft_ms_p50", Json::num(pct(&ttfts, 0.50))),
                ("ttft_ms_p95", Json::num(pct(&ttfts, 0.95))),
                ("ttft_ms_p99", Json::num(pct(&ttfts, 0.99))),
                ("admission_rejects", Json::num(stats.admission_rejects as f64)),
                ("preemptions", Json::num(stats.preemptions() as f64)),
                ("resumes", Json::num(stats.resumes() as f64)),
                ("breaker_trips", Json::num(stats.breaker_trips() as f64)),
                ("mean_queue_wait_ms", Json::num(stats.mean_queue_wait_ms())),
                ("mean_ttft_ms", Json::num(stats.mean_ttft_ms())),
            ]),
        ));
    }
    std::env::remove_var("HASS_TEST_JOB_DELAY_MS");

    let mut kv = vec![
        ("method", Json::str(method)),
        ("workers", Json::num(workers as f64)),
        ("max_active", Json::num(max_active as f64)),
        ("est_capacity_req_per_s", Json::num(capacity_req_s)),
    ];
    kv.extend(report);
    std::fs::write("BENCH_load.json", Json::obj(kv).to_string())?;
    println!("  wrote BENCH_load.json");
    Ok(())
}

/// Chaos scenario (PR 10): replay one seeded open-loop trace twice
/// through identical pools — once fault-free for a per-request baseline,
/// once under injected faults (worker panics per cycle plus decode-call
/// errors, each >= 1%) scoped to the chaos pool's threads.  The run
/// ASSERTS the recovery contract: every request completes exactly once
/// (zero lost, zero duplicated), error-free, with streamed deltas
/// concatenating to a final text byte-identical to the fault-free run.
/// Recovery latency, requeue/replay counts and per-point failpoint
/// trigger counts go to stdout and `BENCH_chaos.json`.
fn chaos_bench(dir: &std::path::Path, wl: &Workloads, method: &str) -> anyhow::Result<()> {
    use std::collections::HashMap;

    use hass::scheduler::{Job, JobEvent, Scheduler};
    use hass::util::failpoint::{self, Action, FaultSpec};
    use hass::util::json::Json;
    use hass::workload::Arrivals;

    let method = {
        let resolved = resolve_runnable(dir, method)?;
        if resolved != method {
            println!("\n(chaos bench: '{method}' unavailable, using 'mock')");
        }
        resolved
    };
    let (workers, max_active, n) = (2usize, 2usize, 24usize);
    // stretch cycles so worker-tick faults actually interleave with live
    // sessions (the mock backend is otherwise too fast to interrupt)
    std::env::set_var("HASS_TEST_JOB_DELAY_MS", "2");
    let trace = || wl.open_loop_trace(n, 777, Arrivals::Poisson { rate_per_s: 40.0 });
    let job_for = |id: u64, prompt: String, max_new: usize, stream: bool| Job {
        id,
        method: method.clone(),
        prompt,
        max_new,
        temperature: 0.0,
        seed: id, // generation is seeded: replay after a crash is exact
        stream,
        deadline_ms: None,
        priority: 0,
    };

    // ---- fault-free baseline: text per request id ----
    let baseline: HashMap<u64, (String, usize)> = {
        let sched =
            Scheduler::start(dir.to_path_buf(), MethodCfg::default(), 64, workers, max_active);
        let (rtx, rrx) = std::sync::mpsc::channel::<JobEvent>();
        for (i, req) in trace().into_iter().enumerate() {
            let job = job_for(i as u64 + 1, req.prompt, req.max_new, false);
            sched.submit_to(job, true, rtx.clone())?;
        }
        drop(rtx);
        let out: HashMap<u64, (String, usize)> = rrx
            .iter()
            .filter_map(JobEvent::into_result)
            .filter(|r| r.error.is_none())
            .map(|r| (r.id, (r.text, r.tokens)))
            .collect();
        sched.shutdown();
        out
    };
    anyhow::ensure!(baseline.len() == n, "baseline run lost requests: {}/{n}", baseline.len());

    // ---- same trace under chaos ----
    let sched = Scheduler::start(dir.to_path_buf(), MethodCfg::default(), 64, workers, max_active);
    // worker panics + decode errors, each at >= 1% (decode points only
    // trigger for compiled methods; the mock backend never calls them)
    let specs = vec![
        FaultSpec { point: failpoint::WORKER_TICK, action: Action::Panic, rate: 0.02 },
        FaultSpec { point: failpoint::TARGET_DECODE, action: Action::Err, rate: 0.02 },
        FaultSpec { point: failpoint::DRAFT_DECODE, action: Action::Err, rate: 0.02 },
    ];
    let fault_rates: Vec<(&str, Json)> = specs
        .iter()
        .map(|s| (s.point.name(), Json::num(s.rate)))
        .collect();
    let guard = failpoint::install(Some(sched.fault_scope()), specs, 0xC7A05);
    let t0 = std::time::Instant::now();
    let (rtx, rrx) = std::sync::mpsc::channel::<JobEvent>();
    let collector = std::thread::spawn(move || {
        let mut deltas: HashMap<u64, String> = HashMap::new();
        let mut done: Vec<hass::scheduler::JobResult> = Vec::new();
        for ev in rrx {
            match ev {
                JobEvent::Delta { id, text, .. } => deltas.entry(id).or_default().push_str(&text),
                JobEvent::Done(r) => done.push(r),
            }
        }
        (deltas, done)
    });
    let mut submit_errors = 0usize;
    for (i, req) in trace().into_iter().enumerate() {
        let due = std::time::Duration::from_millis(req.at_ms);
        let now = t0.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        // streamed, so crash recovery exercises the replay path and the
        // delta journal proves no token is delivered twice
        let job = job_for(i as u64 + 1, req.prompt, req.max_new, true);
        if sched.submit_to(job, true, rtx.clone()).is_err() {
            submit_errors += 1;
        }
    }
    drop(rtx);
    let (deltas, done) = collector.join().expect("collector thread");
    let wall = t0.elapsed().as_secs_f64();
    let stats = sched.stats();
    drop(guard);
    sched.shutdown();
    std::env::remove_var("HASS_TEST_JOB_DELAY_MS");

    // ---- the recovery contract ----
    anyhow::ensure!(submit_errors == 0, "{submit_errors} submissions failed under chaos");
    anyhow::ensure!(
        done.len() == n,
        "lost or duplicated responses under chaos: {} done for {n} submitted",
        done.len()
    );
    let mut seen = std::collections::HashSet::new();
    for r in &done {
        anyhow::ensure!(seen.insert(r.id), "request {} completed twice", r.id);
        anyhow::ensure!(r.error.is_none(), "request {} errored under chaos: {:?}", r.id, r.error);
        let (want_text, want_tokens) = &baseline[&r.id];
        anyhow::ensure!(
            r.text == *want_text && r.tokens == *want_tokens,
            "request {} token-corrupted under chaos",
            r.id
        );
        let streamed = deltas.get(&r.id).map(String::as_str).unwrap_or("");
        anyhow::ensure!(
            streamed == r.text,
            "request {} deltas diverged from its final text (duplicate or missing tokens)",
            r.id
        );
    }
    let triggers: Vec<(&str, Json)> = failpoint::triggers()
        .into_iter()
        .filter(|&(_, c)| c > 0)
        .map(|(name, c)| (name, Json::num(c as f64)))
        .collect();
    println!("\n== chaos ({workers} workers, method '{method}', {n} requests) ==");
    println!(
        "  all {n} requests exactly-once and token-identical to the fault-free run\n  \
         worker_deaths={} requeues={} replays={} mean_recovery_ms={:.1} wall={wall:.1}s",
        stats.worker_deaths(),
        stats.requeues(),
        stats.replays(),
        stats.mean_recovery_ms(),
    );
    println!("  failpoint triggers: {}", Json::obj(triggers.clone()));
    let kv = vec![
        ("method", Json::str(method)),
        ("workers", Json::num(workers as f64)),
        ("max_active", Json::num(max_active as f64)),
        ("requests", Json::num(n as f64)),
        ("fault_rates", Json::obj(fault_rates)),
        ("ok", Json::num(done.len() as f64)),
        ("lost", Json::num(0.0)),
        ("duplicated", Json::num(0.0)),
        ("token_corrupted", Json::num(0.0)),
        ("worker_deaths", Json::num(stats.worker_deaths() as f64)),
        ("requeues", Json::num(stats.requeues() as f64)),
        ("replays", Json::num(stats.replays() as f64)),
        ("mean_recovery_ms", Json::num(stats.mean_recovery_ms())),
        ("wall_s", Json::num(wall)),
        ("failpoint_triggers", Json::obj(triggers)),
    ];
    std::fs::write("BENCH_chaos.json", Json::obj(kv).to_string())?;
    println!("  wrote BENCH_chaos.json");
    Ok(())
}
