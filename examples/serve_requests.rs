//! End-to-end serving driver (the repo's E2E validation workload):
//! starts the scheduler + TCP server in-process, replays a mixed-suite
//! request trace from concurrent client connections, and reports
//! latency percentiles, throughput, and mean acceptance length.
//!
//! ```sh
//! cargo run --release --example serve_requests -- [n_requests] [method]
//! ```

use std::sync::Arc;

use hass::server::Client;
use hass::spec::MethodCfg;
use hass::util::stats::summarize;
use hass::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let method = args.get(2).cloned().unwrap_or_else(|| "hass".to_string());

    let dir = hass::artifact_dir();
    let wl = Workloads::load(&dir).unwrap_or_else(|_| Workloads::embedded());
    let sched = Arc::new(hass::scheduler::Scheduler::start(dir, MethodCfg::default(), 64));
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let sched = sched.clone();
        std::thread::spawn(move || hass::server::serve(listener, sched));
    }
    println!("server on {addr}; replaying {n_requests} requests with '{method}'");

    let trace = wl.trace(n_requests, 123);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    // 3 concurrent client connections hammering the queue (batch=1 engine)
    for (ci, chunk) in trace.chunks(n_requests.div_ceil(3)).enumerate() {
        let chunk = chunk.to_vec();
        let method = method.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr.to_string()).expect("connect");
            let mut out = Vec::new();
            for (suite, prompt, max_new) in chunk {
                let resp = c.request(&method, &prompt, max_new, 0.0).expect("request");
                let lat = resp.f64_at("latency_ms").unwrap_or(0.0);
                let q = resp.f64_at("queue_ms").unwrap_or(0.0);
                let tau = resp.f64_at("tau").unwrap_or(0.0);
                let toks = resp.usize_at("tokens").unwrap_or(0);
                println!("  client{ci} {suite:<9} tokens={toks:<3} tau={tau:<5} lat={lat:.0}ms queue={q:.0}ms");
                out.push((lat, q, tau, toks));
            }
            out
        }));
    }
    let mut lats = Vec::new();
    let mut taus = Vec::new();
    let mut total_tokens = 0usize;
    for h in handles {
        for (lat, _q, tau, toks) in h.join().unwrap() {
            lats.push(lat);
            taus.push(tau);
            total_tokens += toks;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = summarize(&lats);
    println!("\n== serving summary ({method}) ==");
    println!("requests: {}   tokens: {}   wall: {:.1}s", lats.len(), total_tokens, wall);
    println!("throughput: {:.1} tok/s   {:.2} req/s", total_tokens as f64 / wall, lats.len() as f64 / wall);
    println!("latency ms: mean={:.0} p50={:.0} p90={:.0} p99={:.0}", s.mean, s.p50, s.p90, s.p99);
    println!("mean tau: {:.2}", taus.iter().sum::<f64>() / taus.len().max(1) as f64);
    Ok(())
}
