//! End-to-end serving driver (the repo's E2E validation workload):
//! for each worker count, starts a scheduler pool + TCP server
//! in-process, replays a mixed-suite request trace from concurrent
//! client connections, fetches the pool's `{"stats": true}` snapshot
//! over the wire, and reports latency percentiles plus the aggregate
//! throughput per worker count.
//!
//! ```sh
//! cargo run --release --example serve_requests -- \
//!     [--requests 12] [--method hass] [--clients 3] [--workers 1,2] \
//!     [--max-active 2]
//! ```
//!
//! `--max-active` sets how many jobs each engine worker interleaves
//! round-robin (cycle-granular continuous batching); the run ends with a
//! streamed request that counts per-cycle delta lines.

use std::sync::Arc;

use hass::server::{Client, ReqOpts};
use hass::spec::MethodCfg;
use hass::util::cli::Args;
use hass::util::stats::summarize;
use hass::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    // legacy positional form `serve_requests 12 hass` still works: the
    // leading count parses as Args' subcommand, the method as positional 0
    let n_requests = args.usize_or("requests", args.subcommand.parse().unwrap_or(12));
    let method = args.get_or("method", &args.pos_or(0, "hass"));
    let n_clients = args.usize_or("clients", 3).max(1);
    let worker_counts = args.usize_list_or("workers", &[1, 2]);
    let max_active = args.usize_or("max-active", 2).max(1);

    let dir = hass::artifact_dir();
    let wl = Workloads::load(&dir).unwrap_or_else(|_| Workloads::embedded());

    let mut summary = Vec::new();
    for &workers in &worker_counts {
        let workers = workers.max(1); // Scheduler::start clamps the same way
        let sched = Arc::new(hass::scheduler::Scheduler::start(
            dir.clone(),
            MethodCfg::default(),
            64,
            workers,
            max_active,
        ));
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        {
            let sched = sched.clone();
            std::thread::spawn(move || hass::server::serve(listener, sched));
        }
        println!(
            "\n== {workers} worker(s) on {addr}: {n_requests} requests over \
             {n_clients} connections, method '{method}' =="
        );

        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for (ci, chunk) in wl.trace_split(n_requests, 123, n_clients).into_iter().enumerate() {
            if chunk.is_empty() {
                continue;
            }
            let method = method.clone();
            let addr = addr.to_string();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let mut out = Vec::new();
                for (suite, prompt, max_new) in chunk {
                    let resp = c.request(&method, &prompt, max_new, 0.0).expect("request");
                    if let Some(err) = resp.str_at("error") {
                        println!("  client{ci} {suite:<9} error: {err}");
                        continue;
                    }
                    let lat = resp.f64_at("latency_ms").unwrap_or(0.0);
                    let q = resp.f64_at("queue_ms").unwrap_or(0.0);
                    let tau = resp.f64_at("tau").unwrap_or(0.0);
                    let toks = resp.usize_at("tokens").unwrap_or(0);
                    let w = resp.usize_at("worker").unwrap_or(0);
                    println!(
                        "  client{ci} {suite:<9} worker={w} tokens={toks:<3} \
                         tau={tau:<5} lat={lat:.0}ms queue={q:.0}ms"
                    );
                    out.push((lat, q, tau, toks));
                }
                out
            }));
        }
        let mut lats = Vec::new();
        let mut taus = Vec::new();
        let mut total_tokens = 0usize;
        for h in handles {
            for (lat, _q, tau, toks) in h.join().expect("client thread") {
                lats.push(lat);
                taus.push(tau);
                total_tokens += toks;
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        let mut c = Client::connect(&addr.to_string())?;
        // streamed request demo: per-cycle deltas over the same pool
        let mut n_deltas = 0usize;
        let fin = c.generate(
            "User: stream demo please\nAssistant:",
            &ReqOpts { method: method.clone(), max_tokens: 16, stream: true, ..Default::default() },
            |_| n_deltas += 1,
        )?;
        match fin.str_at("error") {
            Some(e) => println!("  stream demo: error: {e}"),
            None => println!(
                "  stream demo: {n_deltas} delta lines -> {} tokens",
                fin.usize_at("tokens").unwrap_or(0)
            ),
        }
        let stats = c.stats()?;
        if let Some(agg) = stats.get("stats").and_then(|s| s.get("aggregate")) {
            println!(
                "  pool: jobs={} ok={} err={} tokens={} tau={}",
                agg.usize_at("jobs").unwrap_or(0),
                agg.usize_at("jobs_ok").unwrap_or(0),
                agg.usize_at("jobs_err").unwrap_or(0),
                agg.usize_at("tokens").unwrap_or(0),
                agg.f64_at("tau").unwrap_or(0.0),
            );
        }
        sched.shutdown();

        let s = summarize(&lats);
        println!(
            "  completed: {}   tokens: {}   wall: {:.1}s   mean tau: {:.2}",
            lats.len(),
            total_tokens,
            wall,
            taus.iter().sum::<f64>() / taus.len().max(1) as f64
        );
        summary.push(format!(
            "workers={workers}: {:.1} tok/s  {:.2} req/s  lat p50={:.0}ms p90={:.0}ms p99={:.0}ms",
            total_tokens as f64 / wall,
            lats.len() as f64 / wall,
            s.p50,
            s.p90,
            s.p99,
        ));
    }

    println!("\n== aggregate throughput by pool size ==");
    for line in summary {
        println!("{line}");
    }
    Ok(())
}
