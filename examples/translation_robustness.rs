//! Table-11-style robustness check: HASS vs EAGLE-2 on the five
//! cipher-"language" translation suites (drafts trained only on dialogue).
//!
//! ```sh
//! cargo run --release --example translation_robustness
//! ```

use std::rc::Rc;

use hass::engine::{build_method, run_suite};
use hass::runtime::Runtime;
use hass::sampling::SampleParams;
use hass::spec::MethodCfg;
use hass::workload::{Workloads, TRANSLATION_SUITES};

fn main() -> anyhow::Result<()> {
    let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
    let wl = Workloads::load(&hass::artifact_dir())?;
    println!("{:<8} {}", "method", TRANSLATION_SUITES.join("   "));
    for method in ["eagle2", "hass"] {
        let mut m = build_method(&rt, method, &MethodCfg::default())?;
        print!("{method:<8}");
        for suite in TRANSLATION_SUITES {
            let prompts = wl.suite(suite)?[..4.min(wl.suite(suite)?.len())].to_vec();
            let r = run_suite(
                m.as_mut(), suite, &prompts, 40,
                &SampleParams { temperature: 0.0, ..Default::default() },
            )?;
            print!(" {:>5.2}", r.tau);
        }
        println!();
    }
    Ok(())
}
