//! End-to-end integration tests over the real artifacts + trained weights.
//!
//! These require `make artifacts` (and `make train` for draft methods);
//! they skip gracefully when artifacts are missing so `cargo test` stays
//! green on a fresh clone.

use std::rc::Rc;
use std::sync::Arc;

use hass::engine::{build_method, generate_once};
use hass::runtime::Runtime;
use hass::sampling::SampleParams;
use hass::spec::{GenRequest, MethodCfg};
use hass::tokenizer;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = hass::artifact_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping integration test: no artifacts (run `make artifacts`)");
        return None;
    }
    let rt = Rc::new(Runtime::new(&dir).expect("runtime"));
    // The vendored offline `xla` stand-in gates compile/execute, so graphs
    // may be un-runnable even with artifacts present.  Probe with a tiny
    // vanilla generation and skip (not fail) when the backend is absent.
    if dir.join("weights/target.json").exists() {
        let params = SampleParams { temperature: 0.0, ..Default::default() };
        if let Err(e) = generate_once(&rt, "vanilla", &MethodCfg::default(), "probe", 2, &params) {
            eprintln!("skipping integration test: backend cannot execute graphs ({e:#})");
            return None;
        }
    }
    Some(rt)
}

/// Artifact dir for serving tests: requires meta + hass weights + an
/// executable backend (same probe as `runtime`).
fn serving_dir() -> Option<std::path::PathBuf> {
    let dir = hass::artifact_dir();
    if !dir.join("weights/hass.json").exists() {
        return None;
    }
    runtime()?;
    Some(dir)
}

fn have(rt: &Rc<Runtime>, ckpt: &str) -> bool {
    rt.has_checkpoint(ckpt)
}

const PROMPT: &str = "User: Why is music theory interesting?\nAssistant:";

#[test]
fn greedy_matches_python_goldens() {
    let Some(rt) = runtime() else { return };
    let goldens = rt.meta().goldens.clone();
    if goldens.is_empty() {
        eprintln!("skipping: goldens not built (train target, re-run make artifacts)");
        return;
    }
    let mut m = build_method(&rt, "vanilla", &MethodCfg::default()).unwrap();
    for g in &goldens {
        let req = GenRequest {
            prompt_tokens: g.prompt_tokens.clone(),
            max_new: g.greedy_tokens.len(),
            params: SampleParams { temperature: 0.0, ..Default::default() },
        };
        let out = m.generate(&req).unwrap();
        assert_eq!(
            out.tokens,
            g.greedy_tokens[..out.tokens.len()].to_vec(),
            "rust greedy decode != python golden"
        );
    }
}

/// THE losslessness invariant: at T=0, every speculative method produces
/// exactly the vanilla greedy continuation.
#[test]
fn all_methods_lossless_at_t0() {
    let Some(rt) = runtime() else { return };
    let params = SampleParams { temperature: 0.0, ..Default::default() };
    let cfg = MethodCfg::default();
    let (want, _) = generate_once(&rt, "vanilla", &cfg, PROMPT, 40, &params).unwrap();
    for m in ["pld", "lookahead", "sps", "medusa", "eagle", "eagle2", "hass"] {
        let needs = match m {
            "sps" => "sps",
            "medusa" => "medusa",
            "eagle" | "eagle2" => "eagle",
            "hass" => "hass",
            _ => "target",
        };
        if !have(&rt, needs) {
            eprintln!("skipping {m}: checkpoint {needs} not trained");
            continue;
        }
        let (got, out) = generate_once(&rt, m, &cfg, PROMPT, 40, &params).unwrap();
        assert_eq!(got, want, "method {m} broke greedy losslessness");
        assert!(out.metrics.tau() >= 1.0, "{m}: tau < 1");
    }
}

/// Stochastic sampling must be reproducible per seed and vary across seeds.
#[test]
fn sampling_reproducible_per_seed() {
    let Some(rt) = runtime() else { return };
    let cfg = MethodCfg::default();
    if !have(&rt, "hass") {
        return;
    }
    let p1 = SampleParams { temperature: 1.0, seed: 7, ..Default::default() };
    let (a, _) = generate_once(&rt, "hass", &cfg, PROMPT, 32, &p1).unwrap();
    let (b, _) = generate_once(&rt, "hass", &cfg, PROMPT, 32, &p1).unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    let p2 = SampleParams { temperature: 1.0, seed: 8, ..Default::default() };
    let (c, _) = generate_once(&rt, "hass", &cfg, PROMPT, 32, &p2).unwrap();
    assert_ne!(a, c, "different seeds should differ (T=1)");
}

/// Stochastic losslessness: HASS output at T=1 must equal single-step
/// target sampling with the same RNG discipline?  RNG streams differ by
/// construction, so instead assert the *distributional* property on the
/// first emitted token over many seeds: speculative HASS and vanilla draw
/// from the same target distribution.
#[test]
fn first_token_distribution_matches_vanilla() {
    let Some(rt) = runtime() else { return };
    if !have(&rt, "hass") {
        return;
    }
    let cfg = MethodCfg::default();
    let mut counts_v = std::collections::HashMap::new();
    let mut counts_h = std::collections::HashMap::new();
    let n = 60usize;
    for seed in 0..n as u64 {
        let p = SampleParams { temperature: 1.0, seed, ..Default::default() };
        // second emitted token is the first speculative one
        let (_, ov) = generate_once(&rt, "vanilla", &cfg, PROMPT, 2, &p).unwrap();
        let (_, oh) = generate_once(&rt, "hass", &cfg, PROMPT, 2, &p).unwrap();
        *counts_v.entry(ov.tokens[1]).or_insert(0usize) += 1;
        *counts_h.entry(oh.tokens[1]).or_insert(0usize) += 1;
    }
    // total-variation distance between the two empirical distributions
    let keys: std::collections::HashSet<i32> =
        counts_v.keys().chain(counts_h.keys()).copied().collect();
    let tv: f64 = keys
        .iter()
        .map(|k| {
            let a = *counts_v.get(k).unwrap_or(&0) as f64 / n as f64;
            let b = *counts_h.get(k).unwrap_or(&0) as f64 / n as f64;
            (a - b).abs()
        })
        .sum::<f64>()
        / 2.0;
    assert!(tv < 0.35, "empirical TV distance too large: {tv}");
}

/// Speculative methods must beat vanilla on acceptance length.
#[test]
fn hass_tau_exceeds_eagle2_on_dialogue() {
    let Some(rt) = runtime() else { return };
    if !(have(&rt, "hass") && have(&rt, "eagle")) {
        return;
    }
    let cfg = MethodCfg::default();
    let params = SampleParams { temperature: 0.0, ..Default::default() };
    let mut tau = |m: &str| {
        let mut total = 0.0;
        for p in [PROMPT, "User: Can you tell me about the weather?\nAssistant:"] {
            total += generate_once(&rt, m, &cfg, p, 48, &params).unwrap().1.metrics.tau();
        }
        total / 2.0
    };
    let h = tau("hass");
    let e2 = tau("eagle2");
    assert!(h > 1.5, "hass tau too low: {h}");
    assert!(e2 > 1.2, "eagle2 tau too low: {e2}");
    // the paper's headline: HASS >= EAGLE-2 (allow tiny slack for noise)
    assert!(h >= e2 - 0.15, "hass ({h:.2}) below eagle2 ({e2:.2})");
}

/// Method instances are reusable across requests (session reset works).
#[test]
fn method_reuse_is_deterministic() {
    let Some(rt) = runtime() else { return };
    if !have(&rt, "hass") {
        return;
    }
    let mut m = build_method(&rt, "hass", &MethodCfg::default()).unwrap();
    let req = GenRequest {
        prompt_tokens: tokenizer::encode(PROMPT, true),
        max_new: 24,
        params: SampleParams { temperature: 0.0, ..Default::default() },
    };
    let a = m.generate(&req).unwrap();
    let b = m.generate(&req).unwrap();
    assert_eq!(a.tokens, b.tokens, "stateful session leaked across requests");
}

/// Prefill logits fingerprint vs python.
#[test]
fn prefill_logits_match_python_fingerprint() {
    let Some(rt) = runtime() else { return };
    let goldens = rt.meta().goldens.clone();
    if goldens.is_empty() {
        return;
    }
    use hass::engine::sessions::TargetSession;
    let tw = rt.checkpoint("target").unwrap();
    let mut sess = TargetSession::new(rt.clone(), tw).unwrap();
    for g in &goldens {
        let logits = sess.prefill(&g.prompt_tokens).unwrap();
        for (i, want) in g.prefill_logits8.iter().enumerate() {
            assert!(
                (logits[i] - want).abs() < 1e-3,
                "logit {i}: {} vs {}",
                logits[i],
                want
            );
        }
        sess.reset();
    }
}

/// End-to-end scheduler + TCP server round-trip.
#[test]
fn server_roundtrip() {
    let Some(dir) = serving_dir() else { return };
    let sched = Arc::new(hass::scheduler::Scheduler::start(
        dir,
        MethodCfg::default(),
        8,
        1,
        2,
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let s2 = sched.clone();
    std::thread::spawn(move || {
        let _ = hass::server::serve(listener, s2);
    });
    let mut c = hass::server::Client::connect(&addr.to_string()).unwrap();
    let resp = c.request("hass", PROMPT, 24, 0.0).unwrap();
    assert!(resp.get("error").is_none(), "server error: {resp:?}");
    assert!(resp.usize_at("tokens").unwrap_or(0) > 0);
    assert!(resp.f64_at("tau").unwrap_or(0.0) >= 1.0);
    assert!(!resp.str_at("text").unwrap_or("").is_empty());
}

/// Pool serving over TCP without artifacts: every job completes with an
/// error result (runtime init fails), responses pair 1:1 with requests
/// across concurrent connections, and the `{"stats": true}` aggregate
/// stays consistent.  Runs everywhere — no artifacts needed.
#[test]
fn pool_tcp_serves_and_reports_stats_without_artifacts() {
    let sched = Arc::new(hass::scheduler::Scheduler::start(
        std::path::PathBuf::from("/nonexistent/hass-artifacts"),
        MethodCfg::default(),
        16,
        2,
        1,
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let s2 = sched.clone();
    std::thread::spawn(move || {
        let _ = hass::server::serve(listener, s2);
    });
    let mut conns = Vec::new();
    for _ in 0..2 {
        let addr = addr.to_string();
        conns.push(std::thread::spawn(move || {
            let mut c = hass::server::Client::connect(&addr).unwrap();
            let mut ids = Vec::new();
            for _ in 0..4 {
                let resp = c.request("hass", PROMPT, 8, 0.0).unwrap();
                let err = resp.str_at("error").expect("no artifacts must yield an error");
                assert!(err.contains("runtime init failed"), "unexpected error: {err}");
                ids.push(resp.usize_at("id").unwrap());
            }
            ids
        }));
    }
    let mut all: Vec<usize> = conns.into_iter().flat_map(|h| h.join().unwrap()).collect();
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), 8, "each job must be answered exactly once");

    let mut c = hass::server::Client::connect(&addr.to_string()).unwrap();
    let stats = c.stats().unwrap();
    let stats = stats.get("stats").expect("stats envelope");
    let agg = stats.get("aggregate").unwrap();
    assert_eq!(agg.usize_at("workers"), Some(2));
    assert_eq!(agg.usize_at("jobs"), Some(8));
    assert_eq!(agg.usize_at("jobs_err"), Some(8));
    assert!(agg.f64_at("tau").unwrap().is_finite());
    let per_worker = stats.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(per_worker.len(), 2);
    let sum: usize = per_worker
        .iter()
        .map(|w| w.usize_at("jobs_ok").unwrap() + w.usize_at("jobs_err").unwrap())
        .sum();
    assert_eq!(sum, 8, "per-worker jobs must sum to the aggregate");
    sched.shutdown();
}

/// Acceptance test for the pool with real artifacts: ≥8 jobs over 2
/// connections against a 2-worker pool; every job must succeed, land on
/// one of the two engine threads, and the PoolStats aggregate must add
/// up.  Skips when artifacts are missing or the backend can't execute
/// graphs (like every artifact test).
#[test]
fn pool_roundtrip_with_artifacts() {
    let Some(dir) = serving_dir() else { return };
    let sched = Arc::new(hass::scheduler::Scheduler::start(
        dir,
        MethodCfg::default(),
        16,
        2,
        2,
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let s2 = sched.clone();
    std::thread::spawn(move || {
        let _ = hass::server::serve(listener, s2);
    });
    let mut conns = Vec::new();
    for _ in 0..2 {
        let addr = addr.to_string();
        conns.push(std::thread::spawn(move || {
            let mut c = hass::server::Client::connect(&addr).unwrap();
            let mut out = Vec::new();
            for _ in 0..4 {
                let resp = c.request("hass", PROMPT, 16, 0.0).unwrap();
                assert!(resp.get("error").is_none(), "server error: {resp:?}");
                assert!(resp.usize_at("tokens").unwrap_or(0) > 0);
                assert!(resp.f64_at("tau").unwrap_or(0.0) >= 1.0);
                out.push(resp.usize_at("worker").unwrap());
            }
            out
        }));
    }
    let workers: std::collections::HashSet<usize> =
        conns.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert!(
        !workers.is_empty() && workers.iter().all(|&w| w < 2),
        "jobs must land on pool workers"
    );
    assert_eq!(workers.len(), 2, "concurrent jobs must use distinct engine threads");

    let stats = sched.stats();
    assert_eq!(stats.workers.len(), 2);
    assert_eq!(stats.jobs(), 8);
    assert_eq!(stats.jobs_ok(), 8);
    assert!(stats.tokens() > 0);
    let tau = stats.tau();
    assert!(tau.is_finite() && tau >= 1.0, "merged pool tau: {tau}");
    sched.shutdown();
}

/// Pool-shape override for the CI matrix: `HASS_TEST_POOL_WORKERS` /
/// `HASS_TEST_POOL_MAX_ACTIVE` re-run the pool-shape-agnostic serving
/// tests with e.g. 2 workers x 4 sessions, so the fused verification
/// path is exercised end-to-end in CI (see .github/workflows/ci.yml).
fn env_pool(var: &str, default: usize) -> usize {
    std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Spawn a TCP server over a fresh pool (no artifacts needed for `mock`).
fn mock_server(workers: usize, max_active: usize) -> (Arc<hass::scheduler::Scheduler>, String) {
    let sched = Arc::new(hass::scheduler::Scheduler::start(
        std::path::PathBuf::from("/nonexistent/hass-artifacts"),
        MethodCfg::default(),
        16,
        env_pool("HASS_TEST_POOL_WORKERS", workers),
        env_pool("HASS_TEST_POOL_MAX_ACTIVE", max_active),
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let s2 = sched.clone();
    std::thread::spawn(move || {
        let _ = hass::server::serve(listener, s2);
    });
    (sched, addr)
}

/// End-to-end streaming over TCP: `{"stream": true}` must emit >= 2
/// delta lines before the final done line, the deltas must concatenate
/// to the final text, and a non-streamed request with the same seed must
/// produce the identical text.  Runs everywhere — `mock` needs no
/// artifacts.
#[test]
fn tcp_streaming_deltas_concatenate_to_text() {
    let (sched, addr) = mock_server(1, 2);
    let mut c = hass::server::Client::connect(&addr).unwrap();
    let mut deltas: Vec<String> = Vec::new();
    let opts = hass::server::ReqOpts {
        method: "mock".into(),
        max_tokens: 8,
        seed: 3,
        stream: true,
        ..Default::default()
    };
    let fin = c.generate("hello", &opts, |d| deltas.push(d.to_string())).unwrap();
    assert!(fin.get("error").is_none(), "stream failed: {fin:?}");
    assert!(deltas.len() >= 2, "want >= 2 delta lines, got {}", deltas.len());
    assert_eq!(fin.get("done").and_then(|v| v.as_bool()), Some(true));
    let text = fin.str_at("text").unwrap().to_string();
    assert_eq!(deltas.concat(), text, "deltas must concatenate to the final text");
    assert_eq!(fin.usize_at("tokens"), Some(8));

    // same seed without streaming -> same text, no delta callbacks
    let opts = hass::server::ReqOpts { stream: false, ..opts };
    let fin2 = c
        .generate("hello", &opts, |_| panic!("non-streamed request must not emit deltas"))
        .unwrap();
    assert_eq!(fin2.str_at("text"), Some(text.as_str()));
    assert!(fin2.get("done").is_none(), "legacy final line must not carry done");
    sched.shutdown();
}

/// Batched-verification equivalence over the seed artifacts: one worker
/// fusing 4 co-active `hass` sessions must produce token-for-token the
/// texts (and tau) of 4 sequential solo runs with the same seeds, with
/// >= 2x fewer verify executions (the fused/solo counters mirror the
/// runtime's target decode-block call counts).  Skips without artifacts
/// or an executable backend, like every artifact test.
#[test]
fn fused_pool_matches_sequential_with_artifacts() {
    let Some(dir) = serving_dir() else { return };
    let run_batch = |sched: &hass::scheduler::Scheduler, temperature: f32| {
        let rxs: Vec<_> = (0..4u64)
            .map(|i| {
                sched
                    .submit(
                        hass::scheduler::Job {
                            id: i + 1,
                            method: "hass".into(),
                            prompt: PROMPT.into(),
                            max_new: 24,
                            temperature,
                            seed: i,
                            stream: false,
                            deadline_ms: None,
                            priority: 0,
                        },
                        true,
                    )
                    .unwrap()
            })
            .collect();
        rxs.into_iter()
            .map(|rx| loop {
                match rx.recv().expect("scheduler dropped a job") {
                    hass::scheduler::JobEvent::Done(r) => {
                        assert!(r.error.is_none(), "job failed: {:?}", r.error);
                        break (r.text, r.tokens, r.tau);
                    }
                    hass::scheduler::JobEvent::Delta { .. } => {}
                }
            })
            .collect::<Vec<_>>()
    };

    // ---- equivalence: stochastic jobs, per-seed streams must match ----
    let solo = hass::scheduler::Scheduler::start(dir.clone(), MethodCfg::default(), 16, 1, 1);
    let want = run_batch(&solo, 1.0);
    solo.shutdown();
    let fused = hass::scheduler::Scheduler::start(dir.clone(), MethodCfg::default(), 16, 1, 4);
    let got = run_batch(&fused, 1.0);
    let eq_stats = fused.stats();
    fused.shutdown();
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        assert_eq!(g.0, w.0, "job {i}: fused text diverged from sequential solo");
        assert_eq!(g.1, w.1, "job {i}: token count diverged");
        assert!((g.2 - w.2).abs() < 1e-9, "job {i}: tau diverged");
    }
    assert!(eq_stats.fused_calls() > 0, "fused path must be exercised");
    // draft-side batching (PR 5): co-active EAGLE-family sessions must
    // fuse their tree levels too — compiled fused draft calls carrying
    // multiple sessions' rows, with draft pages staged like target pages
    assert!(eq_stats.draft_fused_calls() > 0, "fused draft path must be exercised");
    assert!(
        eq_stats.mean_draft_fused_rows() > 1.5,
        "fused draft calls must carry multiple sessions' rows (mean {})",
        eq_stats.mean_draft_fused_rows()
    );
    assert!(
        eq_stats.draft_pack_pages_copied() > 0,
        "fused draft packs must stage draft pages"
    );
    // paged KV: fused packs copy pages, and with stable co-active
    // membership the staging cache reuses unchanged prefix pages across
    // cycles (pack cost O(changed pages), not O(context))
    assert!(eq_stats.pack_pages_copied() > 0, "fused packs must stage pages");
    assert!(
        eq_stats.pack_pages_reused() > 0,
        "steady-state packs must reuse staged prefix pages \
         (copied {}, reused {})",
        eq_stats.pack_pages_copied(),
        eq_stats.pack_pages_reused()
    );
    // identical prompts across the 4 jobs -> dedup'd prompt pages are
    // shared inside the fused image
    assert!(
        eq_stats.shared_pages() > 0,
        "identical prompts must share physical pages in the fused pack"
    );

    // ---- call reduction: equal-length greedy jobs run in lockstep, so
    // the fused pool must issue >= 2x fewer verify executions (each
    // execution is one target decode-block graph call) ----
    let solo = hass::scheduler::Scheduler::start(dir.clone(), MethodCfg::default(), 16, 1, 1);
    run_batch(&solo, 0.0);
    let solo_stats = solo.stats();
    solo.shutdown();
    let fused = hass::scheduler::Scheduler::start(dir, MethodCfg::default(), 16, 1, 4);
    run_batch(&fused, 0.0);
    let fused_stats = fused.stats();
    fused.shutdown();
    assert!(
        fused_stats.verify_calls() * 2 <= solo_stats.verify_calls(),
        "expected >= 2x fewer target verify calls: fused {} vs solo {}",
        fused_stats.verify_calls(),
        solo_stats.verify_calls()
    );
    // ... and the draft side must batch at least as hard: per-group draft
    // calls per cycle drop from N*depth to ~depth
    assert!(
        fused_stats.draft_execs() * 2 <= solo_stats.draft_execs(),
        "expected >= 2x fewer draft executions: fused {} vs solo {}",
        fused_stats.draft_execs(),
        solo_stats.draft_execs()
    );
}

/// End-to-end cancellation over TCP: cancel a streaming job mid-flight
/// (the job id comes from its first delta line); the job's final line
/// must be a done-tagged error mentioning the cancel, and the connection
/// must stay usable for a follow-up request.
#[test]
fn tcp_cancel_aborts_streaming_job() {
    use std::io::{BufRead, BufReader, Write};

    // throttle steps so the job is reliably still running when the cancel
    // lands (the env knob is read once at pool start; the brief window
    // only slows, never breaks, concurrently starting pools)
    std::env::set_var("HASS_TEST_JOB_DELAY_MS", "2");
    let (sched, addr) = mock_server(1, 1);
    std::env::remove_var("HASS_TEST_JOB_DELAY_MS");

    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream.try_clone().unwrap();
    w.write_all(b"{\"prompt\": \"long job\", \"method\": \"mock\", \"max_tokens\": 5000, \"stream\": true}\n")
        .unwrap();

    // first delta line carries the job id
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let first = hass::util::json::parse(line.trim()).unwrap();
    assert!(first.str_at("delta").is_some(), "expected a delta line, got: {line}");
    let id = first.usize_at("id").expect("delta line carries the job id");
    w.write_all(format!("{{\"cancel\": {id}}}\n").as_bytes()).unwrap();

    // drain remaining deltas until the terminal line
    let fin = loop {
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "connection closed early");
        let j = hass::util::json::parse(line.trim()).unwrap();
        if j.str_at("delta").is_none() {
            break j;
        }
    };
    let err = fin.str_at("error").expect("cancelled job must report an error");
    assert!(err.contains("cancel"), "unexpected error: {err}");
    assert_eq!(fin.get("done").and_then(|v| v.as_bool()), Some(true));

    // the worker survives: a fresh request on the same connection succeeds
    w.write_all(b"{\"prompt\": \"after\", \"method\": \"mock\", \"max_tokens\": 3}\n").unwrap();
    let after = loop {
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "connection closed early");
        let j = hass::util::json::parse(line.trim()).unwrap();
        if j.str_at("delta").is_none() {
            break j;
        }
    };
    assert!(after.get("error").is_none(), "follow-up failed: {after:?}");
    assert_eq!(after.usize_at("tokens"), Some(3));
    sched.shutdown();
}

/// End-to-end overload shedding over TCP: a pool whose page gauge sits
/// past the admission high-water mark answers a generate request with
/// the explicit `{"error":"overloaded","retry_after_ms":N}` wire shape
/// (never a hang), the stats wire reports the shed and the exhausted
/// budget, and the SAME client's retry succeeds once pressure clears —
/// the documented client protocol.  Runs everywhere — `mock` needs no
/// artifacts.
#[test]
fn overload_admission_reject_then_client_retry_succeeds() {
    use std::sync::atomic::{AtomicU64, Ordering};

    let gauge = Arc::new(AtomicU64::new(1000));
    let policy = hass::scheduler::OverloadPolicy {
        page_budget: Some(100),
        retry_after_ms: 55,
        gauge: Some(gauge.clone()),
        ..Default::default()
    };
    let sched = Arc::new(hass::scheduler::Scheduler::start_with_policy(
        std::path::PathBuf::from("/nonexistent/hass-artifacts"),
        MethodCfg::default(),
        16,
        1,
        1,
        true,
        policy,
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let s2 = sched.clone();
    std::thread::spawn(move || {
        let _ = hass::server::serve(listener, s2);
    });

    let mut c = hass::server::Client::connect(&addr).unwrap();
    let opts =
        hass::server::ReqOpts { method: "mock".into(), max_tokens: 6, ..Default::default() };
    let rej = c.generate("hello", &opts, |_| panic!("shed request must not stream")).unwrap();
    assert_eq!(rej.str_at("error"), Some("overloaded"), "unexpected response: {rej:?}");
    assert_eq!(rej.usize_at("retry_after_ms"), Some(55), "retry hint missing: {rej:?}");

    let stats = c.stats().unwrap();
    let agg = stats.get("stats").expect("stats envelope").get("aggregate").unwrap();
    assert!(agg.usize_at("admission_rejects").unwrap_or(0) >= 1, "stats: {stats:?}");
    assert_eq!(agg.usize_at("page_budget"), Some(100));
    assert_eq!(agg.usize_at("free_pages"), Some(0));

    // pressure clears: the retry the hint asked for now succeeds
    gauge.store(0, Ordering::Relaxed);
    let ok = c.generate("hello", &opts, |_| {}).unwrap();
    assert!(ok.get("error").is_none(), "retry failed: {ok:?}");
    assert_eq!(ok.usize_at("tokens"), Some(6));
    sched.shutdown();
}
