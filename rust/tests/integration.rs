//! End-to-end integration tests over the real artifacts + trained weights.
//!
//! These require `make artifacts` (and `make train` for draft methods);
//! they skip gracefully when artifacts are missing so `cargo test` stays
//! green on a fresh clone.

use std::rc::Rc;
use std::sync::Arc;

use hass::engine::{build_method, generate_once};
use hass::runtime::Runtime;
use hass::sampling::SampleParams;
use hass::spec::{GenRequest, MethodCfg};
use hass::tokenizer;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = hass::artifact_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping integration test: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(Runtime::new(&dir).expect("runtime")))
}

fn have(rt: &Rc<Runtime>, ckpt: &str) -> bool {
    rt.has_checkpoint(ckpt)
}

const PROMPT: &str = "User: Why is music theory interesting?\nAssistant:";

#[test]
fn greedy_matches_python_goldens() {
    let Some(rt) = runtime() else { return };
    let goldens = rt.meta().goldens.clone();
    if goldens.is_empty() {
        eprintln!("skipping: goldens not built (train target, re-run make artifacts)");
        return;
    }
    let mut m = build_method(&rt, "vanilla", &MethodCfg::default()).unwrap();
    for g in &goldens {
        let req = GenRequest {
            prompt_tokens: g.prompt_tokens.clone(),
            max_new: g.greedy_tokens.len(),
            params: SampleParams { temperature: 0.0, ..Default::default() },
        };
        let out = m.generate(&req).unwrap();
        assert_eq!(
            out.tokens,
            g.greedy_tokens[..out.tokens.len()].to_vec(),
            "rust greedy decode != python golden"
        );
    }
}

/// THE losslessness invariant: at T=0, every speculative method produces
/// exactly the vanilla greedy continuation.
#[test]
fn all_methods_lossless_at_t0() {
    let Some(rt) = runtime() else { return };
    let params = SampleParams { temperature: 0.0, ..Default::default() };
    let cfg = MethodCfg::default();
    let (want, _) = generate_once(&rt, "vanilla", &cfg, PROMPT, 40, &params).unwrap();
    for m in ["pld", "lookahead", "sps", "medusa", "eagle", "eagle2", "hass"] {
        let needs = match m {
            "sps" => "sps",
            "medusa" => "medusa",
            "eagle" | "eagle2" => "eagle",
            "hass" => "hass",
            _ => "target",
        };
        if !have(&rt, needs) {
            eprintln!("skipping {m}: checkpoint {needs} not trained");
            continue;
        }
        let (got, out) = generate_once(&rt, m, &cfg, PROMPT, 40, &params).unwrap();
        assert_eq!(got, want, "method {m} broke greedy losslessness");
        assert!(out.metrics.tau() >= 1.0, "{m}: tau < 1");
    }
}

/// Stochastic sampling must be reproducible per seed and vary across seeds.
#[test]
fn sampling_reproducible_per_seed() {
    let Some(rt) = runtime() else { return };
    let cfg = MethodCfg::default();
    if !have(&rt, "hass") {
        return;
    }
    let p1 = SampleParams { temperature: 1.0, seed: 7, ..Default::default() };
    let (a, _) = generate_once(&rt, "hass", &cfg, PROMPT, 32, &p1).unwrap();
    let (b, _) = generate_once(&rt, "hass", &cfg, PROMPT, 32, &p1).unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    let p2 = SampleParams { temperature: 1.0, seed: 8, ..Default::default() };
    let (c, _) = generate_once(&rt, "hass", &cfg, PROMPT, 32, &p2).unwrap();
    assert_ne!(a, c, "different seeds should differ (T=1)");
}

/// Stochastic losslessness: HASS output at T=1 must equal single-step
/// target sampling with the same RNG discipline?  RNG streams differ by
/// construction, so instead assert the *distributional* property on the
/// first emitted token over many seeds: speculative HASS and vanilla draw
/// from the same target distribution.
#[test]
fn first_token_distribution_matches_vanilla() {
    let Some(rt) = runtime() else { return };
    if !have(&rt, "hass") {
        return;
    }
    let cfg = MethodCfg::default();
    let mut counts_v = std::collections::HashMap::new();
    let mut counts_h = std::collections::HashMap::new();
    let n = 60usize;
    for seed in 0..n as u64 {
        let p = SampleParams { temperature: 1.0, seed, ..Default::default() };
        // second emitted token is the first speculative one
        let (_, ov) = generate_once(&rt, "vanilla", &cfg, PROMPT, 2, &p).unwrap();
        let (_, oh) = generate_once(&rt, "hass", &cfg, PROMPT, 2, &p).unwrap();
        *counts_v.entry(ov.tokens[1]).or_insert(0usize) += 1;
        *counts_h.entry(oh.tokens[1]).or_insert(0usize) += 1;
    }
    // total-variation distance between the two empirical distributions
    let keys: std::collections::HashSet<i32> =
        counts_v.keys().chain(counts_h.keys()).copied().collect();
    let tv: f64 = keys
        .iter()
        .map(|k| {
            let a = *counts_v.get(k).unwrap_or(&0) as f64 / n as f64;
            let b = *counts_h.get(k).unwrap_or(&0) as f64 / n as f64;
            (a - b).abs()
        })
        .sum::<f64>()
        / 2.0;
    assert!(tv < 0.35, "empirical TV distance too large: {tv}");
}

/// Speculative methods must beat vanilla on acceptance length.
#[test]
fn hass_tau_exceeds_eagle2_on_dialogue() {
    let Some(rt) = runtime() else { return };
    if !(have(&rt, "hass") && have(&rt, "eagle")) {
        return;
    }
    let cfg = MethodCfg::default();
    let params = SampleParams { temperature: 0.0, ..Default::default() };
    let mut tau = |m: &str| {
        let mut total = 0.0;
        for p in [PROMPT, "User: Can you tell me about the weather?\nAssistant:"] {
            total += generate_once(&rt, m, &cfg, p, 48, &params).unwrap().1.metrics.tau();
        }
        total / 2.0
    };
    let h = tau("hass");
    let e2 = tau("eagle2");
    assert!(h > 1.5, "hass tau too low: {h}");
    assert!(e2 > 1.2, "eagle2 tau too low: {e2}");
    // the paper's headline: HASS >= EAGLE-2 (allow tiny slack for noise)
    assert!(h >= e2 - 0.15, "hass ({h:.2}) below eagle2 ({e2:.2})");
}

/// Method instances are reusable across requests (session reset works).
#[test]
fn method_reuse_is_deterministic() {
    let Some(rt) = runtime() else { return };
    if !have(&rt, "hass") {
        return;
    }
    let mut m = build_method(&rt, "hass", &MethodCfg::default()).unwrap();
    let req = GenRequest {
        prompt_tokens: tokenizer::encode(PROMPT, true),
        max_new: 24,
        params: SampleParams { temperature: 0.0, ..Default::default() },
    };
    let a = m.generate(&req).unwrap();
    let b = m.generate(&req).unwrap();
    assert_eq!(a.tokens, b.tokens, "stateful session leaked across requests");
}

/// Prefill logits fingerprint vs python.
#[test]
fn prefill_logits_match_python_fingerprint() {
    let Some(rt) = runtime() else { return };
    let goldens = rt.meta().goldens.clone();
    if goldens.is_empty() {
        return;
    }
    use hass::engine::sessions::TargetSession;
    let tw = rt.checkpoint("target").unwrap();
    let mut sess = TargetSession::new(rt.clone(), tw).unwrap();
    for g in &goldens {
        let logits = sess.prefill(&g.prompt_tokens).unwrap();
        for (i, want) in g.prefill_logits8.iter().enumerate() {
            assert!(
                (logits[i] - want).abs() < 1e-3,
                "logit {i}: {} vs {}",
                logits[i],
                want
            );
        }
        sess.reset();
    }
}

/// End-to-end scheduler + TCP server round-trip.
#[test]
fn server_roundtrip() {
    let dir = hass::artifact_dir();
    if !dir.join("meta.json").exists() || !dir.join("weights/hass.json").exists() {
        return;
    }
    let sched = Arc::new(hass::scheduler::Scheduler::start(
        dir,
        MethodCfg::default(),
        8,
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let s2 = sched.clone();
    std::thread::spawn(move || {
        let _ = hass::server::serve(listener, s2);
    });
    let mut c = hass::server::Client::connect(&addr.to_string()).unwrap();
    let resp = c.request("hass", PROMPT, 24, 0.0).unwrap();
    assert!(resp.get("error").is_none(), "server error: {resp:?}");
    assert!(resp.usize_at("tokens").unwrap_or(0) > 0);
    assert!(resp.f64_at("tau").unwrap_or(0.0) >= 1.0);
    assert!(!resp.str_at("text").unwrap_or("").is_empty());
}
