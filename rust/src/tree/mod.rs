//! Draft-tree substrate: node store, EAGLE-2 dynamic selection/reranking,
//! static tree templates (EAGLE-1, Medusa), BFS flattening and ancestor
//! mask packing for tree verification.
//!
//! Scores are cumulative log-probabilities under the draft distribution,
//! which are monotone non-increasing along any root→leaf path — that is
//! what makes top-M reranking ancestor-closed (Li et al. 2024c, EAGLE-2).



#[derive(Clone, Debug)]
pub struct Node {
    pub token: i32,
    pub parent: Option<usize>,
    pub depth: usize,
    /// cumulative draft log-prob along the path (root = 0.0)
    pub score: f32,
    /// draft probability of this token given its parent (for diagnostics)
    pub prob: f32,
    /// slot in the draft KV cache if this node was fed through the draft
    /// model during expansion (interior node), else None (leaf candidate)
    pub draft_slot: Option<usize>,
}

#[derive(Clone, Debug, Default)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

/// Flattened, ancestor-closed verification block.
#[derive(Clone, Debug)]
pub struct VerifyPlan {
    /// tree-node index per block row (row 0 = root), BFS order
    pub order: Vec<usize>,
    pub tokens: Vec<i32>,
    /// depth of each row below the root (root = 0)
    pub depths: Vec<usize>,
    /// block-row index of each row's parent (root -> None)
    pub parent_row: Vec<Option<usize>>,
    /// children rows of each row, in score order (best first)
    pub children_rows: Vec<Vec<usize>>,
}

impl Tree {
    pub fn new(root_token: i32) -> Tree {
        Tree {
            nodes: vec![Node {
                token: root_token,
                parent: None,
                depth: 0,
                score: 0.0,
                prob: 1.0,
                draft_slot: None,
            }],
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a scored child candidate; returns its index.
    pub fn add_child(&mut self, parent: usize, token: i32, logprob: f32) -> usize {
        debug_assert!(parent < self.nodes.len());
        let node = Node {
            token,
            parent: Some(parent),
            depth: self.nodes[parent].depth + 1,
            score: self.nodes[parent].score + logprob,
            prob: logprob.exp(),
            draft_slot: None,
        };
        self.nodes.push(node);
        self.nodes.len() - 1
    }

    pub fn ancestors(&self, mut idx: usize) -> Vec<usize> {
        let mut out = vec![idx];
        while let Some(p) = self.nodes[idx].parent {
            out.push(p);
            idx = p;
        }
        out.reverse();
        out
    }

    /// EAGLE-2 level selection: among `candidates`, keep the `beam` highest
    /// cumulative scores (these get expanded through the draft model).
    pub fn select_beam(&self, candidates: &[usize], beam: usize) -> Vec<usize> {
        let mut sorted = candidates.to_vec();
        sorted.sort_by(|&a, &b| {
            self.nodes[b]
                .score
                .partial_cmp(&self.nodes[a].score)
                .unwrap()
                .then(a.cmp(&b)) // stable tie-break: earlier node wins
        });
        sorted.truncate(beam);
        sorted
    }

    /// EAGLE-2 reranking: keep the root plus the `total` highest-scoring
    /// non-root nodes, then flatten BFS.  Ancestor closure is enforced
    /// explicitly (score ties could otherwise orphan a node).
    pub fn rerank(&self, total: usize) -> VerifyPlan {
        let mut idx: Vec<usize> = (1..self.nodes.len()).collect();
        idx.sort_by(|&a, &b| {
            self.nodes[b]
                .score
                .partial_cmp(&self.nodes[a].score)
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut keep = vec![false; self.nodes.len()];
        keep[0] = true;
        let mut kept = 0;
        for &i in &idx {
            if kept >= total {
                break;
            }
            if !keep[i] {
                // keep the whole path (parents are usually already kept)
                for &a in self.ancestors(i).iter() {
                    if !keep[a] {
                        keep[a] = true;
                        if a != 0 {
                            kept += 1;
                        }
                    }
                }
            }
        }
        self.flatten(&keep)
    }

    /// Flatten all kept nodes in BFS order (parents before children,
    /// siblings by score).
    fn flatten(&self, keep: &[bool]) -> VerifyPlan {
        let mut order: Vec<usize> = (0..self.nodes.len()).filter(|&i| keep[i]).collect();
        order.sort_by(|&a, &b| {
            self.nodes[a]
                .depth
                .cmp(&self.nodes[b].depth)
                .then(
                    self.nodes[b]
                        .score
                        .partial_cmp(&self.nodes[a].score)
                        .unwrap(),
                )
                .then(a.cmp(&b))
        });
        let row_of: std::collections::HashMap<usize, usize> =
            order.iter().enumerate().map(|(r, &i)| (i, r)).collect();
        let tokens = order.iter().map(|&i| self.nodes[i].token).collect();
        let depths = order.iter().map(|&i| self.nodes[i].depth).collect();
        let parent_row: Vec<Option<usize>> = order
            .iter()
            .map(|&i| self.nodes[i].parent.and_then(|p| row_of.get(&p).copied()))
            .collect();
        let mut children_rows = vec![Vec::new(); order.len()];
        for (r, &pr) in parent_row.iter().enumerate() {
            if let Some(p) = pr {
                children_rows[p].push(r);
            }
        }
        // children already in score order because rows are score-sorted
        VerifyPlan { order, tokens, depths, parent_row, children_rows }
    }

    /// Flatten the entire tree (static templates skip reranking).
    pub fn flatten_all(&self) -> VerifyPlan {
        self.flatten(&vec![true; self.nodes.len()])
    }
}

impl VerifyPlan {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Ancestor-relation bitmask within the block: `mask[a][b]` == row a may
    /// attend to row b (b is a or an ancestor of a).
    pub fn block_mask(&self) -> Vec<Vec<bool>> {
        let n = self.len();
        let mut mask = vec![vec![false; n]; n];
        for a in 0..n {
            let mut cur = Some(a);
            while let Some(c) = cur {
                mask[a][c] = true;
                cur = self.parent_row[c];
            }
        }
        mask
    }

    /// Rows of the path from the root to `row` (inclusive), root first.
    pub fn path_rows(&self, row: usize) -> Vec<usize> {
        let mut out = vec![row];
        let mut cur = row;
        while let Some(p) = self.parent_row[cur] {
            out.push(p);
            cur = p;
        }
        out.reverse();
        out
    }
}

// ---------------------------------------------------------------------------
// static templates
// ---------------------------------------------------------------------------

/// EAGLE-1 style static tree: paths expressed as child-rank sequences.
/// Tuned to ~26 nodes / depth 5 like the paper's fixed tree.
pub fn eagle_static_template() -> Vec<Vec<usize>> {
    vec![
        vec![0], vec![1], vec![2], vec![3],
        vec![0, 0], vec![0, 1], vec![0, 2], vec![1, 0], vec![1, 1], vec![2, 0],
        vec![0, 0, 0], vec![0, 0, 1], vec![0, 1, 0], vec![1, 0, 0], vec![2, 0, 0],
        vec![0, 0, 0, 0], vec![0, 0, 0, 1], vec![0, 0, 1, 0], vec![0, 1, 0, 0],
        vec![0, 0, 0, 0, 0], vec![0, 0, 0, 0, 1], vec![0, 0, 0, 1, 0],
        vec![0, 0, 1, 0, 0], vec![0, 0, 0, 0, 0, 0], vec![0, 0, 0, 0, 0, 1],
    ]
}

/// Medusa sparse tree over per-head top-k ranks (head d supplies depth d+1).
pub fn medusa_template() -> Vec<Vec<usize>> {
    vec![
        vec![0], vec![1], vec![2], vec![3], vec![4],
        vec![0, 0], vec![0, 1], vec![0, 2], vec![1, 0], vec![1, 1], vec![2, 0],
        vec![0, 0, 0], vec![0, 0, 1], vec![0, 1, 0], vec![1, 0, 0],
        vec![0, 0, 0, 0], vec![0, 0, 0, 1], vec![0, 0, 1, 0],
    ]
}

/// Max depth of a rank-path template.
pub fn template_depth(t: &[Vec<usize>]) -> usize {
    t.iter().map(|p| p.len()).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_tree(r: &mut Rng, max_nodes: usize) -> Tree {
        let mut t = Tree::new(5);
        let n = 1 + r.gen_range(max_nodes);
        for _ in 0..n {
            let parent = r.gen_range(t.len());
            let lp = -(r.next_f32() * 3.0 + 0.01);
            t.add_child(parent, r.gen_range(100) as i32, lp);
        }
        t
    }

    #[test]
    fn scores_monotone_along_paths() {
        let mut r = Rng::new(3);
        let t = random_tree(&mut r, 60);
        for i in 1..t.len() {
            let p = t.nodes[i].parent.unwrap();
            assert!(t.nodes[i].score <= t.nodes[p].score + 1e-6);
        }
    }

    #[test]
    fn select_beam_orders_by_score() {
        let mut t = Tree::new(1);
        let a = t.add_child(0, 10, -0.1);
        let b = t.add_child(0, 11, -2.0);
        let c = t.add_child(0, 12, -0.5);
        let sel = t.select_beam(&[a, b, c], 2);
        assert_eq!(sel, vec![a, c]);
    }

    #[test]
    fn rerank_keeps_best_and_closure() {
        let mut t = Tree::new(1);
        let a = t.add_child(0, 10, -0.1); // best child
        let _b = t.add_child(0, 11, -5.0); // bad child
        let aa = t.add_child(a, 12, -0.1); // grandchild, score -0.2
        let plan = t.rerank(2);
        // kept: root + {a, aa} (scores -0.1, -0.2 beat -5.0)
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.tokens, vec![1, 10, 12]);
        assert_eq!(plan.parent_row, vec![None, Some(0), Some(1)]);
        let _ = aa;
    }

    #[test]
    fn bfs_parents_before_children() {
        let mut r = Rng::new(17);
        let t = random_tree(&mut r, 80);
        let plan = t.rerank(40);
        for (row, pr) in plan.parent_row.iter().enumerate() {
            if let Some(p) = pr {
                assert!(*p < row, "parent row after child");
            }
        }
    }

    #[test]
    fn block_mask_matches_bruteforce_paths() {
        prop::check(
            "block mask == ancestor relation",
            |r| random_tree(r, 50),
            |t| {
                let plan = t.rerank(30);
                let mask = plan.block_mask();
                for a in 0..plan.len() {
                    let path: std::collections::HashSet<usize> =
                        plan.path_rows(a).into_iter().collect();
                    for b in 0..plan.len() {
                        let want = path.contains(&b);
                        if mask[a][b] != want {
                            return Err(format!("mask[{a}][{b}]={} want {want}", mask[a][b]));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rerank_is_ancestor_closed_property() {
        prop::check(
            "rerank keeps parents of kept nodes",
            |r| (random_tree(r, 70), 1 + r.gen_range(40)),
            |(t, total)| {
                let plan = t.rerank(*total);
                // every row's parent node must also be a row
                for (row, &node) in plan.order.iter().enumerate() {
                    if let Some(pnode) = t.nodes[node].parent {
                        if !plan.order.contains(&pnode) {
                            return Err(format!("row {row}: parent node missing"));
                        }
                    }
                }
                if plan.len() > total + 1 {
                    return Err(format!("kept {} > total {}+1", plan.len(), total));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rerank_keeps_highest_scores_modulo_closure() {
        let mut r = Rng::new(23);
        let t = random_tree(&mut r, 60);
        let total = 10;
        let plan = t.rerank(total);
        // min kept non-root score >= max dropped *leaf-reachable* score is
        // not guaranteed in general, but every kept node must beat or tie
        // the worst kept node on its own path — sanity: no kept node has a
        // better excluded sibling.
        let kept: std::collections::HashSet<usize> = plan.order.iter().copied().collect();
        let min_kept = plan
            .order
            .iter()
            .filter(|&&i| i != 0)
            .map(|&i| t.nodes[i].score)
            .fold(f32::INFINITY, f32::min);
        for i in 1..t.len() {
            if !kept.contains(&i) {
                // an excluded node with score strictly above the min kept
                // score would indicate a broken rerank
                assert!(
                    t.nodes[i].score <= min_kept + 1e-5,
                    "excluded node {i} scores above kept set"
                );
            }
        }
    }

    #[test]
    fn templates_are_prefix_closed() {
        for tmpl in [eagle_static_template(), medusa_template()] {
            for path in &tmpl {
                for cut in 1..path.len() {
                    assert!(
                        tmpl.contains(&path[..cut].to_vec()),
                        "template missing prefix {:?}",
                        &path[..cut]
                    );
                }
            }
        }
    }

    #[test]
    fn template_depths() {
        assert_eq!(template_depth(&eagle_static_template()), 6);
        assert_eq!(template_depth(&medusa_template()), 4);
    }
}
