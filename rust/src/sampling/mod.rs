//! Sampling + lossless verification primitives.
//!
//! * softmax / temperature / top-k / top-p transforms;
//! * `sample_token` — one draw from a processed distribution;
//! * `verify_chain` — canonical Leviathan/Chen speculative rejection
//!   sampling for *chain* drafts whose tokens were sampled from the draft
//!   distribution (vanilla SpS): accept token x with prob min(1, p(x)/q(x)),
//!   on rejection re-sample from norm(relu(p − q)).  Lossless for q-sampled
//!   proposals (statistically tested).
//! * `accept_at_node` — tree verification via sample-then-match: draw
//!   x ~ p_target at the node; if x equals one of the node's (deterministic,
//!   confidence-ranked) children, descend; otherwise emit x as the bonus
//!   token.  The output is *always* an exact sample from the target
//!   distribution, so tree methods (Medusa/EAGLE/EAGLE-2/HASS) are lossless
//!   for any proposal set — including EAGLE-2's deterministic top-k trees
//!   (DESIGN.md §6; at T=0 this reduces to argmax matching, identical to
//!   the paper's greedy acceptance).

use crate::util::rng::Rng;

/// Sampling parameters for a generation request.
#[derive(Clone, Copy, Debug)]
pub struct SampleParams {
    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub seed: u64,
}

impl Default for SampleParams {
    fn default() -> Self {
        SampleParams { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

impl SampleParams {
    pub fn greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Index of the largest finite value; NaN entries never win (a NaN at
/// index 0 used to win by default because every `>` against it is false).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best = i;
            best_v = x;
        }
    }
    best
}

/// logits -> probabilities (in place), applying temperature / top-k / top-p.
/// Greedy (T<=0) produces a one-hot at the argmax.
pub fn process_logits(logits: &[f32], p: &SampleParams) -> Vec<f32> {
    let v = logits.len();
    if p.greedy() {
        let mut out = vec![0.0; v];
        out[argmax(logits)] = 1.0;
        return out;
    }
    let inv_t = 1.0 / p.temperature;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    // mask non-finite weights to 0 up front: a NaN logit must never reach
    // the top-p cumulative sum or normalize() (NaN total would silently
    // flatten the whole distribution to uniform)
    let mut probs: Vec<f32> = logits
        .iter()
        .map(|&l| {
            let e = ((l - mx) * inv_t).exp();
            if e.is_finite() {
                e
            } else {
                0.0
            }
        })
        .collect();

    if p.top_k > 0 && p.top_k < v {
        let idx = sort_desc_indices(&probs);
        for &i in &idx[p.top_k..] {
            probs[i] = 0.0;
        }
    }
    if p.top_p < 1.0 {
        let idx = sort_desc_indices(&probs);
        let total: f32 = probs.iter().sum();
        let mut cum = 0.0;
        for &i in &idx {
            if cum >= p.top_p * total {
                probs[i] = 0.0;
            }
            cum += probs[i];
        }
    }
    normalize(&mut probs);
    probs
}

pub fn normalize(probs: &mut [f32]) {
    let total: f32 = probs.iter().sum();
    if total > 0.0 {
        for x in probs.iter_mut() {
            *x /= total;
        }
    } else if !probs.is_empty() {
        let u = 1.0 / probs.len() as f32;
        for x in probs.iter_mut() {
            *x = u;
        }
    }
}

pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut e: Vec<f32> = logits.iter().map(|&l| (l - mx).exp()).collect();
    normalize(&mut e);
    e
}

pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&l| (l - mx).exp()).sum::<f32>().ln() + mx;
    logits.iter().map(|&l| l - lse).collect()
}

/// Indices of `xs` sorted by value descending.  NaN entries sort last:
/// the old `partial_cmp(..).unwrap()` aborted the engine thread whenever
/// a logit was NaN (satellite regression fix).
fn sort_desc_indices(xs: &[f32]) -> Vec<usize> {
    let key = |i: usize| {
        let x = xs[i];
        if x.is_nan() {
            f32::NEG_INFINITY
        } else {
            x
        }
    };
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_unstable_by(|&a, &b| key(b).total_cmp(&key(a)));
    idx
}

/// Top-k (value, index) pairs, descending; NaN-safe (NaN ranks last).
pub fn topk(xs: &[f32], k: usize) -> Vec<(f32, usize)> {
    sort_desc_indices(xs).into_iter().take(k).map(|i| (xs[i], i)).collect()
}

pub fn sample_token(probs: &[f32], rng: &mut Rng) -> usize {
    rng.sample_weighted(probs)
}

/// Result of verifying a chain of draft tokens.
#[derive(Clone, Debug)]
pub struct ChainVerdict {
    /// number of draft tokens accepted (prefix length)
    pub accepted: usize,
    /// the token sampled after the accepted prefix (bonus / correction)
    pub bonus: i32,
}

/// Canonical speculative rejection sampling over a drafted chain.
///
/// `draft_tokens[i]` was sampled from `draft_probs[i]` (full distribution);
/// `target_probs[i]` is the target's (already temperature/top-p processed)
/// distribution at the same position; `target_probs[len]` is the target
/// distribution *after* the full chain (for the bonus when all accepted).
pub fn verify_chain(
    draft_tokens: &[i32],
    draft_probs: &[Vec<f32>],
    target_probs: &[Vec<f32>],
    rng: &mut Rng,
) -> ChainVerdict {
    debug_assert_eq!(draft_tokens.len(), draft_probs.len());
    debug_assert!(target_probs.len() >= draft_tokens.len() + 1);
    for i in 0..draft_tokens.len() {
        let x = draft_tokens[i] as usize;
        let p = target_probs[i][x];
        let q = draft_probs[i][x].max(1e-30);
        if (rng.next_f64() as f32) < p / q {
            continue; // accepted, move to next position
        }
        // rejected: sample from the residual norm(relu(p - q))
        let mut residual: Vec<f32> = target_probs[i]
            .iter()
            .zip(draft_probs[i].iter())
            .map(|(&pp, &qq)| (pp - qq).max(0.0))
            .collect();
        normalize(&mut residual);
        let bonus = sample_token(&residual, rng) as i32;
        return ChainVerdict { accepted: i, bonus };
    }
    let bonus = sample_token(&target_probs[draft_tokens.len()], rng) as i32;
    ChainVerdict { accepted: draft_tokens.len(), bonus }
}

/// Tree-node verification by sample-then-match (see module docs).
/// Returns (matched child index or None, sampled token).
pub fn accept_at_node(
    target_probs: &[f32],
    child_tokens: &[i32],
    rng: &mut Rng,
    greedy: bool,
) -> (Option<usize>, i32) {
    let x = if greedy {
        argmax(target_probs) as i32
    } else {
        sample_token(target_probs, rng) as i32
    };
    let hit = child_tokens.iter().position(|&c| c == x);
    (hit, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn greedy_one_hot() {
        let p = process_logits(&[0.1, 5.0, -2.0], &SampleParams { temperature: 0.0, ..Default::default() });
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn top_k_masks_tail() {
        let p = process_logits(
            &[1.0, 2.0, 3.0, 4.0],
            &SampleParams { temperature: 1.0, top_k: 2, ..Default::default() },
        );
        assert_eq!(p[0], 0.0);
        assert_eq!(p[1], 0.0);
        assert!(p[2] > 0.0 && p[3] > 0.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn top_p_keeps_head() {
        let p = process_logits(
            &[0.0, 0.0, 10.0],
            &SampleParams { temperature: 1.0, top_p: 0.5, ..Default::default() },
        );
        assert!((p[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn temperature_sharpens() {
        let hot = process_logits(&[1.0, 2.0], &SampleParams { temperature: 2.0, ..Default::default() });
        let cold = process_logits(&[1.0, 2.0], &SampleParams { temperature: 0.5, ..Default::default() });
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn log_softmax_consistent() {
        let l = [0.3f32, -1.2, 2.0];
        let ls = log_softmax(&l);
        let sm = softmax(&l);
        for (a, b) in ls.iter().zip(sm.iter()) {
            assert!((a.exp() - b).abs() < 1e-5);
        }
    }

    #[test]
    fn topk_ordering() {
        let t = topk(&[0.1, 0.9, 0.5], 2);
        assert_eq!(t[0].1, 1);
        assert_eq!(t[1].1, 2);
    }

    /// Satellite regression: a NaN logit must not abort the engine thread.
    #[test]
    fn topk_nan_ranks_last_without_panic() {
        let t = topk(&[0.1, f32::NAN, 0.9], 2);
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].1, 2);
        assert_eq!(t[1].1, 0);
        // NaN only surfaces once the finite values are exhausted
        let all = topk(&[0.1, f32::NAN, 0.9], 3);
        assert_eq!(all[2].1, 1);
        assert!(all[2].0.is_nan());
    }

    #[test]
    fn process_logits_with_nan_does_not_panic() {
        let p = process_logits(
            &[1.0, f32::NAN, 2.0],
            &SampleParams { temperature: 1.0, top_k: 2, top_p: 0.9, ..Default::default() },
        );
        assert_eq!(p.len(), 3);
        assert_eq!(p[1], 0.0, "NaN entry must be masked by top-k");
        assert!(p.iter().all(|x| x.is_finite()));
    }

    /// The greedy (T=0) serving default must also survive NaN: argmax
    /// previously returned index 0 whenever xs[0] was NaN.
    #[test]
    fn greedy_argmax_skips_nan() {
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        let p = process_logits(
            &[f32::NAN, 1.0, 2.0],
            &SampleParams { temperature: 0.0, ..Default::default() },
        );
        assert_eq!(p, vec![0.0, 0.0, 1.0]);
    }

    /// The top-p-only path must also mask NaN: an unmasked NaN poisons the
    /// cumulative sum and used to flatten the output to uniform.
    #[test]
    fn process_logits_nan_with_top_p_only() {
        let p = process_logits(
            &[1.0, f32::NAN, 2.0],
            &SampleParams { temperature: 1.0, top_k: 0, top_p: 0.9, ..Default::default() },
        );
        assert_eq!(p[1], 0.0, "NaN entry must carry zero probability");
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[0], "surviving entries keep their ordering");
        // no top-k / top-p filters at all
        let p = process_logits(
            &[1.0, f32::NAN, 2.0],
            &SampleParams { temperature: 1.0, ..Default::default() },
        );
        assert_eq!(p[1], 0.0);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    /// THE statistical losslessness test for chain rejection sampling:
    /// empirical output distribution of the first emitted token must match
    /// the target distribution regardless of the draft distribution.
    #[test]
    fn chain_rejection_preserves_target_distribution() {
        let v = 5;
        let target = vec![0.40f32, 0.25, 0.15, 0.15, 0.05];
        let draft = vec![0.10f32, 0.50, 0.10, 0.10, 0.20]; // badly misaligned
        let mut rng = Rng::new(1234);
        let n = 60_000;
        let mut counts = vec![0usize; v];
        for _ in 0..n {
            // draft proposes 1 token sampled from draft dist
            let d = sample_token(&draft, &mut rng) as i32;
            let verdict = verify_chain(
                &[d],
                &[draft.clone()],
                &[target.clone(), target.clone()],
                &mut rng,
            );
            let first = if verdict.accepted >= 1 { d } else { verdict.bonus };
            counts[first as usize] += 1;
        }
        for i in 0..v {
            let emp = counts[i] as f32 / n as f32;
            assert!(
                (emp - target[i]).abs() < 0.012,
                "token {i}: got {emp}, want {}",
                target[i]
            );
        }
    }

    #[test]
    fn chain_accepts_everything_when_distributions_match() {
        let dist = vec![0.25f32; 4];
        let mut rng = Rng::new(7);
        let mut total_acc = 0;
        for _ in 0..200 {
            let d: Vec<i32> = (0..3).map(|_| sample_token(&dist, &mut rng) as i32).collect();
            let verdict = verify_chain(
                &d,
                &vec![dist.clone(); 3],
                &vec![dist.clone(); 4],
                &mut rng,
            );
            total_acc += verdict.accepted;
        }
        assert_eq!(total_acc, 600, "p==q must always accept");
    }

    #[test]
    fn sample_then_match_is_exactly_target_distributed() {
        // tree acceptance: emitted token (child-or-bonus) is the raw sample
        let target = vec![0.5f32, 0.3, 0.2];
        let children = vec![0i32, 1];
        let mut rng = Rng::new(99);
        let mut counts = vec![0usize; 3];
        for _ in 0..30_000 {
            let (hit, x) = accept_at_node(&target, &children, &mut rng, false);
            if let Some(h) = hit {
                assert_eq!(children[h], x);
            }
            counts[x as usize] += 1;
        }
        for i in 0..3 {
            let emp = counts[i] as f32 / 30_000.0;
            assert!((emp - target[i]).abs() < 0.012, "{i}: {emp}");
        }
    }

    #[test]
    fn greedy_accept_matches_argmax() {
        let target = vec![0.1f32, 0.7, 0.2];
        let mut rng = Rng::new(1);
        let (hit, x) = accept_at_node(&target, &[1], &mut rng, true);
        assert_eq!(x, 1);
        assert_eq!(hit, Some(0));
        let (hit2, x2) = accept_at_node(&target, &[0, 2], &mut rng, true);
        assert_eq!(x2, 1);
        assert_eq!(hit2, None);
    }

    #[test]
    fn prop_process_logits_valid_distribution() {
        prop::check(
            "process_logits yields a distribution",
            |r| {
                let n = 2 + r.gen_range(40);
                let logits: Vec<f32> = (0..n).map(|_| (r.next_f32() - 0.5) * 20.0).collect();
                let params = SampleParams {
                    temperature: if r.gen_bool(0.3) { 0.0 } else { 0.1 + r.next_f32() * 3.0 },
                    top_k: if r.gen_bool(0.5) { r.gen_range(n) } else { 0 },
                    top_p: if r.gen_bool(0.5) { 0.2 + 0.8 * r.next_f32() } else { 1.0 },
                    seed: 0,
                };
                (logits, params)
            },
            |(logits, params)| {
                let p = process_logits(logits, params);
                let sum: f32 = p.iter().sum();
                if (sum - 1.0).abs() > 1e-4 {
                    return Err(format!("sum={sum}"));
                }
                if p.iter().any(|&x| x < 0.0 || !x.is_finite()) {
                    return Err("negative or nan prob".into());
                }
                // argmax always survives the filters
                if p[argmax(logits)] <= 0.0 {
                    return Err("argmax filtered out".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_verify_chain_prefix_bounds() {
        prop::check(
            "verify_chain accepted <= chain length, bonus in vocab",
            |r| {
                let v = 3 + r.gen_range(10);
                let len = 1 + r.gen_range(5);
                let mk = |r: &mut crate::util::rng::Rng| {
                    let mut p: Vec<f32> = (0..v).map(|_| r.next_f32() + 1e-3).collect();
                    normalize(&mut p);
                    p
                };
                let dp: Vec<Vec<f32>> = (0..len).map(|_| mk(r)).collect();
                let tp: Vec<Vec<f32>> = (0..=len).map(|_| mk(r)).collect();
                let toks: Vec<i32> = dp.iter().map(|p| argmax(p) as i32).collect();
                (toks, dp, tp, r.next_u64())
            },
            |(toks, dp, tp, seed)| {
                let mut rng = Rng::new(*seed);
                let v = verify_chain(toks, dp, tp, &mut rng);
                if v.accepted > toks.len() {
                    return Err("accepted overrun".into());
                }
                if v.bonus < 0 || v.bonus as usize >= tp[0].len() {
                    return Err("bonus out of vocab".into());
                }
                Ok(())
            },
        );
    }
}
