//! Micro-bench harness (criterion substitute for the offline build):
//! warmup, timed iterations, and a summary line — used by `cargo bench`
//! targets and the perf pass.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

/// Run `f` for `warmup` + `iters` iterations; prints + returns stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let summary = summarize(&samples);
    println!(
        "{name:<40} {:>10.3}us/iter  p50={:>10.3}us  p95={:>10.3}us  p99={:>10.3}us  (n={})",
        summary.mean * 1e6,
        summary.p50 * 1e6,
        summary.p95 * 1e6,
        summary.p99 * 1e6,
        iters
    );
    BenchResult { name: name.to_string(), summary }
}

/// Time a single run of `f` (for end-to-end benches where iterations are
/// internal).
pub fn bench_once<F: FnOnce() -> R, R>(name: &str, f: F) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{name:<40} {:>10.1}ms", dt * 1e3);
    (r, dt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_iterations() {
        let mut count = 0;
        let r = bench("noop", 2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(r.summary.n, 10);
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, dt) = bench_once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
