//! Host-side KV-cache manager.
//!
//! Serving graphs are functional: they take the whole cache, write N new
//! rows at `write_start`, and return the updated cache.  The engine keeps
//! the authoritative copy host-side and owns the commit/rollback policy:
//!
//! * tree verification writes its N rows at `committed`; after acceptance
//!   the accepted rows are *compacted* down so the committed region stays
//!   contiguous and the 512-slot cache isn't burned at N slots/cycle;
//! * rejected rows need no cleanup — visibility masks are built from
//!   `committed`, so stale rows are simply never attended to.

use anyhow::{bail, Result};

use crate::runtime::{TensorF, TensorI};

#[derive(Clone, Debug)]
pub struct KvCache {
    pub layers: usize,
    pub slots: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// committed prefix length (slots [0, committed) are canonical context)
    pub committed: usize,
}

impl KvCache {
    pub fn new(layers: usize, slots: usize, heads: usize, head_dim: usize) -> KvCache {
        let n = layers * slots * heads * head_dim;
        KvCache {
            layers,
            slots,
            heads,
            head_dim,
            k: vec![0.0; n],
            v: vec![0.0; n],
            committed: 0,
        }
    }

    pub fn row_size(&self) -> usize {
        self.heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.slots * self.row_size()
    }

    pub fn remaining(&self) -> usize {
        self.slots - self.committed
    }

    /// Replace buffers from graph outputs ([L,S,H,hd] tensors).
    pub fn absorb(&mut self, k: TensorF, v: TensorF) -> Result<()> {
        if k.data.len() != self.k.len() || v.data.len() != self.v.len() {
            bail!(
                "kv absorb size mismatch: got {}/{}, want {}",
                k.data.len(),
                v.data.len(),
                self.k.len()
            );
        }
        self.k = k.data;
        self.v = v.data;
        Ok(())
    }

    pub fn k_tensor(&self) -> TensorF {
        TensorF {
            dims: vec![self.layers, self.slots, self.heads, self.head_dim],
            data: self.k.clone(),
        }
    }

    pub fn v_tensor(&self) -> TensorF {
        TensorF { dims: vec![self.layers, self.slots, self.heads, self.head_dim], data: self.v.clone() }
    }

    /// Single-layer tensors shaped [S,H,hd] (draft cache graphs).
    pub fn k_tensor_2d(&self) -> TensorF {
        TensorF { dims: vec![self.slots, self.heads, self.head_dim], data: self.k.clone() }
    }

    pub fn v_tensor_2d(&self) -> TensorF {
        TensorF { dims: vec![self.slots, self.heads, self.head_dim], data: self.v.clone() }
    }

    /// Mark `n` rows starting at `committed` as committed (chain decode:
    /// rows were written contiguously at the old committed offset).
    pub fn commit(&mut self, n: usize) -> Result<()> {
        if self.committed + n > self.slots {
            bail!("kv cache overflow: {} + {n} > {}", self.committed, self.slots);
        }
        self.committed += n;
        Ok(())
    }

    /// Compact accepted block rows down to the committed boundary.
    ///
    /// A verification block of N rows was written at `base == committed`;
    /// `accepted_rows` are the accepted rows in increasing order.  Their KV
    /// rows move to `committed .. committed+len`, then commit advances.
    pub fn compact_accepted(&mut self, accepted_rows: &[usize]) -> Result<()> {
        let base = self.committed;
        for w in accepted_rows.windows(2) {
            if w[1] <= w[0] {
                bail!("accepted rows must be strictly increasing");
            }
        }
        if let Some(&last) = accepted_rows.last() {
            if base + last >= self.slots {
                bail!("accepted row {last} out of cache");
            }
        }
        let rs = self.row_size();
        for l in 0..self.layers {
            let ls = l * self.layer_stride();
            for (i, &r) in accepted_rows.iter().enumerate() {
                let src = ls + (base + r) * rs;
                let dst = ls + (base + i) * rs;
                if src != dst {
                    self.k.copy_within(src..src + rs, dst);
                    self.v.copy_within(src..src + rs, dst);
                }
            }
        }
        self.committed += accepted_rows.len();
        Ok(())
    }

    /// Reset to an empty cache (new request).
    pub fn reset(&mut self) {
        self.committed = 0;
        // buffers need no clearing: masks hide stale rows
    }

    /// Copy `n` slot rows (every layer) from `src` starting at
    /// `src_start` into this cache at `dst_start` — the gather half of
    /// packing several sessions' committed prefixes into one fused cache.
    pub fn copy_slots_from(
        &mut self,
        src: &KvCache,
        src_start: usize,
        dst_start: usize,
        n: usize,
    ) -> Result<()> {
        if self.layers != src.layers || self.row_size() != src.row_size() {
            bail!("kv cache geometry mismatch");
        }
        if src_start + n > src.slots || dst_start + n > self.slots {
            bail!(
                "kv slot copy out of range: {src_start}+{n} > {} or {dst_start}+{n} > {}",
                src.slots,
                self.slots
            );
        }
        let rs = self.row_size();
        for l in 0..self.layers {
            let s0 = l * src.layer_stride() + src_start * rs;
            let d0 = l * self.layer_stride() + dst_start * rs;
            self.k[d0..d0 + n * rs].copy_from_slice(&src.k[s0..s0 + n * rs]);
            self.v[d0..d0 + n * rs].copy_from_slice(&src.v[s0..s0 + n * rs]);
        }
        Ok(())
    }

    /// Copy `n` slot rows (every layer) from graph-output `[L,S,H,hd]`
    /// tensors into this cache — the scatter half of a fused call: the
    /// rows a fused decode wrote at `src` land at `dst`, exactly where a
    /// solo decode would have written them.
    pub fn write_rows_from(
        &mut self,
        k: &TensorF,
        v: &TensorF,
        src: usize,
        dst: usize,
        n: usize,
    ) -> Result<()> {
        let rs = self.row_size();
        let expect = self.layers * self.slots * rs;
        if k.data.len() != expect || v.data.len() != expect {
            bail!(
                "kv scatter size mismatch: got {}/{}, want {expect}",
                k.data.len(),
                v.data.len()
            );
        }
        if src + n > self.slots || dst + n > self.slots {
            bail!("kv scatter out of range: {src}+{n} / {dst}+{n} > {}", self.slots);
        }
        for l in 0..self.layers {
            let ls = l * self.layer_stride();
            let s0 = ls + src * rs;
            let d0 = ls + dst * rs;
            self.k[d0..d0 + n * rs].copy_from_slice(&k.data[s0..s0 + n * rs]);
            self.v[d0..d0 + n * rs].copy_from_slice(&v.data[s0..s0 + n * rs]);
        }
        Ok(())
    }

    /// Visibility mask rows for a decode block: row n sees all committed
    /// slots, plus (optionally) block ancestors at `base + ancestor_row`,
    /// plus its own slot `base + n`.
    pub fn block_mask(
        &self,
        n: usize,
        block_anc: Option<&[Vec<bool>]>,
    ) -> TensorI {
        let base = self.committed;
        let mut data = vec![0i32; n * self.slots];
        for row in 0..n {
            let off = row * self.slots;
            for s in 0..base {
                data[off + s] = 1;
            }
            match block_anc {
                Some(anc) => {
                    for b in 0..n {
                        if anc[row][b] {
                            data[off + base + b] = 1;
                        }
                    }
                }
                None => {
                    // chain semantics: row n sees rows 0..=n of the block
                    for b in 0..=row {
                        data[off + base + b] = 1;
                    }
                }
            }
        }
        TensorI { dims: vec![n, self.slots], data }
    }
}

// ---------------------------------------------------------------------------
// fused-verification packing
// ---------------------------------------------------------------------------

/// Row-offset bookkeeping for several sessions' segments packed into one
/// fused decode block.
///
/// Layout of the synthetic cache: every member's committed prefix first
/// (member j's prefix occupies fused slots `[prefix_start[j],
/// prefix_start[j] + prefix_len[j])`), then all members' candidate rows
/// contiguously above the packed prefixes — member j's block row i is
/// fused block row `row_off[j] + i`, written at fused slot `base +
/// row_off[j] + i` (the graph's write pointer is `base`, the fused
/// `committed`).  Visibility is block-diagonal: a row sees only its own
/// member's prefix and its own member's in-block ancestors.
#[derive(Clone, Debug)]
pub struct PackedLayout {
    pub slots: usize,
    /// fused slot where member j's committed prefix starts
    pub prefix_start: Vec<usize>,
    /// member j's committed prefix length
    pub prefix_len: Vec<usize>,
    /// member j's first block row (row `i` of member j = `row_off[j] + i`)
    pub row_off: Vec<usize>,
    /// member j's candidate row count
    pub rows: Vec<usize>,
    /// total packed prefix == fused committed == block write base
    pub base: usize,
    /// total candidate rows across members
    pub n_rows: usize,
}

impl PackedLayout {
    /// Plan the packing of `prefix_lens[j]` committed slots + `rows[j]`
    /// candidate rows per member into a `slots`-slot cache, padding the
    /// block to the compiled `width`.  Fails when the pack cannot fit.
    pub fn plan(
        prefix_lens: &[usize],
        rows: &[usize],
        slots: usize,
        width: usize,
    ) -> Result<PackedLayout> {
        if prefix_lens.len() != rows.len() || prefix_lens.is_empty() {
            bail!("packed layout needs matching, non-empty member lists");
        }
        let base: usize = prefix_lens.iter().sum();
        let n_rows: usize = rows.iter().sum();
        if n_rows > width {
            bail!("packed rows {n_rows} exceed block width {width}");
        }
        if base + width > slots {
            bail!(
                "packed segments do not fit: {base} prefix + {width} block > {slots} slots"
            );
        }
        let mut prefix_start = Vec::with_capacity(prefix_lens.len());
        let mut row_off = Vec::with_capacity(rows.len());
        let (mut p, mut r) = (0usize, 0usize);
        for j in 0..prefix_lens.len() {
            prefix_start.push(p);
            p += prefix_lens[j];
            row_off.push(r);
            r += rows[j];
        }
        Ok(PackedLayout {
            slots,
            prefix_start,
            prefix_len: prefix_lens.to_vec(),
            row_off,
            rows: rows.to_vec(),
            base,
            n_rows,
        })
    }

    /// Compose the fused visibility mask `[width, slots]`: member j's row
    /// i sees member j's committed prefix plus its in-block ancestors per
    /// `ancs[j]` (`None` = chain semantics, rows 0..=i of member j).
    /// Padding rows (`n_rows..width`) see nothing.
    pub fn mask(&self, width: usize, ancs: &[Option<&[Vec<bool>]>]) -> TensorI {
        let mut data = vec![0i32; width * self.slots];
        for j in 0..self.rows.len() {
            for i in 0..self.rows[j] {
                let off = (self.row_off[j] + i) * self.slots;
                for s in self.prefix_start[j]..self.prefix_start[j] + self.prefix_len[j] {
                    data[off + s] = 1;
                }
                let block0 = self.base + self.row_off[j];
                match ancs.get(j).copied().flatten() {
                    Some(anc) => {
                        for b in 0..self.rows[j] {
                            if anc[i][b] {
                                data[off + block0 + b] = 1;
                            }
                        }
                    }
                    None => {
                        for b in 0..=i {
                            data[off + block0 + b] = 1;
                        }
                    }
                }
            }
        }
        TensorI { dims: vec![width, self.slots], data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn filled(layers: usize, slots: usize) -> KvCache {
        let mut c = KvCache::new(layers, slots, 2, 4);
        for (i, x) in c.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in c.v.iter_mut().enumerate() {
            *x = -(i as f32);
        }
        c
    }

    #[test]
    fn k_v_tensor_shapes_symmetric() {
        for layers in [1, 3] {
            let c = KvCache::new(layers, 8, 2, 4);
            assert_eq!(c.k_tensor().dims, c.v_tensor().dims);
            assert_eq!(c.k_tensor().dims, vec![layers, 8, 2, 4]);
            assert_eq!(c.k_tensor().data.len(), c.v_tensor().data.len());
            assert_eq!(c.k_tensor_2d().dims, c.v_tensor_2d().dims);
        }
    }

    #[test]
    fn commit_bounds() {
        let mut c = KvCache::new(1, 8, 2, 4);
        assert!(c.commit(8).is_ok());
        assert!(c.commit(1).is_err());
    }

    #[test]
    fn compact_moves_rows_in_order() {
        let mut c = filled(2, 16);
        c.committed = 4;
        let rs = c.row_size();
        // block rows 1 and 3 accepted -> slots 5 and 7 move to 4 and 5
        let expect_k_slot4: Vec<f32> = c.k[5 * rs..6 * rs].to_vec();
        let expect_k_slot5: Vec<f32> = c.k[7 * rs..8 * rs].to_vec();
        let l1 = c.layer_stride();
        let expect_l1_slot4: Vec<f32> = c.k[l1 + 5 * rs..l1 + 6 * rs].to_vec();
        c.compact_accepted(&[1, 3]).unwrap();
        assert_eq!(c.committed, 6);
        assert_eq!(&c.k[4 * rs..5 * rs], &expect_k_slot4[..]);
        assert_eq!(&c.k[5 * rs..6 * rs], &expect_k_slot5[..]);
        assert_eq!(&c.k[l1 + 4 * rs..l1 + 5 * rs], &expect_l1_slot4[..]);
    }

    #[test]
    fn compact_rejects_bad_input() {
        let mut c = filled(1, 8);
        c.committed = 2;
        assert!(c.compact_accepted(&[3, 1]).is_err());
        assert!(c.compact_accepted(&[7]).is_err()); // 2 + 7 >= 8
    }

    #[test]
    fn compact_accepted_row0_is_noop_move() {
        let mut c = filled(1, 8);
        c.committed = 3;
        let before = c.k.clone();
        c.compact_accepted(&[0]).unwrap();
        assert_eq!(c.k, before);
        assert_eq!(c.committed, 4);
    }

    #[test]
    fn chain_mask_rows() {
        let mut c = KvCache::new(1, 8, 2, 4);
        c.committed = 3;
        let m = c.block_mask(2, None);
        assert_eq!(m.dims, vec![2, 8]);
        assert_eq!(&m.data[0..8], &[1, 1, 1, 1, 0, 0, 0, 0]);
        assert_eq!(&m.data[8..16], &[1, 1, 1, 1, 1, 0, 0, 0]);
    }

    #[test]
    fn tree_mask_rows() {
        let mut c = KvCache::new(1, 8, 2, 4);
        c.committed = 2;
        // 3-row block: row2's parent is row0 (not row1)
        let anc = vec![
            vec![true, false, false],
            vec![true, true, false],
            vec![true, false, true],
        ];
        let m = c.block_mask(3, Some(&anc));
        assert_eq!(&m.data[16..24], &[1, 1, 1, 0, 1, 0, 0, 0]);
    }

    #[test]
    fn copy_slots_then_scatter_roundtrip() {
        let src = filled(2, 16);
        let mut fused = KvCache::new(2, 16, 2, 4);
        // gather src slots [3, 7) into fused slots [5, 9)
        fused.copy_slots_from(&src, 3, 5, 4).unwrap();
        let rs = src.row_size();
        let l1 = 16 * rs;
        assert_eq!(&fused.k[5 * rs..6 * rs], &src.k[3 * rs..4 * rs]);
        assert_eq!(&fused.k[l1 + 8 * rs..l1 + 9 * rs], &src.k[l1 + 6 * rs..l1 + 7 * rs]);
        assert_eq!(&fused.v[5 * rs..6 * rs], &src.v[3 * rs..4 * rs]);
        // scatter fused rows [5, 7) back into a fresh cache at [0, 2)
        let mut dst = KvCache::new(2, 16, 2, 4);
        dst.write_rows_from(&fused.k_tensor(), &fused.v_tensor(), 5, 0, 2).unwrap();
        assert_eq!(&dst.k[0..2 * rs], &src.k[3 * rs..5 * rs]);
        assert_eq!(&dst.k[l1..l1 + rs], &src.k[l1 + 3 * rs..l1 + 4 * rs]);
        // bounds are enforced
        assert!(dst.write_rows_from(&fused.k_tensor(), &fused.v_tensor(), 15, 0, 2).is_err());
        let other = KvCache::new(1, 16, 2, 4);
        assert!(fused.copy_slots_from(&other, 0, 0, 1).is_err(), "geometry must match");
    }

    /// A single-member pack must reproduce the solo `block_mask` exactly
    /// (same prefix visibility, same in-block ancestors).
    #[test]
    fn packed_mask_single_member_matches_block_mask() {
        let mut c = KvCache::new(1, 32, 2, 4);
        c.committed = 5;
        let anc = vec![
            vec![true, false, false],
            vec![true, true, false],
            vec![true, false, true],
        ];
        let solo = c.block_mask(3, Some(&anc));
        let layout = PackedLayout::plan(&[5], &[3], 32, 3).unwrap();
        let fused = layout.mask(3, &[Some(&anc[..])]);
        assert_eq!(solo.data, fused.data);
        // chain semantics too
        let solo = c.block_mask(3, None);
        let fused = layout.mask(3, &[None]);
        assert_eq!(solo.data, fused.data);
    }

    /// Two members packed block-diagonally: no row may see the other
    /// member's prefix or rows, and each member's visibility matches its
    /// own solo mask shifted to its segment offsets.
    #[test]
    fn packed_mask_is_block_diagonal() {
        let slots = 64;
        let anc1 = vec![vec![true, false], vec![true, true]];
        let layout = PackedLayout::plan(&[4, 6], &[2, 3], slots, 8).unwrap();
        assert_eq!(layout.prefix_start, vec![0, 4]);
        assert_eq!(layout.row_off, vec![0, 2]);
        assert_eq!(layout.base, 10);
        let m = layout.mask(8, &[Some(&anc1[..]), None]);
        assert_eq!(m.dims, vec![8, slots]);
        let row = |r: usize| &m.data[r * slots..(r + 1) * slots];
        // member 0, row 1: own prefix [0,4) + block rows {0,1} at base 10
        let r = row(1);
        for s in 0..4 {
            assert_eq!(r[s], 1, "own prefix slot {s}");
        }
        for s in 4..10 {
            assert_eq!(r[s], 0, "member 1 prefix must be invisible at {s}");
        }
        assert_eq!(&r[10..15], &[1, 1, 0, 0, 0]);
        // member 1, row 1 (fused row 3): prefix [4,10) + own chain rows
        let r = row(3);
        for s in 0..4 {
            assert_eq!(r[s], 0, "member 0 prefix must be invisible at {s}");
        }
        for s in 4..10 {
            assert_eq!(r[s], 1);
        }
        // member 1's block rows start at base + row_off = 12
        assert_eq!(&r[10..16], &[0, 0, 1, 1, 0, 0]);
        // padding rows see nothing
        assert!(row(6).iter().all(|&x| x == 0));
        assert!(row(7).iter().all(|&x| x == 0));
    }

    #[test]
    fn packed_layout_rejects_overflow() {
        assert!(PackedLayout::plan(&[30, 30], &[4, 4], 64, 8).is_err(), "prefix + width > slots");
        assert!(PackedLayout::plan(&[1, 1], &[5, 5], 64, 8).is_err(), "rows > width");
        assert!(PackedLayout::plan(&[], &[], 64, 8).is_err());
        assert!(PackedLayout::plan(&[1], &[1, 2], 64, 8).is_err());
    }

    #[test]
    fn prop_compact_preserves_committed_prefix() {
        prop::check(
            "compaction never touches the committed prefix",
            |r| {
                let slots = 16 + r.gen_range(16);
                let committed = r.gen_range(slots / 2);
                let n_free = slots - committed;
                let mut rows = Vec::new();
                let mut cur = 0;
                while rows.len() < 5 && cur < n_free - 1 {
                    cur += 1 + r.gen_range(2);
                    if cur < n_free {
                        rows.push(cur - 1);
                    }
                }
                (slots, committed, rows)
            },
            |(slots, committed, rows)| {
                let mut c = filled(2, *slots);
                c.committed = *committed;
                let prefix_k: Vec<f32> = c.k[..*committed * c.row_size()].to_vec();
                c.compact_accepted(rows).map_err(|e| e.to_string())?;
                if &c.k[..*committed * c.row_size()] != &prefix_k[..] {
                    return Err("committed prefix mutated".into());
                }
                if c.committed != committed + rows.len() {
                    return Err("commit count wrong".into());
                }
                Ok(())
            },
        );
    }
}
