//! Host-side KV-cache manager.
//!
//! Serving graphs are functional: they take the whole cache, write N new
//! rows at `write_start`, and return the updated cache.  The engine keeps
//! the authoritative copy host-side and owns the commit/rollback policy:
//!
//! * tree verification writes its N rows at `committed`; after acceptance
//!   the accepted rows are *compacted* down so the committed region stays
//!   contiguous and the 512-slot cache isn't burned at N slots/cycle;
//! * rejected rows need no cleanup — visibility masks are built from
//!   `committed`, so stale rows are simply never attended to.

use anyhow::{bail, Result};

use crate::runtime::{TensorF, TensorI};

#[derive(Clone, Debug)]
pub struct KvCache {
    pub layers: usize,
    pub slots: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// committed prefix length (slots [0, committed) are canonical context)
    pub committed: usize,
}

impl KvCache {
    pub fn new(layers: usize, slots: usize, heads: usize, head_dim: usize) -> KvCache {
        let n = layers * slots * heads * head_dim;
        KvCache {
            layers,
            slots,
            heads,
            head_dim,
            k: vec![0.0; n],
            v: vec![0.0; n],
            committed: 0,
        }
    }

    pub fn row_size(&self) -> usize {
        self.heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.slots * self.row_size()
    }

    pub fn remaining(&self) -> usize {
        self.slots - self.committed
    }

    /// Replace buffers from graph outputs ([L,S,H,hd] tensors).
    pub fn absorb(&mut self, k: TensorF, v: TensorF) -> Result<()> {
        if k.data.len() != self.k.len() || v.data.len() != self.v.len() {
            bail!(
                "kv absorb size mismatch: got {}/{}, want {}",
                k.data.len(),
                v.data.len(),
                self.k.len()
            );
        }
        self.k = k.data;
        self.v = v.data;
        Ok(())
    }

    pub fn k_tensor(&self) -> TensorF {
        TensorF {
            dims: vec![self.layers, self.slots, self.heads, self.head_dim],
            data: self.k.clone(),
        }
    }

    pub fn v_tensor(&self) -> TensorF {
        TensorF { dims: vec![self.layers, self.slots, self.heads, self.head_dim], data: self.v.clone() }
    }

    /// Single-layer tensors shaped [S,H,hd] (draft cache graphs).
    pub fn k_tensor_2d(&self) -> TensorF {
        TensorF { dims: vec![self.slots, self.heads, self.head_dim], data: self.k.clone() }
    }

    pub fn v_tensor_2d(&self) -> TensorF {
        TensorF { dims: vec![self.slots, self.heads, self.head_dim], data: self.v.clone() }
    }

    /// Mark `n` rows starting at `committed` as committed (chain decode:
    /// rows were written contiguously at the old committed offset).
    pub fn commit(&mut self, n: usize) -> Result<()> {
        if self.committed + n > self.slots {
            bail!("kv cache overflow: {} + {n} > {}", self.committed, self.slots);
        }
        self.committed += n;
        Ok(())
    }

    /// Compact accepted block rows down to the committed boundary.
    ///
    /// A verification block of N rows was written at `base == committed`;
    /// `accepted_rows` are the accepted rows in increasing order.  Their KV
    /// rows move to `committed .. committed+len`, then commit advances.
    pub fn compact_accepted(&mut self, accepted_rows: &[usize]) -> Result<()> {
        let base = self.committed;
        for w in accepted_rows.windows(2) {
            if w[1] <= w[0] {
                bail!("accepted rows must be strictly increasing");
            }
        }
        if let Some(&last) = accepted_rows.last() {
            if base + last >= self.slots {
                bail!("accepted row {last} out of cache");
            }
        }
        let rs = self.row_size();
        for l in 0..self.layers {
            let ls = l * self.layer_stride();
            for (i, &r) in accepted_rows.iter().enumerate() {
                let src = ls + (base + r) * rs;
                let dst = ls + (base + i) * rs;
                if src != dst {
                    self.k.copy_within(src..src + rs, dst);
                    self.v.copy_within(src..src + rs, dst);
                }
            }
        }
        self.committed += accepted_rows.len();
        Ok(())
    }

    /// Reset to an empty cache (new request).
    pub fn reset(&mut self) {
        self.committed = 0;
        // buffers need no clearing: masks hide stale rows
    }

    /// Visibility mask rows for a decode block: row n sees all committed
    /// slots, plus (optionally) block ancestors at `base + ancestor_row`,
    /// plus its own slot `base + n`.
    pub fn block_mask(
        &self,
        n: usize,
        block_anc: Option<&[Vec<bool>]>,
    ) -> TensorI {
        let base = self.committed;
        let mut data = vec![0i32; n * self.slots];
        for row in 0..n {
            let off = row * self.slots;
            for s in 0..base {
                data[off + s] = 1;
            }
            match block_anc {
                Some(anc) => {
                    for b in 0..n {
                        if anc[row][b] {
                            data[off + base + b] = 1;
                        }
                    }
                }
                None => {
                    // chain semantics: row n sees rows 0..=n of the block
                    for b in 0..=row {
                        data[off + base + b] = 1;
                    }
                }
            }
        }
        TensorI { dims: vec![n, self.slots], data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn filled(layers: usize, slots: usize) -> KvCache {
        let mut c = KvCache::new(layers, slots, 2, 4);
        for (i, x) in c.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in c.v.iter_mut().enumerate() {
            *x = -(i as f32);
        }
        c
    }

    #[test]
    fn k_v_tensor_shapes_symmetric() {
        for layers in [1, 3] {
            let c = KvCache::new(layers, 8, 2, 4);
            assert_eq!(c.k_tensor().dims, c.v_tensor().dims);
            assert_eq!(c.k_tensor().dims, vec![layers, 8, 2, 4]);
            assert_eq!(c.k_tensor().data.len(), c.v_tensor().data.len());
            assert_eq!(c.k_tensor_2d().dims, c.v_tensor_2d().dims);
        }
    }

    #[test]
    fn commit_bounds() {
        let mut c = KvCache::new(1, 8, 2, 4);
        assert!(c.commit(8).is_ok());
        assert!(c.commit(1).is_err());
    }

    #[test]
    fn compact_moves_rows_in_order() {
        let mut c = filled(2, 16);
        c.committed = 4;
        let rs = c.row_size();
        // block rows 1 and 3 accepted -> slots 5 and 7 move to 4 and 5
        let expect_k_slot4: Vec<f32> = c.k[5 * rs..6 * rs].to_vec();
        let expect_k_slot5: Vec<f32> = c.k[7 * rs..8 * rs].to_vec();
        let l1 = c.layer_stride();
        let expect_l1_slot4: Vec<f32> = c.k[l1 + 5 * rs..l1 + 6 * rs].to_vec();
        c.compact_accepted(&[1, 3]).unwrap();
        assert_eq!(c.committed, 6);
        assert_eq!(&c.k[4 * rs..5 * rs], &expect_k_slot4[..]);
        assert_eq!(&c.k[5 * rs..6 * rs], &expect_k_slot5[..]);
        assert_eq!(&c.k[l1 + 4 * rs..l1 + 5 * rs], &expect_l1_slot4[..]);
    }

    #[test]
    fn compact_rejects_bad_input() {
        let mut c = filled(1, 8);
        c.committed = 2;
        assert!(c.compact_accepted(&[3, 1]).is_err());
        assert!(c.compact_accepted(&[7]).is_err()); // 2 + 7 >= 8
    }

    #[test]
    fn compact_accepted_row0_is_noop_move() {
        let mut c = filled(1, 8);
        c.committed = 3;
        let before = c.k.clone();
        c.compact_accepted(&[0]).unwrap();
        assert_eq!(c.k, before);
        assert_eq!(c.committed, 4);
    }

    #[test]
    fn chain_mask_rows() {
        let mut c = KvCache::new(1, 8, 2, 4);
        c.committed = 3;
        let m = c.block_mask(2, None);
        assert_eq!(m.dims, vec![2, 8]);
        assert_eq!(&m.data[0..8], &[1, 1, 1, 1, 0, 0, 0, 0]);
        assert_eq!(&m.data[8..16], &[1, 1, 1, 1, 1, 0, 0, 0]);
    }

    #[test]
    fn tree_mask_rows() {
        let mut c = KvCache::new(1, 8, 2, 4);
        c.committed = 2;
        // 3-row block: row2's parent is row0 (not row1)
        let anc = vec![
            vec![true, false, false],
            vec![true, true, false],
            vec![true, false, true],
        ];
        let m = c.block_mask(3, Some(&anc));
        assert_eq!(&m.data[16..24], &[1, 1, 1, 0, 1, 0, 0, 0]);
    }

    #[test]
    fn prop_compact_preserves_committed_prefix() {
        prop::check(
            "compaction never touches the committed prefix",
            |r| {
                let slots = 16 + r.gen_range(16);
                let committed = r.gen_range(slots / 2);
                let n_free = slots - committed;
                let mut rows = Vec::new();
                let mut cur = 0;
                while rows.len() < 5 && cur < n_free - 1 {
                    cur += 1 + r.gen_range(2);
                    if cur < n_free {
                        rows.push(cur - 1);
                    }
                }
                (slots, committed, rows)
            },
            |(slots, committed, rows)| {
                let mut c = filled(2, *slots);
                c.committed = *committed;
                let prefix_k: Vec<f32> = c.k[..*committed * c.row_size()].to_vec();
                c.compact_accepted(rows).map_err(|e| e.to_string())?;
                if &c.k[..*committed * c.row_size()] != &prefix_k[..] {
                    return Err("committed prefix mutated".into());
                }
                if c.committed != committed + rows.len() {
                    return Err("commit count wrong".into());
                }
                Ok(())
            },
        );
    }
}
