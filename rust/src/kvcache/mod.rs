//! Host-side **paged** KV-cache manager.
//!
//! Serving graphs are functional: they take the whole `[L,S,H,hd]` cache,
//! write N new rows at `write_start`, and return the updated cache.  The
//! engine keeps the authoritative copy host-side and owns the
//! commit/rollback policy — but since PR 4 the authoritative storage is
//! **paged**, not one flat `Vec<f32>`:
//!
//! * Storage is split into fixed-size [`Page`]s of `page_size` slots
//!   (every layer of those slots lives in the page), refcounted via
//!   `Arc` — pages move freely between worker threads, so a session
//!   admitted on worker A and one admitted on worker B can reference
//!   the *same physical page*.  A [`KvCache`] is a *block table*:
//!   `ceil(slots / page_size)` page references, allocated lazily on
//!   first write.
//! * **Copy-on-write**: writing through [`KvCache::write_rows_from`] or
//!   [`KvCache::compact_accepted`] clones a page first when anyone else
//!   still references it (another session — on any thread — or the
//!   pool registry below).  Cloning a `KvCache` is therefore cheap and
//!   safe: both copies share pages until they diverge.  The gate is
//!   race-free without a lock: when `Arc::strong_count == 1` and
//!   `Arc::weak_count == 0` the writing thread holds the only path to
//!   the page (nobody else can clone a handle they don't have), and
//!   `Arc::get_mut` re-verifies sole ownership atomically.
//! * **Shared prompt pages, pool-wide**: [`KvCache::absorb`] (the
//!   prefill path) rebuilds the pages covering the prompt from the
//!   graph output (later pages are dropped — masked until rewritten)
//!   and runs each through a **sharded, content-addressed pool
//!   registry** shared by every worker thread — sessions admitted with
//!   an identical prompt prefix reference the *same* physical pages no
//!   matter which worker admitted them, so fleet memory scales with
//!   unique prefixes, not active sessions.  The registry is
//!   [`REGISTRY_SHARDS`] independently locked shards routed by content
//!   hash (lock class [`lockorder::PAGE_SHARD`](crate::util::lockorder)
//!   — a strict leaf: a shard critical section calls nothing that
//!   locks).  It holds `Weak` references only, verifies byte-for-byte
//!   equality on every hit (so a hash collision or a page mutated after
//!   registration can never be falsely shared), prunes dead entries on
//!   a cadence and on every probed bucket, and caps each shard at
//!   [`SHARD_ENTRY_CAP`] entries so dead or cold prefixes cannot pin a
//!   shard ([`registry_stats`] exposes live-entry and eviction gauges).
//! * Each page carries a unique `id` plus a `stamp` bumped on every
//!   in-place mutation (an `AtomicU64`, so pages are `Send + Sync`).
//!   Ids and stamps are drawn from one global counter, so `(id, stamp)`
//!   identifies page *content* pool-wide — which is what makes
//!   O(changed-pages) packing possible (below) even when the pages were
//!   produced by another worker.
//!
//! Commit semantics are unchanged: tree verification writes its N rows at
//! `committed`; after acceptance the accepted rows are *compacted* down
//! (tail-page writes only) so the committed region stays contiguous;
//! rejected rows need no cleanup — visibility masks are built from
//! `committed`, so stale rows are simply never attended to.
//!
//! ## Packing: when bytes are copied vs. referenced
//!
//! The compiled graphs still want one contiguous `[L,S,H,hd]` buffer per
//! call, so pages are materialized at two boundaries, both incrementally:
//!
//! * **Solo decode** ([`KvCache::sync_image`]): each cache lazily owns a
//!   contiguous image plus a per-page `(id, stamp)` staging map; a decode
//!   call refreshes only the pages whose stamp changed since the last
//!   call (normally just the tail page) and hands the graph a borrowed
//!   slice — no full-buffer clone per call.
//! * **Fused verification** ([`FusedScratch`]): one per-worker synthetic
//!   image packs many sessions' prefixes.  [`PackedLayout::plan`] assigns
//!   each *distinct* page (by id) one page-aligned segment — co-active
//!   sessions that share prompt pages reference the **same fused
//!   segment**, which lifts the old `Σ prefixes + block <= slots` fusion
//!   ceiling to `(unique pages) · page_size + block <= slots`.
//!   [`FusedScratch::pack`] memcpys whole pages, skipping every page
//!   whose `(id, stamp)` is already staged from a previous cycle, so the
//!   steady-state host cost per cycle is bounded by the *changed* (tail)
//!   pages, not the total prefix.  [`PackedLayout::mask`] composes the
//!   block-diagonal visibility mask from each member's own page segments
//!   (a shared segment is visible to every sharer; padding slots inside a
//!   tail page are visible to no one).
//!
//! Masks make all of this exact: the graphs are purely mask-driven
//! (positions feed only the positional embedding; prefix KV carries its
//! positions baked in), so relocating a page to any slot offset changes
//! nothing a visible row can observe.
//!
//! ## Shadow sanitizer (`HASS_CHECK=1`)
//!
//! Debug builds with `HASS_CHECK=1` in the environment (or tests that
//! call [`audit::force_enable_for_tests`]) re-verify the load-bearing
//! invariants after the fact — see [`audit`]: dedup-registry entries
//! still hash to their bucket (COW never mutates a registered page in
//! place), `(id, stamp)` never names two different byte images, the
//! solo [`KvCache::sync_image`] image and the fused [`FusedScratch`]
//! image stay bit-exact mirrors of the paged storage they were staged
//! from, fused scatters land exactly where the layout says, and
//! composed visibility masks expose exactly the independently derived
//! slot set.  A divergence panics with a `hass-check[...]` tag.

use std::cell::Cell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use anyhow::{bail, Result};

use crate::runtime::{TensorF, TensorI};

pub mod audit;

/// Default page size in slots; `HASS_TEST_PAGE_SIZE` overrides it (the CI
/// matrix runs the suite at an odd size so page-boundary edge cases are
/// exercised in every build).
pub fn default_page_size() -> usize {
    static PS: OnceLock<usize> = OnceLock::new();
    *PS.get_or_init(|| {
        std::env::var("HASS_TEST_PAGE_SIZE")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&p| p > 0)
            .unwrap_or(32)
    })
}

/// Draft-cache page size: `HASS_TEST_DRAFT_PAGE_SIZE` overrides it (the
/// CI matrix drives the draft cache at a tiny odd size so every fused
/// draft level crosses page/COW boundaries); falls back to the shared
/// [`default_page_size`].
pub fn draft_page_size() -> usize {
    static PS: OnceLock<usize> = OnceLock::new();
    *PS.get_or_init(|| {
        std::env::var("HASS_TEST_DRAFT_PAGE_SIZE")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&p| p > 0)
            .unwrap_or_else(default_page_size)
    })
}

/// Monotonic source for page ids and mutation stamps (never reused, so an
/// `(id, stamp)` staging key can never alias two different contents).
static NEXT_PAGE_STAMP: AtomicU64 = AtomicU64::new(1);

fn next_stamp() -> u64 {
    NEXT_PAGE_STAMP.fetch_add(1, Ordering::Relaxed)
}

/// Pool-wide count of physical pages currently alive.  Every [`Page`]
/// is built through [`Page::alloc`] (which increments) and decrements on
/// drop, so this gauge is exact across workers — it is what the
/// scheduler's admission-control and preemption watermarks read.
static LIVE_PAGES: AtomicU64 = AtomicU64::new(0);

/// Current number of physical pages alive anywhere in the process (all
/// workers, all caches; registry weaks don't keep pages alive and are
/// not counted).
pub fn live_pages() -> u64 {
    LIVE_PAGES.load(Ordering::Relaxed)
}

/// One fixed-size block of KV storage: `page_size` slots across every
/// layer, for both K and V (layout `[L, page_size, H*hd]`, layer-major).
/// Pages are shared by `Arc` across worker threads; mutation goes
/// through the owning cache's copy-on-write discipline ([`KvCache`]
/// module docs), so a page's bytes are immutable while any other holder
/// (or a registry weak) can observe them.
#[derive(Debug)]
pub struct Page {
    id: u64,
    /// bumped on every in-place mutation — `(id, stamp)` is the staging
    /// key that lets packers skip unchanged pages.  Atomic only so the
    /// page is `Sync`; stores race with nothing (the COW gate proves the
    /// writer is the sole owner before bumping).
    stamp: AtomicU64,
    layers: usize,
    page_size: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl Page {
    /// Sole constructor: every physical page allocation passes through
    /// here so [`live_pages`] counts exactly the pages that exist
    /// (construction increments, [`Drop`] decrements).
    fn alloc(layers: usize, page_size: usize, k: Vec<f32>, v: Vec<f32>) -> Page {
        crate::util::failpoint::fire_unit(crate::util::failpoint::PAGE_ALLOC);
        LIVE_PAGES.fetch_add(1, Ordering::Relaxed);
        Page {
            id: next_stamp(),
            stamp: AtomicU64::new(next_stamp()),
            layers,
            page_size,
            k,
            v,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn stamp(&self) -> u64 {
        self.stamp.load(Ordering::Relaxed)
    }
}

impl Drop for Page {
    fn drop(&mut self) {
        LIVE_PAGES.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Shared handle to one physical page (pool-wide: handles move freely
/// between worker threads).
pub type PageRef = Arc<Page>;

/// Number of shards in the pool-wide page registry — content hashes
/// route to shards, so workers admitting different prompts almost never
/// contend on the same lock.
pub const REGISTRY_SHARDS: usize = 16;

/// Per-shard live-entry cap: [`RegistryShard::enforce_cap`] evicts past
/// it so a cold prefix working set cannot pin a shard's memory.  An
/// evicted live entry only disables future dedup for that content —
/// sessions keep their strong refs and COW still sees them.
pub const SHARD_ENTRY_CAP: usize = 4096;

/// Per-shard sweep cadence: after this many registrations, drop every
/// bucket entry whose page died (a dead `Weak` still pins the `ArcBox`).
const DEDUP_SWEEP_EVERY: usize = 1024;

/// One registered page: the weak content handle plus the thread that
/// registered it, so a dedup hit from a *different* thread can be
/// counted as cross-worker sharing on the stats wire.
struct RegEntry {
    w: Weak<Page>,
    owner: std::thread::ThreadId,
}

/// One shard of the pool-wide content-addressed registry.
#[derive(Default)]
struct RegistryShard {
    buckets: HashMap<u64, Vec<RegEntry>>,
    /// entries currently held (live or not-yet-swept dead)
    entries: usize,
    /// registrations since the last whole-shard prune
    since_sweep: usize,
    /// cumulative entries dropped: dead-prefix sweeps + cap evictions
    evictions: u64,
}

impl RegistryShard {
    /// Drop every entry whose page died (dead prefixes must not pin the
    /// shard), folding the drops into the eviction counter.
    fn prune(&mut self) {
        let mut dropped = 0usize;
        self.buckets.retain(|_, bucket| {
            let before = bucket.len();
            bucket.retain(|e| e.w.strong_count() > 0);
            dropped += before - bucket.len();
            !bucket.is_empty()
        });
        self.entries -= dropped;
        self.evictions += dropped as u64;
    }

    fn sweep_if_due(&mut self) {
        self.since_sweep += 1;
        if self.since_sweep < DEDUP_SWEEP_EVERY {
            return;
        }
        self.since_sweep = 0;
        self.prune();
    }

    /// Keep the shard at or under `cap` entries: prune dead ones first,
    /// then evict whole buckets (arbitrary order) until under the cap.
    fn enforce_cap(&mut self, cap: usize) {
        if self.entries <= cap {
            return;
        }
        self.prune();
        while self.entries > cap {
            let Some(&h) = self.buckets.keys().next() else { break };
            if let Some(bucket) = self.buckets.remove(&h) {
                self.entries -= bucket.len();
                self.evictions += bucket.len() as u64;
            }
        }
    }
}

/// The pool-wide registry: [`REGISTRY_SHARDS`] independently locked
/// shards.  Shard locks are leaves in the lock order (class
/// [`lockorder::PAGE_SHARD`](crate::util::lockorder)): a shard critical
/// section calls nothing that locks, and whole-pool walks
/// ([`registry_stats`], [`audit::check_registry`]) visit shards strictly
/// one at a time.
fn registry() -> &'static [Mutex<RegistryShard>; REGISTRY_SHARDS] {
    static POOL: OnceLock<[Mutex<RegistryShard>; REGISTRY_SHARDS]> = OnceLock::new();
    POOL.get_or_init(|| std::array::from_fn(|_| Mutex::new(RegistryShard::default())))
}

fn shard_of(hash: u64) -> usize {
    (hash % REGISTRY_SHARDS as u64) as usize
}

thread_local! {
    /// Dedup hits THIS thread took on pages first registered by another
    /// thread, since the last [`take_cross_worker_hits`] drain.
    static CROSS_HITS: Cell<u64> = const { Cell::new(0) };
}

/// Drain the calling thread's cross-worker dedup-hit counter (scheduler
/// workers fold it into their `cross_worker_shared_pages` stats row).
pub fn take_cross_worker_hits() -> u64 {
    CROSS_HITS.with(|c| c.replace(0))
}

/// Pool-wide registry gauges for the stats wire.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegistryStats {
    /// live registered pages across all shards
    pub entries: u64,
    /// cumulative entries dropped (dead-prefix sweeps + cap evictions)
    pub evictions: u64,
}

/// Walk the shards (one lock at a time — see [`registry`]) and report
/// live entries plus cumulative evictions.
pub fn registry_stats() -> RegistryStats {
    let mut out = RegistryStats::default();
    for shard in registry().iter() {
        let _t = crate::util::lockorder::trace(crate::util::lockorder::PAGE_SHARD);
        let reg = shard.lock().unwrap_or_else(|p| p.into_inner());
        out.entries += reg
            .buckets
            .values()
            .flat_map(|b| b.iter())
            .filter(|e| e.w.strong_count() > 0)
            .count() as u64;
        out.evictions += reg.evictions;
    }
    out
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One prospective page viewed in place inside full-cache `[L,S,H,hd]`
/// tensors: slots `[p0, p0+valid)` per layer, zero padding beyond.
/// Hashing and equality run over this view directly, so a dedup-registry
/// HIT costs no page allocation or copy at all.
struct PageSrc<'a> {
    k: &'a [f32],
    v: &'a [f32],
    layers: usize,
    slots: usize,
    page_size: usize,
    rs: usize,
    /// first slot of the page
    p0: usize,
    /// valid slots (the rest of the page is zero padding)
    valid: usize,
}

impl PageSrc<'_> {
    fn hash(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        eat(self.layers as u64);
        eat(self.page_size as u64);
        eat(self.rs as u64);
        for buf in [self.k, self.v] {
            for l in 0..self.layers {
                let s0 = l * self.slots * self.rs + self.p0 * self.rs;
                for &f in &buf[s0..s0 + self.valid * self.rs] {
                    eat(f.to_bits() as u64);
                }
                for _ in self.valid * self.rs..self.page_size * self.rs {
                    eat(0);
                }
            }
        }
        h
    }

    /// Byte-exact match against a materialized page (valid region equals
    /// the tensor slices, padding region is bit-zero).
    fn matches(&self, p: &Page) -> bool {
        let (ps, rs) = (self.page_size, self.rs);
        if p.layers != self.layers || p.page_size != ps || p.k.len() != self.layers * ps * rs {
            return false;
        }
        for (buf, pbuf) in [(self.k, &p.k), (self.v, &p.v)] {
            for l in 0..self.layers {
                let s0 = l * self.slots * rs + self.p0 * rs;
                let d0 = l * ps * rs;
                if !bits_eq(&buf[s0..s0 + self.valid * rs], &pbuf[d0..d0 + self.valid * rs]) {
                    return false;
                }
                if pbuf[d0 + self.valid * rs..d0 + ps * rs].iter().any(|f| f.to_bits() != 0) {
                    return false;
                }
            }
        }
        true
    }

    fn materialize(&self) -> (Vec<f32>, Vec<f32>) {
        let (ps, rs) = (self.page_size, self.rs);
        let n = self.layers * ps * rs;
        let mut pk = vec![0.0f32; n];
        let mut pv = vec![0.0f32; n];
        for l in 0..self.layers {
            let s0 = l * self.slots * rs + self.p0 * rs;
            let d0 = l * ps * rs;
            pk[d0..d0 + self.valid * rs].copy_from_slice(&self.k[s0..s0 + self.valid * rs]);
            pv[d0..d0 + self.valid * rs].copy_from_slice(&self.v[s0..s0 + self.valid * rs]);
        }
        (pk, pv)
    }
}

/// Return a shared page for this content if a live byte-identical one is
/// registered anywhere in the pool, otherwise materialize, register and
/// return a fresh page.  Hits on pages first registered by another
/// thread are counted into [`take_cross_worker_hits`].
fn dedup_page(src: &PageSrc) -> PageRef {
    let h = src.hash();
    let tid = std::thread::current().id();
    let _t = crate::util::lockorder::trace(crate::util::lockorder::PAGE_SHARD);
    let mut reg = registry()[shard_of(h)].lock().unwrap_or_else(|p| p.into_inner());
    // chaos: a panic here poisons the shard lock at a point where its
    // contents are still consistent (nothing mutated yet), exercising the
    // `into_inner` poison-recovery path above
    crate::util::failpoint::fire_unit(crate::util::failpoint::DEDUP_SHARD);
    reg.sweep_if_due();
    let mut dropped = 0usize;
    let mut hit = None;
    if let Some(bucket) = reg.buckets.get_mut(&h) {
        let before = bucket.len();
        bucket.retain(|e| e.w.strong_count() > 0);
        dropped = before - bucket.len();
        for e in bucket.iter() {
            if let Some(p) = e.w.upgrade() {
                if src.matches(&p) {
                    if e.owner != tid {
                        CROSS_HITS.with(|c| c.set(c.get() + 1));
                    }
                    hit = Some(p);
                    break;
                }
            }
        }
    }
    reg.entries -= dropped;
    reg.evictions += dropped as u64;
    if let Some(p) = hit {
        return p;
    }
    let (pk, pv) = src.materialize();
    let p = Arc::new(Page::alloc(src.layers, src.page_size, pk, pv));
    reg.buckets
        .entry(h)
        .or_default()
        .push(RegEntry { w: Arc::downgrade(&p), owner: tid });
    reg.entries += 1;
    reg.enforce_cap(SHARD_ENTRY_CAP);
    p
}

/// Solo-decode staging state: a contiguous `[L,S,H,hd]` image of the
/// paged cache plus the `(id, stamp)` each image region was staged from.
/// `staged[pi] == None` means the region holds zeros (unallocated page).
#[derive(Clone, Debug)]
struct CacheImage {
    k: Vec<f32>,
    v: Vec<f32>,
    staged: Vec<Option<(u64, u64)>>,
}

#[derive(Debug)]
pub struct KvCache {
    pub layers: usize,
    pub slots: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// committed prefix length (slots [0, committed) are canonical context)
    pub committed: usize,
    page_size: usize,
    /// block table: page `pi` backs slots `[pi*page_size, (pi+1)*page_size)`
    pages: Vec<Option<PageRef>>,
    /// lazily materialized contiguous image (solo decode calls)
    image: Option<CacheImage>,
}

impl Clone for KvCache {
    /// Clones share pages (copy-on-write protects both sides) and drop
    /// the materialized image — a clone costs one block table, not two
    /// full `[L,S,H,hd]` buffers.
    fn clone(&self) -> KvCache {
        KvCache {
            layers: self.layers,
            slots: self.slots,
            heads: self.heads,
            head_dim: self.head_dim,
            committed: self.committed,
            page_size: self.page_size,
            pages: self.pages.clone(),
            image: None,
        }
    }
}

impl KvCache {
    pub fn new(layers: usize, slots: usize, heads: usize, head_dim: usize) -> KvCache {
        KvCache::with_page_size(layers, slots, heads, head_dim, default_page_size())
    }

    pub fn with_page_size(
        layers: usize,
        slots: usize,
        heads: usize,
        head_dim: usize,
        page_size: usize,
    ) -> KvCache {
        let page_size = page_size.max(1);
        let n_pages = slots.div_ceil(page_size);
        KvCache {
            layers,
            slots,
            heads,
            head_dim,
            committed: 0,
            page_size,
            pages: vec![None; n_pages],
            image: None,
        }
    }

    pub fn row_size(&self) -> usize {
        self.heads * self.head_dim
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn remaining(&self) -> usize {
        self.slots - self.committed
    }

    /// Pages whose refcount shows another holder (another session's block
    /// table, possibly on another worker thread; the dedup registry holds
    /// only weak refs and doesn't count).
    pub fn shared_pages(&self) -> usize {
        self.pages
            .iter()
            .flatten()
            .filter(|p| Arc::strong_count(p) > 1)
            .count()
    }

    fn ensure_page(&mut self, pi: usize) {
        if self.pages[pi].is_none() {
            let n = self.layers * self.page_size * self.row_size();
            self.pages[pi] = Some(Arc::new(Page::alloc(
                self.layers,
                self.page_size,
                vec![0.0; n],
                vec![0.0; n],
            )));
        }
    }

    /// Writable access to page `pi` — the copy-on-write gate.  A page
    /// referenced by anyone else (refcount, or a dedup-registry weak) is
    /// cloned with a fresh id; a uniquely owned page is mutated in place
    /// with a stamp bump, so staging caches keyed by `(id, stamp)` stay
    /// exact either way.
    ///
    /// Race-freedom across threads: when `strong_count == 1` and
    /// `weak_count == 0` this cache holds the *only* path to the page —
    /// no other thread can mint a new handle without already holding one
    /// — so the counts cannot change under us.  `Arc::get_mut` re-checks
    /// both counts atomically and would refuse (panic here) if that
    /// reasoning were ever violated.
    fn page_mut(&mut self, pi: usize) -> &mut Page {
        self.ensure_page(pi);
        // hass-lint: allow(no-unwrap) — slot was materialized by ensure_page one line up
        let slot = self.pages[pi].as_mut().expect("page just ensured");
        if Arc::strong_count(slot) > 1 || Arc::weak_count(slot) > 0 {
            *slot = Arc::new(Page::alloc(
                slot.layers,
                slot.page_size,
                slot.k.clone(),
                slot.v.clone(),
            ));
        } else {
            slot.stamp.store(next_stamp(), Ordering::Relaxed);
        }
        // hass-lint: allow(no-unwrap) — the branch above just cloned or verified sole ownership
        Arc::get_mut(slot).expect("uniquely owned page after COW")
    }

    /// Handles for the pages backing slots `[0, prefix)` (allocating any
    /// the caller claimed without writing), for fused packing.  The draft
    /// path packs past `committed` — its scratch tree rows live above the
    /// committed boundary but must travel with the prefix.
    ///
    /// `#[hass::mutates_storage]` — allocates missing pages (fresh
    /// `(id, stamp)` identities) even though it writes no rows.
    pub fn pages_covering(&mut self, prefix: usize) -> Vec<PageRef> {
        let n = prefix.min(self.slots).div_ceil(self.page_size);
        (0..n)
            .map(|pi| {
                self.ensure_page(pi);
                // hass-lint: allow(no-unwrap) — slot was materialized by ensure_page one line up
                self.pages[pi].clone().expect("page just ensured")
            })
            .collect()
    }

    /// Handles for the pages backing the committed prefix, for fused
    /// packing.
    ///
    /// `#[hass::mutates_storage]` — allocates via [`KvCache::pages_covering`].
    pub fn committed_pages(&mut self) -> Vec<PageRef> {
        let c = self.committed;
        self.pages_covering(c)
    }

    /// Ids of the pages backing slots `[0, prefix)` (capacity probing:
    /// distinct ids are what page-granular occupancy counts).  Allocates
    /// missing pages like [`KvCache::pages_covering`] but clones no
    /// handles.
    ///
    /// `#[hass::mutates_storage]` — allocates missing pages (fresh
    /// `(id, stamp)` identities).
    pub fn page_ids_covering(&mut self, prefix: usize) -> Vec<u64> {
        let n = prefix.min(self.slots).div_ceil(self.page_size);
        (0..n)
            .map(|pi| {
                self.ensure_page(pi);
                // hass-lint: allow(no-unwrap) — slot was materialized by ensure_page one line up
                self.pages[pi].as_ref().expect("page just ensured").id()
            })
            .collect()
    }

    /// Ids of the committed-prefix pages.
    ///
    /// `#[hass::mutates_storage]` — allocates via [`KvCache::page_ids_covering`].
    pub fn committed_page_ids(&mut self) -> Vec<u64> {
        let c = self.committed;
        self.page_ids_covering(c)
    }

    /// Replace the cache from graph outputs (`[L,S,H,hd]` tensors) — the
    /// prefill path.  Only the pages covering the `prefix` valid slots
    /// (the prompt) are materialized, each routed through the pool-wide
    /// sharded dedup registry so sessions prefilled with an identical
    /// prompt — on *any* worker thread — share physical pages until they
    /// diverge; pages beyond the prefix
    /// are dropped (their slots are masked until rewritten), keeping the
    /// per-admission cost O(prompt pages), not O(cache).
    ///
    /// `#[hass::mutates_storage]` — rebuilds prefix pages through the
    /// dedup registry (fresh pages carry fresh `(id, stamp)` keys).
    pub fn absorb(&mut self, k: TensorF, v: TensorF, prefix: usize) -> Result<()> {
        let n = self.layers * self.slots * self.row_size();
        if k.data.len() != n || v.data.len() != n {
            bail!("kv absorb size mismatch: got {}/{}, want {n}", k.data.len(), v.data.len());
        }
        if prefix > self.slots {
            bail!("kv absorb prefix {prefix} > {} slots", self.slots);
        }
        let (layers, slots, ps, rs) = (self.layers, self.slots, self.page_size, self.row_size());
        let n_prefix = prefix.div_ceil(ps);
        for pi in 0..self.pages.len() {
            if pi >= n_prefix {
                self.pages[pi] = None;
                continue;
            }
            let p0 = pi * ps;
            let src = PageSrc {
                k: &k.data,
                v: &v.data,
                layers,
                slots,
                page_size: ps,
                rs,
                p0,
                valid: ps.min(slots - p0),
            };
            self.pages[pi] = Some(dedup_page(&src));
        }
        if audit::enabled() {
            audit::check_registry();
            audit::note_pages(&self.pages);
        }
        Ok(())
    }

    /// Refresh and borrow the contiguous `[L,S,H,hd]` images (k, v).
    /// Only pages whose `(id, stamp)` changed since the last call are
    /// copied — normally just the tail page — so a steady-state decode
    /// call costs O(changed pages), not O(context).
    pub fn sync_image(&mut self) -> (&[f32], &[f32]) {
        let rs = self.heads * self.head_dim;
        let (layers, slots, ps) = (self.layers, self.slots, self.page_size);
        let n = layers * slots * rs;
        let n_pages = self.pages.len();
        let image = self.image.get_or_insert_with(|| CacheImage {
            k: vec![0.0; n],
            v: vec![0.0; n],
            staged: vec![None; n_pages],
        });
        for (pi, slot) in self.pages.iter().enumerate() {
            let key = slot.as_ref().map(|p| (p.id, p.stamp()));
            if image.staged[pi] == key {
                continue;
            }
            let p0 = pi * ps;
            let valid = ps.min(slots - p0);
            match slot {
                Some(p) => {
                    for l in 0..layers {
                        let io = l * slots * rs + p0 * rs;
                        let po = l * ps * rs;
                        image.k[io..io + valid * rs].copy_from_slice(&p.k[po..po + valid * rs]);
                        image.v[io..io + valid * rs].copy_from_slice(&p.v[po..po + valid * rs]);
                    }
                }
                None => {
                    for l in 0..layers {
                        let io = l * slots * rs + p0 * rs;
                        image.k[io..io + valid * rs].fill(0.0);
                        image.v[io..io + valid * rs].fill(0.0);
                    }
                }
            }
            image.staged[pi] = key;
        }
        if audit::enabled() {
            audit::check_image(&self.pages, image, layers, slots, ps, rs);
        }
        (&image.k, &image.v)
    }

    /// Materialized `[L,S,H,hd]` K tensor (test/inspection convenience;
    /// the decode path borrows [`KvCache::sync_image`] slices instead of
    /// cloning).
    pub fn k_tensor(&mut self) -> TensorF {
        let dims = vec![self.layers, self.slots, self.heads, self.head_dim];
        let (k, _) = self.sync_image();
        TensorF { dims, data: k.to_vec() }
    }

    pub fn v_tensor(&mut self) -> TensorF {
        let dims = vec![self.layers, self.slots, self.heads, self.head_dim];
        let (_, v) = self.sync_image();
        TensorF { dims, data: v.to_vec() }
    }

    /// Single-layer tensors shaped [S,H,hd] (draft cache graphs).
    pub fn k_tensor_2d(&mut self) -> TensorF {
        let dims = vec![self.slots, self.heads, self.head_dim];
        let n = self.slots * self.heads * self.head_dim;
        let (k, _) = self.sync_image();
        TensorF { dims, data: k[..n].to_vec() }
    }

    pub fn v_tensor_2d(&mut self) -> TensorF {
        let dims = vec![self.slots, self.heads, self.head_dim];
        let n = self.slots * self.heads * self.head_dim;
        let (_, v) = self.sync_image();
        TensorF { dims, data: v[..n].to_vec() }
    }

    /// Mark `n` rows starting at `committed` as committed (chain decode:
    /// rows were written contiguously at the old committed offset).
    pub fn commit(&mut self, n: usize) -> Result<()> {
        if self.committed + n > self.slots {
            bail!("kv cache overflow: {} + {n} > {}", self.committed, self.slots);
        }
        self.committed += n;
        Ok(())
    }

    /// Compact accepted block rows down to the committed boundary.
    ///
    /// A verification block of N rows was written at `base == committed`;
    /// `accepted_rows` are the accepted rows in increasing order.  Their KV
    /// rows move to `committed .. committed+len`, then commit advances.
    /// Only the page(s) under the block region are touched (tail pages) —
    /// the committed prefix pages are never written.
    ///
    /// `#[hass::mutates_storage]` — scatters rows through the COW gate
    /// (stamp bump or fresh page per touched tail page).
    pub fn compact_accepted(&mut self, accepted_rows: &[usize]) -> Result<()> {
        let base = self.committed;
        for w in accepted_rows.windows(2) {
            if w[1] <= w[0] {
                bail!("accepted rows must be strictly increasing");
            }
        }
        if let Some(&last) = accepted_rows.last() {
            if base + last >= self.slots {
                bail!("accepted row {last} out of cache");
            }
        }
        let rs = self.row_size();
        let ps = self.page_size;
        let layers = self.layers;
        let mut tk = vec![0.0f32; layers * rs];
        let mut tv = vec![0.0f32; layers * rs];
        for (i, &r) in accepted_rows.iter().enumerate() {
            let src = base + r;
            let dst = base + i;
            if src == dst {
                continue;
            }
            // gather the source row (all layers), then scatter through the
            // COW gate — src slots are always above every dst written so
            // far (rows are strictly increasing), so order is safe
            let spi = src / ps;
            let so = (src % ps) * rs;
            self.ensure_page(spi);
            {
                // hass-lint: allow(no-unwrap) — slot was materialized by ensure_page one line up
                let p = self.pages[spi].as_ref().expect("page just ensured");
                for l in 0..layers {
                    let po = l * ps * rs + so;
                    tk[l * rs..(l + 1) * rs].copy_from_slice(&p.k[po..po + rs]);
                    tv[l * rs..(l + 1) * rs].copy_from_slice(&p.v[po..po + rs]);
                }
            }
            let dof = (dst % ps) * rs;
            let dp = self.page_mut(dst / ps);
            for l in 0..layers {
                let po = l * ps * rs + dof;
                dp.k[po..po + rs].copy_from_slice(&tk[l * rs..(l + 1) * rs]);
                dp.v[po..po + rs].copy_from_slice(&tv[l * rs..(l + 1) * rs]);
            }
        }
        self.committed += accepted_rows.len();
        if audit::enabled() {
            audit::note_pages(&self.pages);
        }
        Ok(())
    }

    /// Reset to an empty cache (new request): drop every page reference.
    /// Shared pages survive as long as another session still uses them.
    pub fn reset(&mut self) {
        self.committed = 0;
        for p in &mut self.pages {
            *p = None;
        }
    }

    /// Park support (page-granular preemption): drop everything a
    /// resumed session can rebuild — the contiguous staging image and
    /// every page wholly past the committed prefix (uncommitted draft /
    /// scratch rows) — while keeping committed pages intact so they
    /// still dedup through the registry and resume is token-identical.
    /// Returns the number of pages released.
    pub fn release_staging(&mut self) -> usize {
        self.image = None;
        let keep = self.committed.div_ceil(self.page_size);
        let mut dropped = 0usize;
        for slot in self.pages.iter_mut().skip(keep) {
            if slot.take().is_some() {
                dropped += 1;
            }
        }
        dropped
    }

    /// Copy `n` slot rows (every layer) from `src` starting at
    /// `src_start` into this cache at `dst_start`.  Slot-granular (the
    /// two caches may use different page sizes); writes go through the
    /// COW gate.  Test-only since fused packing moved to whole-page
    /// staging ([`FusedScratch::pack`]).
    ///
    /// `#[hass::mutates_storage]` — slot-granular writes through the COW gate.
    #[cfg(test)]
    pub fn copy_slots_from(
        &mut self,
        src: &KvCache,
        src_start: usize,
        dst_start: usize,
        n: usize,
    ) -> Result<()> {
        if self.layers != src.layers || self.row_size() != src.row_size() {
            bail!("kv cache geometry mismatch");
        }
        if src_start + n > src.slots || dst_start + n > self.slots {
            bail!(
                "kv slot copy out of range: {src_start}+{n} > {} or {dst_start}+{n} > {}",
                src.slots,
                self.slots
            );
        }
        let rs = self.row_size();
        let layers = self.layers;
        let sps = src.page_size;
        let ps = self.page_size;
        let mut tk = vec![0.0f32; layers * rs];
        let mut tv = vec![0.0f32; layers * rs];
        for i in 0..n {
            let s = src_start + i;
            let d = dst_start + i;
            match src.pages[s / sps].as_ref() {
                Some(p) => {
                    let so = (s % sps) * rs;
                    for l in 0..layers {
                        let po = l * sps * rs + so;
                        tk[l * rs..(l + 1) * rs].copy_from_slice(&p.k[po..po + rs]);
                        tv[l * rs..(l + 1) * rs].copy_from_slice(&p.v[po..po + rs]);
                    }
                }
                None => {
                    tk.fill(0.0);
                    tv.fill(0.0);
                }
            }
            let dof = (d % ps) * rs;
            let dp = self.page_mut(d / ps);
            for l in 0..layers {
                let po = l * ps * rs + dof;
                dp.k[po..po + rs].copy_from_slice(&tk[l * rs..(l + 1) * rs]);
                dp.v[po..po + rs].copy_from_slice(&tv[l * rs..(l + 1) * rs]);
            }
        }
        Ok(())
    }

    /// Copy `n` slot rows (every layer) from graph-output `[L,S,H,hd]`
    /// tensors into this cache — the scatter half of a decode call: the
    /// rows the graph wrote at `src` land at `dst`, exactly where a solo
    /// decode would have written them.  Page-chunked; COW per page.
    ///
    /// `#[hass::mutates_storage]` — page-chunked writes through the COW
    /// gate; every touched page gets a stamp bump or a fresh id.
    pub fn write_rows_from(
        &mut self,
        k: &TensorF,
        v: &TensorF,
        src: usize,
        dst: usize,
        n: usize,
    ) -> Result<()> {
        let rs = self.row_size();
        let expect = self.layers * self.slots * rs;
        if k.data.len() != expect || v.data.len() != expect {
            bail!(
                "kv scatter size mismatch: got {}/{}, want {expect}",
                k.data.len(),
                v.data.len()
            );
        }
        if src + n > self.slots || dst + n > self.slots {
            bail!("kv scatter out of range: {src}+{n} / {dst}+{n} > {}", self.slots);
        }
        let (layers, slots, ps) = (self.layers, self.slots, self.page_size);
        let mut s = 0usize;
        while s < n {
            let pi = (dst + s) / ps;
            let local = (dst + s) % ps;
            let take = (ps - local).min(n - s);
            let page = self.page_mut(pi);
            for l in 0..layers {
                let to = l * slots * rs + (src + s) * rs;
                let po = l * ps * rs + local * rs;
                page.k[po..po + take * rs].copy_from_slice(&k.data[to..to + take * rs]);
                page.v[po..po + take * rs].copy_from_slice(&v.data[to..to + take * rs]);
            }
            s += take;
        }
        if audit::enabled() {
            audit::note_pages(&self.pages);
        }
        Ok(())
    }

    /// Visibility mask rows for a decode block: row n sees all committed
    /// slots, plus (optionally) block ancestors at `base + ancestor_row`,
    /// plus its own slot `base + n`.  Fails with a descriptive capacity
    /// error when the block cannot fit (`committed + n > slots`) instead
    /// of indexing out of bounds deep in the mask loop.
    pub fn block_mask(&self, n: usize, block_anc: Option<&[Vec<bool>]>) -> Result<TensorI> {
        let base = self.committed;
        if base + n > self.slots {
            bail!(
                "mask block of {n} rows exceeds cache capacity ({base} committed + {n} > {} slots)",
                self.slots
            );
        }
        if let Some(anc) = block_anc {
            if anc.len() < n || anc.iter().take(n).any(|row| row.len() < n) {
                bail!("ancestor mask smaller than block ({n} rows)");
            }
        }
        let mut data = vec![0i32; n * self.slots];
        for row in 0..n {
            let off = row * self.slots;
            for s in 0..base {
                data[off + s] = 1;
            }
            match block_anc {
                Some(anc) => {
                    for b in 0..n {
                        if anc[row][b] {
                            data[off + base + b] = 1;
                        }
                    }
                }
                None => {
                    // chain semantics: row n sees rows 0..=n of the block
                    for b in 0..=row {
                        data[off + base + b] = 1;
                    }
                }
            }
        }
        Ok(TensorI { dims: vec![n, self.slots], data })
    }
}

// ---------------------------------------------------------------------------
// fused-verification packing (paged)
// ---------------------------------------------------------------------------

/// One member of a fused pack, described at the page level.
#[derive(Clone, Debug)]
pub struct PackMember {
    /// ids of the pages backing the committed prefix, in slot order
    /// (`ceil(prefix_len / page_size)` of them)
    pub page_ids: Vec<u64>,
    /// committed prefix length in slots
    pub prefix_len: usize,
    /// candidate verification rows this cycle
    pub rows: usize,
}

/// Page-granular layout of several sessions' segments packed into one
/// fused decode block.
///
/// Every *distinct* page (by id) across the members gets one page-aligned
/// fused segment, in first-appearance order; members that share pages
/// (identical prompt prefixes) reference the same segment, so the fused
/// occupancy is `(unique pages) * page_size`, not `Σ prefixes`.  All
/// members' candidate rows then sit contiguously above the packed pages:
/// member j's block row i is fused block row `row_off[j] + i`, written at
/// fused slot `base + row_off[j] + i` (the graph's write pointer is
/// `base`).  Visibility is block-diagonal per member: a row sees the
/// valid slots of its own member's pages plus its own member's in-block
/// ancestors — padding slots inside a tail page are visible to no one.
#[derive(Clone, Debug)]
pub struct PackedLayout {
    pub slots: usize,
    pub page_size: usize,
    /// fused page index of member j's p-th committed page
    pub prefix_pages: Vec<Vec<usize>>,
    /// member j's committed prefix length
    pub prefix_len: Vec<usize>,
    /// member j's first block row (row `i` of member j = `row_off[j] + i`)
    pub row_off: Vec<usize>,
    /// member j's candidate row count
    pub rows: Vec<usize>,
    /// total packed pages * page_size == fused committed == block write base
    pub base: usize,
    /// total candidate rows across members
    pub n_rows: usize,
}

impl PackedLayout {
    /// Plan the packing of `members` into a `slots`-slot cache with the
    /// block padded to the compiled `width`.  Distinct pages are placed
    /// once; a page id repeated *within* one member is given a separate
    /// segment (aliasing it would double the member's visible copies of
    /// those rows).  Fails when `(unique pages)·page_size + width > slots`
    /// or the rows exceed the width.
    pub fn plan(
        members: &[PackMember],
        slots: usize,
        page_size: usize,
        width: usize,
    ) -> Result<PackedLayout> {
        if members.is_empty() {
            bail!("packed layout needs at least one member");
        }
        if page_size == 0 {
            bail!("packed layout needs a non-zero page size");
        }
        let n_rows: usize = members.iter().map(|m| m.rows).sum();
        if n_rows > width {
            bail!("packed rows {n_rows} exceed block width {width}");
        }
        let mut fused_of: HashMap<u64, usize> = HashMap::new();
        let mut n_fused = 0usize;
        let mut prefix_pages = Vec::with_capacity(members.len());
        let mut row_off = Vec::with_capacity(members.len());
        let mut r = 0usize;
        for (j, m) in members.iter().enumerate() {
            let want = m.prefix_len.div_ceil(page_size);
            if m.page_ids.len() != want {
                bail!(
                    "member {j}: {} pages != ceil({} / {page_size})",
                    m.page_ids.len(),
                    m.prefix_len
                );
            }
            let mut seen: HashSet<u64> = HashSet::new();
            let mut fp = Vec::with_capacity(want);
            for &id in &m.page_ids {
                let f = if !seen.insert(id) {
                    // intra-member duplicate: force a distinct segment
                    let f = n_fused;
                    n_fused += 1;
                    f
                } else {
                    *fused_of.entry(id).or_insert_with(|| {
                        let f = n_fused;
                        n_fused += 1;
                        f
                    })
                };
                fp.push(f);
            }
            prefix_pages.push(fp);
            row_off.push(r);
            r += m.rows;
        }
        let base = n_fused * page_size;
        if base + width > slots {
            bail!(
                "packed segments do not fit: {n_fused} pages * {page_size} + {width} block > {slots} slots"
            );
        }
        Ok(PackedLayout {
            slots,
            page_size,
            prefix_pages,
            prefix_len: members.iter().map(|m| m.prefix_len).collect(),
            row_off,
            rows: members.iter().map(|m| m.rows).collect(),
            base,
            n_rows,
        })
    }

    /// Compose the fused visibility mask `[width, slots]`: member j's row
    /// i sees the valid slots of member j's page segments plus its
    /// in-block ancestors per `ancs[j]` (`None` = chain semantics, rows
    /// 0..=i of member j).  Padding rows (`n_rows..width`) see nothing.
    pub fn mask(&self, width: usize, ancs: &[Option<&[Vec<bool>]>]) -> Result<TensorI> {
        if width < self.n_rows {
            bail!("mask width {width} < packed rows {}", self.n_rows);
        }
        if self.base + width > self.slots {
            bail!("mask block exceeds fused capacity ({} + {width} > {})", self.base, self.slots);
        }
        let mut data = vec![0i32; width * self.slots];
        for j in 0..self.rows.len() {
            let anc = ancs.get(j).copied().flatten();
            if let Some(anc) = anc {
                if anc.len() < self.rows[j]
                    || anc.iter().take(self.rows[j]).any(|r| r.len() < self.rows[j])
                {
                    bail!("member {j}: ancestor mask smaller than its rows");
                }
            }
            for i in 0..self.rows[j] {
                let off = (self.row_off[j] + i) * self.slots;
                for (p, &f) in self.prefix_pages[j].iter().enumerate() {
                    let valid = self.page_size.min(self.prefix_len[j] - p * self.page_size);
                    let s0 = f * self.page_size;
                    for s in s0..s0 + valid {
                        data[off + s] = 1;
                    }
                }
                let block0 = self.base + self.row_off[j];
                match anc {
                    Some(anc) => {
                        for b in 0..self.rows[j] {
                            if anc[i][b] {
                                data[off + block0 + b] = 1;
                            }
                        }
                    }
                    None => {
                        for b in 0..=i {
                            data[off + block0 + b] = 1;
                        }
                    }
                }
            }
        }
        if audit::enabled() {
            audit::check_mask(self, width, ancs, &data);
        }
        Ok(TensorI { dims: vec![width, self.slots], data })
    }

    /// Compose a SPARSE fused visibility mask `[width, slots]` — the draft
    /// expansion's shape: member j's row i sees the member's committed
    /// prefix (`vis[j].committed` slots, mapped through the member's page
    /// segments), the row's listed extra slots (tree ancestors — member-
    /// local absolute slots; a slot `>= prefix_len[j]` names a row of THIS
    /// call and maps into the block region), and its own block slot.
    /// Unlike [`PackedLayout::mask`], nothing between `committed` and the
    /// packed prefix is implicitly visible — scratch rows are only seen
    /// where a row lists them.
    pub fn mask_sparse(&self, width: usize, vis: &[MemberVis]) -> Result<TensorI> {
        if vis.len() != self.rows.len() {
            bail!("sparse mask: {} member specs != {} members", vis.len(), self.rows.len());
        }
        if width < self.n_rows {
            bail!("mask width {width} < packed rows {}", self.n_rows);
        }
        if self.base + width > self.slots {
            bail!("mask block exceeds fused capacity ({} + {width} > {})", self.base, self.slots);
        }
        let mut data = vec![0i32; width * self.slots];
        for (j, v) in vis.iter().enumerate() {
            if v.committed > self.prefix_len[j] {
                bail!(
                    "member {j}: committed {} beyond packed prefix {}",
                    v.committed,
                    self.prefix_len[j]
                );
            }
            if v.extra.len() < self.rows[j] {
                bail!("member {j}: {} extra-slot rows < {} rows", v.extra.len(), self.rows[j]);
            }
            let block0 = self.base + self.row_off[j];
            for i in 0..self.rows[j] {
                let off = (self.row_off[j] + i) * self.slots;
                for (p, &f) in self.prefix_pages[j].iter().enumerate() {
                    let lo = p * self.page_size;
                    if lo >= v.committed {
                        break;
                    }
                    let valid = self.page_size.min(v.committed - lo);
                    let s0 = f * self.page_size;
                    for s in s0..s0 + valid {
                        data[off + s] = 1;
                    }
                }
                for &s in &v.extra[i] {
                    if s < self.prefix_len[j] {
                        let f = self.prefix_pages[j][s / self.page_size];
                        data[off + f * self.page_size + s % self.page_size] = 1;
                    } else {
                        let b = s - self.prefix_len[j];
                        if b >= self.rows[j] {
                            bail!("member {j} row {i}: extra slot {s} beyond its rows");
                        }
                        data[off + block0 + b] = 1;
                    }
                }
                data[off + block0 + i] = 1; // own slot
            }
        }
        if audit::enabled() {
            audit::check_mask_sparse(self, width, vis, &data);
        }
        Ok(TensorI { dims: vec![width, self.slots], data })
    }
}

/// Per-member visibility spec for [`PackedLayout::mask_sparse`]: the
/// committed prefix every row sees, plus each row's extra visible slots
/// (member-local absolute; draft-tree ancestors live in the scratch
/// region between `committed` and the packed prefix).
pub struct MemberVis<'a> {
    /// member-local committed prefix length (visible to every row)
    pub committed: usize,
    /// per-row extra visible member-local slots
    pub extra: &'a [Vec<usize>],
}

/// What one [`FusedScratch::pack`] call did.
#[derive(Clone, Copy, Debug, Default)]
pub struct PackStats {
    /// pages memcpy'd into the fused image this pack
    pub pages_copied: usize,
    /// pages skipped because their `(id, stamp)` was already staged
    pub pages_reused: usize,
    /// distinct pages referenced by >= 2 members (cross-session sharing)
    pub shared_pages: usize,
}

/// Persistent synthetic cache for fused verification (schedulers keep
/// one per worker per fused-group ordinal): a contiguous `[L,S,H,hd]`
/// image that survives across cycles, plus a per-fused-page `(id, stamp)`
/// staging map so [`FusedScratch::pack`] copies only the pages that
/// changed (or moved) since the previous cycle.
pub struct FusedScratch {
    layers: usize,
    slots: usize,
    rs: usize,
    page_size: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    staged: Vec<Option<(u64, u64)>>,
    /// cumulative counters (observability; the scheduler diffs them)
    pub pages_copied: u64,
    pub pages_reused: u64,
    /// packs completed (lets callers tell "pack ran" from "pack bailed
    /// early", so the gauge below is never read stale)
    pub packs: u64,
    /// cross-session shared pages observed by the most recent pack
    pub shared_pages: u64,
}

impl FusedScratch {
    pub fn new() -> FusedScratch {
        FusedScratch {
            layers: 0,
            slots: 0,
            rs: 0,
            page_size: 0,
            k: Vec::new(),
            v: Vec::new(),
            staged: Vec::new(),
            pages_copied: 0,
            pages_reused: 0,
            packs: 0,
            shared_pages: 0,
        }
    }

    fn ensure(&mut self, layers: usize, slots: usize, rs: usize, page_size: usize) {
        if (self.layers, self.slots, self.rs, self.page_size) == (layers, slots, rs, page_size) {
            return;
        }
        self.layers = layers;
        self.slots = slots;
        self.rs = rs;
        self.page_size = page_size;
        let n = layers * slots * rs;
        self.k = vec![0.0; n];
        self.v = vec![0.0; n];
        self.staged = vec![None; slots.div_ceil(page_size.max(1))];
    }

    /// The packed contiguous K image (graph input).
    pub fn k(&self) -> &[f32] {
        &self.k
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// Assemble the fused image for `layout`: for every fused page slot,
    /// memcpy the backing page unless its `(id, stamp)` is already staged
    /// there from a previous cycle.  `members[j]` must be the page handles
    /// whose ids produced `layout.prefix_pages[j]`.
    pub fn pack(
        &mut self,
        layout: &PackedLayout,
        members: &[Vec<PageRef>],
        layers: usize,
        rs: usize,
    ) -> Result<PackStats> {
        if members.len() != layout.prefix_pages.len() {
            bail!("pack members/layout mismatch");
        }
        self.ensure(layers, layout.slots, rs, layout.page_size);
        let ps = layout.page_size;
        let n_fused = layout.base / ps;
        let mut by_fused: Vec<Option<&PageRef>> = vec![None; n_fused];
        let mut refs: Vec<usize> = vec![0; n_fused];
        for (j, pages) in members.iter().enumerate() {
            if pages.len() != layout.prefix_pages[j].len() {
                bail!("pack member {j}: page count diverged from layout");
            }
            for (p, pg) in pages.iter().enumerate() {
                let f = layout.prefix_pages[j][p];
                if let Some(prev) = by_fused[f] {
                    if prev.id != pg.id {
                        bail!("pack member {j}: page id diverged from layout");
                    }
                }
                by_fused[f] = Some(pg);
                refs[f] += 1;
            }
        }
        let mut stats = PackStats::default();
        for (f, pg) in by_fused.iter().enumerate() {
            let Some(pg) = pg else {
                bail!("fused page {f} unassigned");
            };
            if pg.layers != layers || pg.page_size != ps || pg.k.len() != layers * ps * rs {
                bail!("pack page geometry mismatch");
            }
            if refs[f] >= 2 {
                stats.shared_pages += 1;
            }
            let key = Some((pg.id, pg.stamp()));
            if self.staged[f] == key {
                stats.pages_reused += 1;
                continue;
            }
            let p0 = f * ps;
            for l in 0..layers {
                let io = l * self.slots * rs + p0 * rs;
                let po = l * ps * rs;
                self.k[io..io + ps * rs].copy_from_slice(&pg.k[po..po + ps * rs]);
                self.v[io..io + ps * rs].copy_from_slice(&pg.v[po..po + ps * rs]);
            }
            self.staged[f] = key;
            stats.pages_copied += 1;
        }
        self.pages_copied += stats.pages_copied as u64;
        self.pages_reused += stats.pages_reused as u64;
        self.packs += 1;
        self.shared_pages = stats.shared_pages as u64;
        if audit::enabled() {
            audit::check_pack(self, layout, members);
        }
        Ok(stats)
    }
}

impl Default for FusedScratch {
    fn default() -> FusedScratch {
        FusedScratch::new()
    }
}

#[cfg(test)]
mod props;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Full-cache tensors with deterministic content (k[i] = i + seed,
    /// v[i] = -(i + seed)).
    fn fill_tensors(layers: usize, slots: usize, rs: usize, seed: f32) -> (TensorF, TensorF) {
        let n = layers * slots * rs;
        let k = TensorF {
            dims: vec![layers, slots, rs / 4, 4],
            data: (0..n).map(|i| i as f32 + seed).collect(),
        };
        let v = TensorF {
            dims: vec![layers, slots, rs / 4, 4],
            data: (0..n).map(|i| -(i as f32 + seed)).collect(),
        };
        (k, v)
    }

    /// A cache with every slot filled (k[i] = i, v[i] = -i in image
    /// coordinates), page size `ps`.
    fn filled_ps(layers: usize, slots: usize, ps: usize) -> KvCache {
        let mut c = KvCache::with_page_size(layers, slots, 2, 4, ps);
        let (k, v) = fill_tensors(layers, slots, c.row_size(), 0.0);
        c.write_rows_from(&k, &v, 0, 0, slots).unwrap();
        c
    }

    fn filled(layers: usize, slots: usize) -> KvCache {
        filled_ps(layers, slots, 4)
    }

    /// K-image row of (layer, slot).
    fn k_row(c: &mut KvCache, layer: usize, slot: usize) -> Vec<f32> {
        let rs = c.row_size();
        let slots = c.slots;
        let (k, _) = c.sync_image();
        k[layer * slots * rs + slot * rs..layer * slots * rs + (slot + 1) * rs].to_vec()
    }

    #[test]
    fn k_v_tensor_shapes_symmetric() {
        for layers in [1, 3] {
            let mut c = KvCache::new(layers, 8, 2, 4);
            assert_eq!(c.k_tensor().dims, c.v_tensor().dims);
            assert_eq!(c.k_tensor().dims, vec![layers, 8, 2, 4]);
            assert_eq!(c.k_tensor().data.len(), c.v_tensor().data.len());
            assert_eq!(c.k_tensor_2d().dims, c.v_tensor_2d().dims);
        }
    }

    #[test]
    fn commit_bounds() {
        let mut c = KvCache::new(1, 8, 2, 4);
        assert!(c.commit(8).is_ok());
        assert!(c.commit(1).is_err());
    }

    #[test]
    fn compact_moves_rows_in_order() {
        let mut c = filled(2, 16);
        c.committed = 4;
        // block rows 1 and 3 accepted -> slots 5 and 7 move to 4 and 5
        let expect_slot4 = k_row(&mut c, 0, 5);
        let expect_slot5 = k_row(&mut c, 0, 7);
        let expect_l1_slot4 = k_row(&mut c, 1, 5);
        c.compact_accepted(&[1, 3]).unwrap();
        assert_eq!(c.committed, 6);
        assert_eq!(k_row(&mut c, 0, 4), expect_slot4);
        assert_eq!(k_row(&mut c, 0, 5), expect_slot5);
        assert_eq!(k_row(&mut c, 1, 4), expect_l1_slot4);
    }

    #[test]
    fn compact_rejects_bad_input() {
        let mut c = filled(1, 8);
        c.committed = 2;
        assert!(c.compact_accepted(&[3, 1]).is_err());
        assert!(c.compact_accepted(&[7]).is_err()); // 2 + 7 >= 8
    }

    #[test]
    fn compact_accepted_row0_is_noop_move() {
        let mut c = filled(1, 8);
        c.committed = 3;
        let before = c.k_tensor().data;
        c.compact_accepted(&[0]).unwrap();
        assert_eq!(c.k_tensor().data, before);
        assert_eq!(c.committed, 4);
    }

    #[test]
    fn chain_mask_rows() {
        let mut c = KvCache::new(1, 8, 2, 4);
        c.committed = 3;
        let m = c.block_mask(2, None).unwrap();
        assert_eq!(m.dims, vec![2, 8]);
        assert_eq!(&m.data[0..8], &[1, 1, 1, 1, 0, 0, 0, 0]);
        assert_eq!(&m.data[8..16], &[1, 1, 1, 1, 1, 0, 0, 0]);
    }

    #[test]
    fn tree_mask_rows() {
        let mut c = KvCache::new(1, 8, 2, 4);
        c.committed = 2;
        // 3-row block: row2's parent is row0 (not row1)
        let anc = vec![
            vec![true, false, false],
            vec![true, true, false],
            vec![true, false, true],
        ];
        let m = c.block_mask(3, Some(&anc)).unwrap();
        assert_eq!(&m.data[16..24], &[1, 1, 1, 0, 1, 0, 0, 0]);
    }

    /// Satellite: an oversized block must produce the descriptive capacity
    /// error, not index out of bounds deep in the mask loop.
    #[test]
    fn block_mask_rejects_overflow() {
        let mut c = KvCache::new(1, 8, 2, 4);
        c.committed = 6;
        assert!(c.block_mask(2, None).is_ok());
        let err = c.block_mask(3, None).unwrap_err().to_string();
        assert!(err.contains("capacity"), "unexpected error: {err}");
        // undersized ancestor masks are rejected too
        let anc = vec![vec![true]];
        assert!(c.block_mask(2, Some(&anc)).is_err());
    }

    #[test]
    fn copy_slots_then_scatter_roundtrip() {
        let mut src = filled(2, 16);
        let mut fused = KvCache::with_page_size(2, 16, 2, 4, 4);
        // gather src slots [3, 7) into fused slots [5, 9)
        fused.copy_slots_from(&src, 3, 5, 4).unwrap();
        for i in 0..4 {
            assert_eq!(k_row(&mut fused, 0, 5 + i), k_row(&mut src, 0, 3 + i));
            assert_eq!(k_row(&mut fused, 1, 5 + i), k_row(&mut src, 1, 3 + i));
        }
        // scatter fused rows [5, 7) back into a fresh cache at [0, 2)
        let mut dst = KvCache::with_page_size(2, 16, 2, 4, 4);
        let (fk, fv) = (fused.k_tensor(), fused.v_tensor());
        dst.write_rows_from(&fk, &fv, 5, 0, 2).unwrap();
        assert_eq!(k_row(&mut dst, 0, 0), k_row(&mut src, 0, 3));
        assert_eq!(k_row(&mut dst, 0, 1), k_row(&mut src, 0, 4));
        assert_eq!(k_row(&mut dst, 1, 0), k_row(&mut src, 1, 3));
        // bounds are enforced
        assert!(dst.write_rows_from(&fk, &fv, 15, 0, 2).is_err());
        let other = KvCache::new(1, 16, 2, 4);
        assert!(fused.copy_slots_from(&other, 0, 0, 1).is_err(), "geometry must match");
    }

    /// A single-member pack must give every committed slot the same
    /// visibility a solo `block_mask` gives it (page segments start at
    /// fused slot 0 for the first member, so the prefix region coincides).
    #[test]
    fn packed_mask_single_member_matches_block_mask_prefix() {
        let ps = 8usize;
        let mut c = KvCache::with_page_size(1, 64, 2, 4, ps);
        c.committed = 5;
        let anc = vec![
            vec![true, false, false],
            vec![true, true, false],
            vec![true, false, true],
        ];
        let solo = c.block_mask(3, Some(&anc)).unwrap();
        let ids = c.committed_page_ids();
        let m = PackMember { page_ids: ids, prefix_len: 5, rows: 3 };
        let layout = PackedLayout::plan(&[m], 64, ps, 3).unwrap();
        assert_eq!(layout.base, ps); // one page, aligned up
        let fused = layout.mask(3, &[Some(&anc[..])]).unwrap();
        for row in 0..3 {
            // prefix visibility identical (slots [0, 5)); padding slots of
            // the tail page ([5, 8)) invisible
            for s in 0..5 {
                assert_eq!(fused.data[row * 64 + s], 1, "row {row} slot {s}");
            }
            for s in 5..ps {
                assert_eq!(fused.data[row * 64 + s], 0, "row {row} pad slot {s}");
            }
            // block ancestors shifted from committed=5 to base=8
            for b in 0..3 {
                assert_eq!(
                    fused.data[row * 64 + ps + b],
                    solo.data[row * 64 + 5 + b],
                    "row {row} block col {b}"
                );
            }
        }
    }

    /// Two members packed block-diagonally: no row may see the other
    /// member's pages or rows.
    #[test]
    fn packed_mask_is_block_diagonal() {
        let (slots, ps) = (64usize, 4usize);
        let anc1 = vec![vec![true, false], vec![true, true]];
        let members = [
            PackMember { page_ids: vec![101], prefix_len: 4, rows: 2 },
            PackMember { page_ids: vec![102, 103], prefix_len: 6, rows: 3 },
        ];
        let layout = PackedLayout::plan(&members, slots, ps, 8).unwrap();
        assert_eq!(layout.prefix_pages, vec![vec![0], vec![1, 2]]);
        assert_eq!(layout.row_off, vec![0, 2]);
        assert_eq!(layout.base, 12); // 3 unique pages * 4
        let m = layout.mask(8, &[Some(&anc1[..]), None]).unwrap();
        assert_eq!(m.dims, vec![8, slots]);
        let row = |r: usize| &m.data[r * slots..(r + 1) * slots];
        // member 0, row 1: own page [0,4) + block rows {0,1} at base 12
        let r = row(1);
        for s in 0..4 {
            assert_eq!(r[s], 1, "own prefix slot {s}");
        }
        for s in 4..12 {
            assert_eq!(r[s], 0, "member 1 pages must be invisible at {s}");
        }
        assert_eq!(&r[12..17], &[1, 1, 0, 0, 0]);
        // member 1, row 1 (fused row 3): pages [4,10) + own chain rows
        let r = row(3);
        for s in 0..4 {
            assert_eq!(r[s], 0, "member 0 page must be invisible at {s}");
        }
        for s in 4..10 {
            assert_eq!(r[s], 1);
        }
        // tail-page padding slots [10,12) invisible
        assert_eq!(&r[10..12], &[0, 0]);
        // member 1's block rows start at base + row_off = 14
        assert_eq!(&r[12..18], &[0, 0, 1, 1, 0, 0]);
        // padding rows see nothing
        assert!(row(6).iter().all(|&x| x == 0));
        assert!(row(7).iter().all(|&x| x == 0));
    }

    /// Members sharing pages reference ONE fused segment — the lifted
    /// fusion ceiling: a shared-prefix fleet fits where the old
    /// `Σ prefixes + block <= slots` bound would overflow.
    #[test]
    fn shared_pages_lift_fusion_ceiling() {
        let (slots, ps) = (128usize, 8usize);
        // 7 members, each committed 20 over the same 3 pages, 1 row each:
        // old bound: 7*20 + 8 = 148 > 128.  New: 3 pages * 8 + 8 = 32.
        let members: Vec<PackMember> = (0..7)
            .map(|_| PackMember { page_ids: vec![1, 2, 3], prefix_len: 20, rows: 1 })
            .collect();
        let old_bound: usize = members.iter().map(|m| m.prefix_len).sum::<usize>() + 8;
        assert!(old_bound > slots, "test must exceed the old ceiling");
        let layout = PackedLayout::plan(&members, slots, ps, 8).unwrap();
        assert_eq!(layout.base, 24);
        assert_eq!(layout.prefix_pages[0], layout.prefix_pages[6]);
        let m = layout.mask(8, &[None; 7]).unwrap();
        // every member sees the shared segment's valid slots [0, 20)
        for j in 0..7 {
            let off = layout.row_off[j] * slots;
            for s in 0..20 {
                assert_eq!(m.data[off + s], 1, "member {j} slot {s}");
            }
            for s in 20..24 {
                assert_eq!(m.data[off + s], 0, "member {j} pad slot {s}");
            }
        }
    }

    #[test]
    fn packed_layout_rejects_overflow() {
        let m = |pages: Vec<u64>, len: usize, rows: usize| PackMember {
            page_ids: pages,
            prefix_len: len,
            rows,
        };
        // distinct pages: 2 members * 30 slots at page 8 = 8 pages = 64,
        // + 8 block > 64 slots
        assert!(
            PackedLayout::plan(
                &[m(vec![1, 2, 3, 4], 30, 4), m(vec![5, 6, 7, 8], 30, 4)],
                64,
                8,
                8
            )
            .is_err(),
            "pages + width > slots"
        );
        assert!(
            PackedLayout::plan(&[m(vec![1], 1, 5), m(vec![2], 1, 5)], 64, 8, 8).is_err(),
            "rows > width"
        );
        assert!(PackedLayout::plan(&[], 64, 8, 8).is_err());
        // page count must match ceil(prefix_len / page_size)
        assert!(PackedLayout::plan(&[m(vec![1], 20, 1)], 64, 8, 8).is_err());
    }

    /// An intra-member duplicate page id must get its own segment (one
    /// segment would double that member's visible copies of those rows).
    #[test]
    fn intra_member_duplicate_pages_get_distinct_segments() {
        let members = [PackMember { page_ids: vec![9, 9], prefix_len: 10, rows: 1 }];
        let layout = PackedLayout::plan(&members, 64, 8, 1).unwrap();
        assert_eq!(layout.prefix_pages[0], vec![0, 1]);
        assert_eq!(layout.base, 16);
    }

    /// Prefill dedup: two caches absorbing identical tensors share every
    /// page; the first divergent write COWs without touching the peer.
    #[test]
    fn absorb_dedups_and_cow_diverges() {
        let (layers, slots, ps) = (2usize, 16usize, 4usize);
        let mut a = KvCache::with_page_size(layers, slots, 2, 4, ps);
        let mut b = KvCache::with_page_size(layers, slots, 2, 4, ps);
        let (k, v) = fill_tensors(layers, slots, 8, 1000.0);
        a.absorb(k.clone(), v.clone(), 10).unwrap();
        b.absorb(k.clone(), v.clone(), 10).unwrap();
        a.committed = 10;
        b.committed = 10;
        assert_eq!(a.committed_page_ids(), b.committed_page_ids(), "prompt pages must dedup");
        assert!(a.shared_pages() > 0);
        // divergence: b writes one row at its committed boundary
        let (k2, v2) = fill_tensors(layers, slots, 8, -7.0);
        b.write_rows_from(&k2, &v2, 10, 10, 1).unwrap();
        assert_ne!(
            a.committed_page_ids().last(),
            b.committed_page_ids().last(),
            "divergent tail page must COW to a fresh id"
        );
        // a's bytes are untouched
        assert_eq!(k_row(&mut a, 0, 10), k.data[10 * 8..11 * 8].to_vec());
        // b's written row took the new content
        assert_eq!(k_row(&mut b, 0, 10), k2.data[10 * 8..11 * 8].to_vec());
        // shared prefix pages still shared
        assert_eq!(a.committed_page_ids()[0], b.committed_page_ids()[0]);
    }

    /// FusedScratch staging: a second pack with unchanged pages copies
    /// nothing; a tail-page write invalidates exactly that page.
    #[test]
    fn fused_scratch_stages_by_page_stamp() {
        let (layers, slots, ps) = (1usize, 32usize, 4usize);
        let rs = 8usize;
        let mut a = filled_ps(layers, slots, ps);
        let mut b = filled_ps(layers, slots, ps);
        a.committed = 6;
        b.committed = 7;
        let mut scratch = FusedScratch::new();
        let plan_pack = |a: &mut KvCache, b: &mut KvCache, scratch: &mut FusedScratch| {
            let pa = a.committed_pages();
            let pb = b.committed_pages();
            let members = [
                PackMember {
                    page_ids: pa.iter().map(|p| p.id()).collect(),
                    prefix_len: a.committed,
                    rows: 1,
                },
                PackMember {
                    page_ids: pb.iter().map(|p| p.id()).collect(),
                    prefix_len: b.committed,
                    rows: 1,
                },
            ];
            let layout = PackedLayout::plan(&members, slots, ps, 8).unwrap();
            scratch.pack(&layout, &[pa, pb], layers, rs).unwrap()
        };
        let s1 = plan_pack(&mut a, &mut b, &mut scratch);
        assert_eq!(s1.pages_copied, 4); // 2 pages each, nothing staged yet
        assert_eq!(s1.pages_reused, 0);
        let s2 = plan_pack(&mut a, &mut b, &mut scratch);
        assert_eq!(s2.pages_copied, 0, "unchanged pages must be reused");
        assert_eq!(s2.pages_reused, 4);
        // dirty b's tail page only
        let (k2, v2) = fill_tensors(layers, slots, rs, 3.0);
        b.write_rows_from(&k2, &v2, 7, 7, 1).unwrap();
        let s3 = plan_pack(&mut a, &mut b, &mut scratch);
        assert_eq!(s3.pages_copied, 1, "only the dirtied tail page re-copies");
        assert_eq!(s3.pages_reused, 3);
        // the packed image matches the sessions' own images in the
        // committed regions
        let (ka, _) = a.sync_image();
        let prefix_a = ka[..6 * rs].to_vec();
        assert_eq!(&scratch.k()[..6 * rs], &prefix_a[..]);
        let (kb, _) = b.sync_image();
        // b's pages occupy fused pages [2, 4) (first-appearance order)
        let prefix_b = kb[..7 * rs].to_vec();
        assert_eq!(&scratch.k()[2 * ps * rs..2 * ps * rs + 7 * rs], &prefix_b[..]);
    }

    /// Sparse (draft-shape) fused mask: committed prefix visible to every
    /// row, scratch rows only where listed, in-call ancestors map to the
    /// block region, padding/unlisted scratch slots invisible.
    #[test]
    fn sparse_mask_maps_prefix_scratch_and_block() {
        let (slots, ps) = (64usize, 4usize);
        // two members: j0 committed 5 with 6 packed slots (one scratch row
        // at slot 5), j1 committed 3 with 3 packed slots
        let members = [
            PackMember { page_ids: vec![11, 12], prefix_len: 6, rows: 2 },
            PackMember { page_ids: vec![21], prefix_len: 3, rows: 1 },
        ];
        let layout = PackedLayout::plan(&members, slots, ps, 8).unwrap();
        assert_eq!(layout.base, 12); // 3 unique pages * 4
        // j0 row 0 sees scratch slot 5; row 1 sees scratch 5 + in-call row 0
        let extra0 = vec![vec![5usize], vec![5, 6]]; // 6 == prefix_len -> block row 0
        let extra1 = vec![vec![]];
        let m = layout
            .mask_sparse(
                8,
                &[
                    MemberVis { committed: 5, extra: &extra0 },
                    MemberVis { committed: 3, extra: &extra1 },
                ],
            )
            .unwrap();
        let row = |r: usize| &m.data[r * slots..(r + 1) * slots];
        // member 0 row 0: committed [0,5) + scratch slot 5 + own block slot
        let r = row(0);
        assert_eq!(&r[0..8], &[1, 1, 1, 1, 1, 1, 0, 0]);
        for s in 8..12 {
            assert_eq!(r[s], 0, "member 1 pages must be invisible at {s}");
        }
        assert_eq!(&r[12..16], &[1, 0, 0, 0], "own slot only in the block");
        // member 0 row 1: adds in-call row 0 at block0
        let r = row(1);
        assert_eq!(&r[12..16], &[1, 1, 0, 0]);
        // member 1 (fused row 2): committed [8,11), own slot base+2
        let r = row(2);
        for s in 0..8 {
            assert_eq!(r[s], 0, "member 0 region invisible at {s}");
        }
        assert_eq!(&r[8..12], &[1, 1, 1, 0]);
        assert_eq!(&r[12..16], &[0, 0, 1, 0]);
        // padding rows see nothing
        assert!(row(5).iter().all(|&x| x == 0));
        // validation: committed beyond the packed prefix is rejected, as
        // is an extra slot past the member's own rows
        let over = [
            MemberVis { committed: 7, extra: &extra0 },
            MemberVis { committed: 3, extra: &extra1 },
        ];
        assert!(layout.mask_sparse(8, &over).is_err());
        let bad = vec![vec![9usize], vec![]]; // 9 - 6 = block row 3 >= rows 2
        let oob = [
            MemberVis { committed: 5, extra: &bad },
            MemberVis { committed: 3, extra: &extra1 },
        ];
        assert!(layout.mask_sparse(8, &oob).is_err());
    }

    #[test]
    fn pages_covering_extends_past_committed() {
        let mut c = KvCache::with_page_size(1, 32, 2, 4, 4);
        c.committed = 5;
        assert_eq!(c.committed_pages().len(), 2);
        // draft scratch packing covers slots beyond the committed prefix
        assert_eq!(c.pages_covering(9).len(), 3);
        assert_eq!(c.page_ids_covering(9).len(), 3);
        assert_eq!(c.pages_covering(0).len(), 0);
    }

    #[test]
    fn prop_compact_preserves_committed_prefix() {
        prop::check(
            "compaction never touches the committed prefix",
            |r| {
                let slots = 16 + r.gen_range(16);
                let committed = r.gen_range(slots / 2);
                let page = 1 + r.gen_range(10);
                let n_free = slots - committed;
                let mut rows = Vec::new();
                let mut cur = 0;
                while rows.len() < 5 && cur < n_free - 1 {
                    cur += 1 + r.gen_range(2);
                    if cur < n_free {
                        rows.push(cur - 1);
                    }
                }
                (slots, committed, page, rows)
            },
            |(slots, committed, page, rows)| {
                let mut c = filled_ps(2, *slots, *page);
                c.committed = *committed;
                let prefix_k: Vec<f32> = {
                    let (k, _) = c.sync_image();
                    k[..*committed * 8].to_vec()
                };
                c.compact_accepted(rows).map_err(|e| e.to_string())?;
                let (k, _) = c.sync_image();
                if k[..*committed * 8] != prefix_k[..] {
                    return Err("committed prefix mutated".into());
                }
                if c.committed != committed + rows.len() {
                    return Err("commit count wrong".into());
                }
                Ok(())
            },
        );
    }

    /// Page handles and whole caches must cross threads freely — the
    /// pool-wide registry and prefix-affinity dispatch depend on it.
    #[test]
    fn pages_are_send_and_sync() {
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<PageRef>();
        assert_ss::<KvCache>();
    }

    /// Shard bookkeeping: pruning drops dead weaks and counts evictions;
    /// the cap evicts live buckets once dead ones are gone.
    #[test]
    fn registry_shard_prunes_and_caps() {
        let mk =
            |seed: u64| Arc::new(Page::alloc(1, 1, vec![seed as f32; 8], vec![seed as f32; 8]));
        let tid = std::thread::current().id();
        let mut shard = RegistryShard::default();
        let live: Vec<PageRef> = (0..3).map(|i| mk(100 + i)).collect();
        for (i, p) in live.iter().enumerate() {
            shard
                .buckets
                .entry(i as u64)
                .or_default()
                .push(RegEntry { w: Arc::downgrade(p), owner: tid });
            shard.entries += 1;
        }
        // a dead entry in its own bucket
        let dead = mk(999);
        shard
            .buckets
            .entry(77)
            .or_default()
            .push(RegEntry { w: Arc::downgrade(&dead), owner: tid });
        shard.entries += 1;
        drop(dead);
        shard.prune();
        assert_eq!(shard.entries, 3, "dead entry must be pruned");
        assert_eq!(shard.evictions, 1);
        assert!(!shard.buckets.contains_key(&77), "empty bucket must be dropped");
        // cap below the live count: whole live buckets are evicted
        shard.enforce_cap(1);
        assert_eq!(shard.entries, 1);
        assert_eq!(shard.evictions, 3);
        assert!(live.iter().all(|p| Arc::strong_count(p) == 1), "eviction never frees live pages");
    }

    /// The registry is pool-wide: a cache absorbed on another OS thread
    /// shares physical pages with one absorbed here, the hit is counted
    /// as cross-worker, and a divergent write stays thread-local.
    #[test]
    fn registry_shares_pages_across_threads() {
        let (layers, slots, ps) = (2usize, 16usize, 4usize);
        let (k, v) = fill_tensors(layers, slots, 8, 4242.0);
        let (tk, tv) = (k.clone(), v.clone());
        let mut remote = std::thread::spawn(move || {
            let mut c = KvCache::with_page_size(layers, slots, 2, 4, ps);
            c.absorb(tk, tv, 10).unwrap();
            c.committed = 10;
            c
        })
        .join()
        .expect("remote absorb thread");
        let _ = take_cross_worker_hits(); // reset this thread's counter
        let mut local = KvCache::with_page_size(layers, slots, 2, 4, ps);
        local.absorb(k.clone(), v.clone(), 10).unwrap();
        local.committed = 10;
        assert_eq!(
            local.committed_page_ids(),
            remote.committed_page_ids(),
            "identical prompts on two threads must share physical pages"
        );
        assert!(local.shared_pages() > 0);
        assert!(
            take_cross_worker_hits() >= 1,
            "dedup hits on another thread's pages must be attributed"
        );
        // divergence on this thread leaves the remote cache's bytes alone
        let (k2, v2) = fill_tensors(layers, slots, 8, -4242.0);
        local.write_rows_from(&k2, &v2, 10, 10, 1).unwrap();
        assert_ne!(local.committed_page_ids().last(), remote.committed_page_ids().last());
        assert_eq!(k_row(&mut remote, 0, 10), k.data[10 * 8..11 * 8].to_vec());
    }

    /// The pool-wide live-page gauge (the overload policy's admission
    /// signal) counts every constructed page.  The gauge is global and
    /// other test threads allocate concurrently, so the only safe
    /// assertion is a lower bound: holding N pages, the gauge reads
    /// at least N.
    #[test]
    fn overload_live_page_gauge_counts_held_pages() {
        let caches: Vec<KvCache> = (0..4)
            .map(|_| {
                let mut c = KvCache::with_page_size(1, 8, 2, 4, 2);
                // lazily allocated zero pages skip dedup: 4 fresh pages
                c.page_ids_covering(8);
                c
            })
            .collect();
        assert!(live_pages() >= 16, "gauge {} < the 16 pages held here", live_pages());
        drop(caches);
    }

    /// `release_staging` (the preemption park path) drops exactly the
    /// pages above the committed boundary and the contiguous image:
    /// committed rows stay byte-identical, dropped slots read as masked
    /// zeros, and a second call finds nothing left.
    #[test]
    fn overload_release_staging_keeps_committed_pages() {
        let mut c = filled_ps(2, 16, 4);
        c.commit(10).unwrap();
        let committed_row = k_row(&mut c, 1, 5);
        // pages 0..3 back slots 0..12 (ceil(10/4) = 3 kept); page 3 drops
        assert_eq!(c.release_staging(), 1, "only the page above the boundary drops");
        assert_eq!(k_row(&mut c, 1, 5), committed_row, "committed rows must survive the park");
        assert!(k_row(&mut c, 0, 13).iter().all(|x| *x == 0.0), "dropped slots must read masked");
        assert_eq!(c.release_staging(), 0, "second park finds nothing to drop");
        assert_eq!(c.committed, 10);
    }

    /// Robustness satellite: a panic injected inside a dedup-registry
    /// shard critical section (`kvcache.dedup_shard`, fired before any
    /// mutation) poisons that shard's lock with its contents consistent;
    /// the `into_inner` recovery path must keep dedup fully functional
    /// afterwards, including hits against entries registered pre-poison.
    #[test]
    fn chaos_poisoned_registry_shard_recovers() {
        use crate::util::failpoint;
        let (layers, slots, ps) = (2usize, 16usize, 4usize);
        let (k, v) = fill_tensors(layers, slots, 8, 4242.0);
        // register the content first, fault-free
        let mut a = KvCache::with_page_size(layers, slots, 2, 4, ps);
        a.absorb(k.clone(), v.clone(), 10).unwrap();
        a.committed = 10;
        // poison the shard: the failpoint fires with the lock held
        let tag = std::thread::current().name().expect("test threads are named").to_string();
        let g = failpoint::install(
            Some(&tag),
            vec![failpoint::FaultSpec {
                point: failpoint::DEDUP_SHARD,
                action: failpoint::Action::Panic,
                rate: 1.0,
            }],
            23,
        );
        let mut b = KvCache::with_page_size(layers, slots, 2, 4, ps);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            b.absorb(k.clone(), v.clone(), 10)
        }));
        assert!(boom.is_err(), "the dedup_shard failpoint must panic the absorber");
        drop(g);
        // recovery: the same content still dedups against a's pages
        // through the poisoned (into_inner-recovered) shard
        let mut c = KvCache::with_page_size(layers, slots, 2, 4, ps);
        c.absorb(k.clone(), v.clone(), 10).unwrap();
        c.committed = 10;
        assert_eq!(
            a.committed_page_ids(),
            c.committed_page_ids(),
            "post-poison absorb must still dedup against pre-poison pages"
        );
        // and the pool-wide registry walk stays functional too
        assert!(registry_stats().entries >= 1);
    }
}
