//! Property tests: the paged cache must reproduce the old contiguous
//! implementation byte-for-byte under random commit/compact/write/reset
//! sequences (random page sizes, including `1` and `> slots`), clones
//! must be copy-on-write-isolated, and fused packing over shared-prompt
//! "mock sessions" must stay O(changed pages) per steady-state cycle.

use anyhow::{bail, Result};

use super::{FusedScratch, KvCache, PackMember, PackedLayout};
use crate::runtime::TensorF;
use crate::util::prop;
use crate::util::rng::Rng;

/// The pre-PR-4 contiguous cache, kept as the oracle.  `reset` zeroes the
/// buffers (the paged cache drops its pages, whose image reads as zeros).
struct Oracle {
    layers: usize,
    slots: usize,
    rs: usize,
    committed: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl Oracle {
    fn new(layers: usize, slots: usize, rs: usize) -> Oracle {
        let n = layers * slots * rs;
        Oracle { layers, slots, rs, committed: 0, k: vec![0.0; n], v: vec![0.0; n] }
    }

    fn write_rows_from(
        &mut self,
        k: &TensorF,
        v: &TensorF,
        src: usize,
        dst: usize,
        n: usize,
    ) -> Result<()> {
        if src + n > self.slots || dst + n > self.slots {
            bail!("oracle scatter out of range");
        }
        for l in 0..self.layers {
            let ls = l * self.slots * self.rs;
            let s0 = ls + src * self.rs;
            let d0 = ls + dst * self.rs;
            self.k[d0..d0 + n * self.rs].copy_from_slice(&k.data[s0..s0 + n * self.rs]);
            self.v[d0..d0 + n * self.rs].copy_from_slice(&v.data[s0..s0 + n * self.rs]);
        }
        Ok(())
    }

    fn commit(&mut self, n: usize) -> Result<()> {
        if self.committed + n > self.slots {
            bail!("oracle overflow");
        }
        self.committed += n;
        Ok(())
    }

    fn compact_accepted(&mut self, rows: &[usize]) -> Result<()> {
        let base = self.committed;
        for w in rows.windows(2) {
            if w[1] <= w[0] {
                bail!("rows not increasing");
            }
        }
        if let Some(&last) = rows.last() {
            if base + last >= self.slots {
                bail!("row out of cache");
            }
        }
        for l in 0..self.layers {
            let ls = l * self.slots * self.rs;
            for (i, &r) in rows.iter().enumerate() {
                let src = ls + (base + r) * self.rs;
                let dst = ls + (base + i) * self.rs;
                if src != dst {
                    self.k.copy_within(src..src + self.rs, dst);
                    self.v.copy_within(src..src + self.rs, dst);
                }
            }
        }
        self.committed += rows.len();
        Ok(())
    }

    fn reset(&mut self) {
        self.committed = 0;
        self.k.fill(0.0);
        self.v.fill(0.0);
    }
}

#[derive(Clone, Debug)]
enum Op {
    /// scatter `n` rows from a seeded full-size tensor, src == dst
    Write { at: usize, n: usize, seed: u32 },
    Commit(usize),
    Compact(Vec<usize>),
    Reset,
}

fn tensors(layers: usize, slots: usize, rs: usize, seed: u32) -> (TensorF, TensorF) {
    let n = layers * slots * rs;
    let f = |i: usize| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) % 10007) as f32;
    let k = TensorF { dims: vec![layers, slots, rs, 1], data: (0..n).map(f).collect() };
    let v = TensorF { dims: vec![layers, slots, rs, 1], data: (0..n).map(|i| -f(i)).collect() };
    (k, v)
}

#[derive(Debug)]
struct Case {
    layers: usize,
    slots: usize,
    heads: usize,
    page: usize,
    ops: Vec<Op>,
}

fn gen_case(r: &mut Rng) -> Case {
    let layers = 1 + r.gen_range(2);
    let slots = 8 + r.gen_range(24);
    let heads = 1 + r.gen_range(2);
    let page = *r.choice(&[1, 2, 3, 5, 8, slots, slots + 7]);
    let n_ops = 4 + r.gen_range(10);
    let mut ops = Vec::with_capacity(n_ops);
    let mut committed = 0usize;
    for _ in 0..n_ops {
        let remaining = slots - committed;
        match r.gen_range(8) {
            0 => {
                ops.push(Op::Reset);
                committed = 0;
            }
            1..=3 => {
                if remaining == 0 {
                    ops.push(Op::Reset);
                    committed = 0;
                    continue;
                }
                let n = 1 + r.gen_range(remaining.min(6));
                ops.push(Op::Write { at: committed, n, seed: r.next_u64() as u32 });
            }
            4..=5 => {
                if remaining == 0 {
                    ops.push(Op::Reset);
                    committed = 0;
                    continue;
                }
                let n = 1 + r.gen_range(remaining.min(4));
                ops.push(Op::Commit(n));
                committed += n;
            }
            _ => {
                if remaining < 2 {
                    ops.push(Op::Reset);
                    committed = 0;
                    continue;
                }
                // strictly increasing accepted rows within the free region
                let mut rows = Vec::new();
                let mut cur = 0usize;
                while rows.len() < 4 && cur + 1 < remaining {
                    cur += 1 + r.gen_range(2);
                    if cur < remaining {
                        rows.push(cur - 1);
                    }
                }
                if rows.is_empty() {
                    rows.push(0);
                }
                committed += rows.len();
                ops.push(Op::Compact(rows));
            }
        }
    }
    Case { layers, slots, heads, page, ops }
}

fn images_match(c: &mut KvCache, o: &Oracle) -> Result<(), String> {
    let (k, v) = c.sync_image();
    if k != &o.k[..] {
        return Err("k image diverged from contiguous oracle".into());
    }
    if v != &o.v[..] {
        return Err("v image diverged from contiguous oracle".into());
    }
    if c.committed != o.committed {
        return Err(format!("committed diverged: {} vs {}", c.committed, o.committed));
    }
    Ok(())
}

/// Byte-for-byte equivalence with the contiguous implementation under
/// random op sequences and page sizes (including 1 and > slots).
#[test]
fn prop_paged_matches_contiguous() {
    prop::check(
        "paged cache == contiguous oracle",
        gen_case,
        |case| {
            let rs = case.heads * 4;
            let mut c = KvCache::with_page_size(case.layers, case.slots, case.heads, 4, case.page);
            let mut o = Oracle::new(case.layers, case.slots, rs);
            for op in &case.ops {
                let (a, b) = match op {
                    Op::Write { at, n, seed } => {
                        let (k, v) = tensors(case.layers, case.slots, rs, *seed);
                        (
                            c.write_rows_from(&k, &v, *at, *at, *n).map_err(|e| e.to_string()),
                            o.write_rows_from(&k, &v, *at, *at, *n).map_err(|e| e.to_string()),
                        )
                    }
                    Op::Commit(n) => (
                        c.commit(*n).map_err(|e| e.to_string()),
                        o.commit(*n).map_err(|e| e.to_string()),
                    ),
                    Op::Compact(rows) => (
                        c.compact_accepted(rows).map_err(|e| e.to_string()),
                        o.compact_accepted(rows).map_err(|e| e.to_string()),
                    ),
                    Op::Reset => {
                        c.reset();
                        o.reset();
                        (Ok(()), Ok(()))
                    }
                };
                if a.is_ok() != b.is_ok() {
                    return Err(format!("status diverged on {op:?}: {a:?} vs {b:?}"));
                }
                images_match(&mut c, &o)?;
            }
            Ok(())
        },
    );
}

/// Clones share pages copy-on-write: mutating the original never changes
/// the clone's bytes.
#[test]
fn prop_clone_is_cow_isolated() {
    prop::check(
        "clone is COW-isolated",
        gen_case,
        |case| {
            let rs = case.heads * 4;
            let mut c = KvCache::with_page_size(case.layers, case.slots, case.heads, 4, case.page);
            // seed some content, then snapshot via clone
            let (k, v) = tensors(case.layers, case.slots, rs, 42);
            c.write_rows_from(&k, &v, 0, 0, case.slots).map_err(|e| e.to_string())?;
            c.committed = case.slots / 2;
            let mut snap = c.clone();
            let want_k = snap.k_tensor().data;
            let want_v = snap.v_tensor().data;
            // hammer the original with the op sequence
            for op in &case.ops {
                match op {
                    Op::Write { at, n, seed } => {
                        let (k, v) = tensors(case.layers, case.slots, rs, *seed);
                        let _ = c.write_rows_from(&k, &v, *at, *at, *n);
                    }
                    Op::Commit(n) => {
                        let _ = c.commit(*n);
                    }
                    Op::Compact(rows) => {
                        let _ = c.compact_accepted(rows);
                    }
                    Op::Reset => c.reset(),
                }
            }
            if snap.k_tensor().data != want_k || snap.v_tensor().data != want_v {
                return Err("clone bytes changed under the original's mutations".into());
            }
            Ok(())
        },
    );
}

/// Multi-handle Arc-COW: clones of one cache handed to several OS
/// threads stay isolated — each thread hammers its own handle with the
/// op sequence while the original's bytes (read afterwards on the
/// spawning thread) never change.  This is the cross-worker version of
/// [`prop_clone_is_cow_isolated`]: pages are `Arc`-shared through the
/// pool-wide registry, so a COW bug here would corrupt another worker's
/// prompt, not just a local snapshot.
#[test]
fn prop_multi_handle_arc_cow_is_thread_isolated() {
    prop::check(
        "multi-handle Arc-COW is thread-isolated",
        gen_case,
        |case| {
            let rs = case.heads * 4;
            let mut c = KvCache::with_page_size(case.layers, case.slots, case.heads, 4, case.page);
            let (k, v) = tensors(case.layers, case.slots, rs, 42);
            c.write_rows_from(&k, &v, 0, 0, case.slots).map_err(|e| e.to_string())?;
            c.committed = case.slots / 2;
            let want_k = c.k_tensor().data;
            let want_v = c.v_tensor().data;
            let threads: Vec<_> = (0..3)
                .map(|_| {
                    let mut h = c.clone();
                    let ops = case.ops.clone();
                    let (layers, slots) = (case.layers, case.slots);
                    std::thread::spawn(move || {
                        for op in &ops {
                            match op {
                                Op::Write { at, n, seed } => {
                                    let (k, v) = tensors(layers, slots, rs, *seed);
                                    let _ = h.write_rows_from(&k, &v, *at, *at, *n);
                                }
                                Op::Commit(n) => {
                                    let _ = h.commit(*n);
                                }
                                Op::Compact(rows) => {
                                    let _ = h.compact_accepted(rows);
                                }
                                Op::Reset => h.reset(),
                            }
                        }
                    })
                })
                .collect();
            for t in threads {
                t.join().map_err(|_| "mutator thread panicked".to_string())?;
            }
            if c.k_tensor().data != want_k || c.v_tensor().data != want_v {
                return Err("original bytes changed under other threads' mutations".into());
            }
            Ok(())
        },
    );
}

/// Draft-session op sequence for the passthrough-equivalence property.
#[derive(Clone, Debug)]
enum DraftOp {
    /// prefill absorb: the prompt's rows replace the prefix; the tail
    /// page is materialized whole (its over-prefix rows keep the graph
    /// bytes, exactly like the old passthrough buffer kept them — never
    /// visible under the masks), pages past it read as zeros
    Prefill { len: usize, seed: u32 },
    /// tree-level write at or above the committed boundary (src == dst)
    Scratch { at: usize, n: usize, seed: u32 },
    Commit(usize),
    Reset,
}

/// PR 5: the paged DRAFT cache must reproduce the literal-passthrough
/// implementation it replaced.  The old draft session fed one flat
/// buffer back call-to-call; its visible semantics were: prefill
/// replaces the prefix (absorb keeps the whole tail page's graph bytes
/// — invisible under the masks, exactly like the passthrough buffer —
/// and drops the pages past it, which read as zeros), each decode
/// writes its rows at a `write_start` at or above the committed
/// boundary, `commit` advances the boundary, `reset` clears.  Drive a
/// single-layer paged cache with random such sequences (draft page
/// sizes incl. 1 and > slots) against a flat oracle, byte-for-byte.
#[test]
fn prop_paged_draft_cache_matches_passthrough() {
    prop::check(
        "paged draft cache == passthrough oracle",
        |r| {
            let slots = 8 + r.gen_range(24);
            let heads = 1 + r.gen_range(2);
            let page = *r.choice(&[1, 2, 3, 5, 8, slots, slots + 7]);
            let n_ops = 4 + r.gen_range(10);
            let mut ops = Vec::with_capacity(n_ops + 2);
            let mut committed = 0usize;
            for _ in 0..n_ops {
                match r.gen_range(6) {
                    0 => {
                        let len = 1 + r.gen_range(slots);
                        ops.push(DraftOp::Prefill { len, seed: r.next_u64() as u32 });
                        committed = len;
                    }
                    1 => {
                        ops.push(DraftOp::Reset);
                        committed = 0;
                    }
                    2..=3 => {
                        // scratch level at an arbitrary offset above the
                        // committed boundary (the walk's watermark)
                        if committed >= slots {
                            ops.push(DraftOp::Reset);
                            committed = 0;
                            continue;
                        }
                        let at = committed + r.gen_range(slots - committed);
                        let n = 1 + r.gen_range((slots - at).min(5));
                        ops.push(DraftOp::Scratch { at, n, seed: r.next_u64() as u32 });
                    }
                    _ => {
                        // the commit call: rows written at the boundary,
                        // then committed
                        if committed >= slots {
                            ops.push(DraftOp::Reset);
                            committed = 0;
                            continue;
                        }
                        let n = 1 + r.gen_range((slots - committed).min(4));
                        ops.push(DraftOp::Scratch { at: committed, n, seed: r.next_u64() as u32 });
                        ops.push(DraftOp::Commit(n));
                        committed += n;
                    }
                }
            }
            (slots, heads, page, ops)
        },
        |(slots, heads, page, ops)| {
            let rs = heads * 4;
            let mut c = KvCache::with_page_size(1, *slots, *heads, 4, *page);
            // flat single-layer passthrough oracle
            let mut ok = vec![0.0f32; *slots * rs];
            let mut ov = vec![0.0f32; *slots * rs];
            let mut ocommitted = 0usize;
            for op in ops {
                match op {
                    DraftOp::Prefill { len, seed } => {
                        let (k, v) = tensors(1, *slots, rs, *seed);
                        c.absorb(k.clone(), v.clone(), *len).map_err(|e| e.to_string())?;
                        c.committed = *len;
                        // absorb materializes whole pages: up to the tail
                        // page's boundary the image carries the graph
                        // bytes, beyond it zeros (dropped pages)
                        let edge = len.div_ceil(*page).saturating_mul(*page).min(*slots);
                        ok[..edge * rs].copy_from_slice(&k.data[..edge * rs]);
                        ov[..edge * rs].copy_from_slice(&v.data[..edge * rs]);
                        ok[edge * rs..].fill(0.0);
                        ov[edge * rs..].fill(0.0);
                        ocommitted = *len;
                    }
                    DraftOp::Scratch { at, n, seed } => {
                        let (k, v) = tensors(1, *slots, rs, *seed);
                        c.write_rows_from(&k, &v, *at, *at, *n).map_err(|e| e.to_string())?;
                        let span = *at * rs..(*at + *n) * rs;
                        ok[span.clone()].copy_from_slice(&k.data[span.clone()]);
                        ov[span.clone()].copy_from_slice(&v.data[span]);
                    }
                    DraftOp::Commit(n) => {
                        c.commit(*n).map_err(|e| e.to_string())?;
                        ocommitted += n;
                    }
                    DraftOp::Reset => {
                        c.reset();
                        ok.fill(0.0);
                        ov.fill(0.0);
                        ocommitted = 0;
                    }
                }
                let (ik, iv) = c.sync_image();
                if ik != &ok[..] {
                    return Err("draft k image diverged from passthrough oracle".into());
                }
                if iv != &ov[..] {
                    return Err("draft v image diverged from passthrough oracle".into());
                }
                if c.committed != ocommitted {
                    return Err(format!("committed diverged: {} vs {ocommitted}", c.committed));
                }
            }
            Ok(())
        },
    );
}

/// THE paged-packing acceptance test, CI flavor: N "mock sessions" share
/// a prompt (dedup'd prefill), then run fused cycles.  Steady-state packs
/// must copy only tail pages (not the whole prefix), report shared pages,
/// and the fleet must fuse past the old `Σ prefixes + block <= slots`
/// ceiling.  The packed image must reproduce each member's own committed
/// bytes exactly.
#[test]
fn shared_prompt_fleet_packs_o_changed_pages() {
    let (layers, slots, heads, hd, ps) = (2usize, 128usize, 2usize, 4usize, 8usize);
    let rs = heads * hd;
    let n_sessions = 7usize;
    let prompt = 20usize;
    let rows_per = 1usize;
    let width = 8usize; // pick_block(7 rows) on the compiled ladder

    // identical prompts -> dedup'd pages
    let mut sessions: Vec<KvCache> = (0..n_sessions)
        .map(|_| {
            let mut c = KvCache::with_page_size(layers, slots, heads, hd, ps);
            let (k, v) = {
                let n = layers * slots * rs;
                let f = |i: usize| (i % 8191) as f32 * 0.5;
                (
                    TensorF { dims: vec![layers, slots, heads, hd], data: (0..n).map(f).collect() },
                    TensorF {
                        dims: vec![layers, slots, heads, hd],
                        data: (0..n).map(|i| -f(i)).collect(),
                    },
                )
            };
            c.absorb(k, v, prompt).unwrap();
            c.committed = prompt;
            c
        })
        .collect();

    // the fleet exceeds the old contiguous fusion ceiling
    let old_bound = n_sessions * prompt + width;
    assert!(old_bound > slots, "fixture must exceed the old ceiling ({old_bound} <= {slots})");

    let mut scratch = FusedScratch::new();
    let mut copied_per_cycle = Vec::new();
    let mut shared_per_cycle = Vec::new();
    for cycle in 0..4usize {
        let mut handles = Vec::with_capacity(n_sessions);
        let mut members = Vec::with_capacity(n_sessions);
        for c in sessions.iter_mut() {
            let pages = c.committed_pages();
            members.push(PackMember {
                page_ids: pages.iter().map(|p| p.id()).collect(),
                prefix_len: c.committed,
                rows: rows_per,
            });
            handles.push(pages);
        }
        let layout = PackedLayout::plan(&members, slots, ps, width)
            .expect("shared-prefix fleet must fit the lifted ceiling");
        let stats = scratch.pack(&layout, &handles, layers, rs).unwrap();
        // release handles before the absorb writes (as fused_decode does)
        // so tail-page writes stay in place instead of COWing
        drop(handles);
        copied_per_cycle.push(stats.pages_copied);
        shared_per_cycle.push(stats.shared_pages);

        // the packed image reproduces every member's committed bytes
        for (j, c) in sessions.iter_mut().enumerate() {
            let committed = c.committed;
            let (ck, _) = c.sync_image();
            let ck = ck.to_vec();
            for (p, &f) in layout.prefix_pages[j].iter().enumerate() {
                let valid = ps.min(committed - p * ps);
                let own = &ck[(p * ps) * rs..(p * ps + valid) * rs];
                let packed = &scratch.k()[(f * ps) * rs..(f * ps + valid) * rs];
                assert_eq!(own, packed, "cycle {cycle} member {j} page {p} bytes diverged");
            }
        }

        // absorb: one fresh committed row per member (solo-equivalent
        // write at the committed boundary, then commit)
        for (j, c) in sessions.iter_mut().enumerate() {
            let n = layers * slots * rs;
            let f = |i: usize| ((i + 31 * j + 977 * cycle) % 4093) as f32;
            let k = TensorF {
                dims: vec![layers, slots, heads, hd],
                data: (0..n).map(f).collect(),
            };
            let v = TensorF {
                dims: vec![layers, slots, heads, hd],
                data: (0..n).map(|i| -f(i)).collect(),
            };
            let at = c.committed;
            c.write_rows_from(&k, &v, at, at, rows_per).unwrap();
            c.commit(rows_per).unwrap();
        }
    }

    // cycle 0 stages everything; the prompt pages are shared
    assert!(shared_per_cycle[0] > 0, "identical prompts must share pages: {shared_per_cycle:?}");
    // steady state: each cycle re-copies at most the per-session tail
    // pages (the row written last cycle dirties <= 2 pages/session at
    // these sizes), never the whole prefix
    let prefix_pages_total: usize = n_sessions * prompt.div_ceil(ps);
    for (cy, &copied) in copied_per_cycle.iter().enumerate().skip(1) {
        assert!(
            copied <= 2 * n_sessions,
            "cycle {cy}: copied {copied} pages, want <= tail pages ({})",
            2 * n_sessions
        );
        assert!(copied < prefix_pages_total, "cycle {cy} re-copied the whole prefix");
    }
    // and something was actually reused
    assert!(scratch.pages_reused > 0);
}
