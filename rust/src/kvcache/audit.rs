//! `HASS_CHECK=1` shadow sanitizer for the paged KV cache.
//!
//! The fused serving path rests on invariants the type system cannot
//! express: `(id, stamp)` names page *content* (never aliases two
//! different byte images), the dedup registry only ever returns
//! byte-identical pages, the contiguous images ([`CacheImage`] /
//! [`FusedScratch`]) stay bit-exact mirrors of the paged storage they
//! were staged from, and composed visibility masks expose exactly the
//! slots each member may see.  This module re-derives each of those
//! from first principles after the fact and panics with a
//! `hass-check[...]` tag on the first divergence.
//!
//! Auditing is **off** unless [`enabled`] returns true: debug builds
//! with `HASS_CHECK=1` in the environment (the CI matrix runs one entry
//! that way), or a thread-local force flag tests flip via
//! [`force_enable_for_tests`].  Release builds compile the hooks down
//! to a cold branch.
//!
//! The audits are deliberately O(everything-they-look-at) — full-image
//! byte compares, per-slot mask recomputation.  That is the point: the
//! production code is incremental (O(changed pages)), and the sanitizer
//! is the non-incremental oracle that proves the increments added up.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::OnceLock;

use super::{bits_eq, CacheImage, FusedScratch, KvCache, MemberVis, Page, PackedLayout, PageRef};
use crate::runtime::TensorF;

thread_local! {
    /// Per-thread force switch so one test can audit without leaking
    /// the mode into tests sharing the process.
    static FORCE: Cell<bool> = const { Cell::new(false) };

    /// Every `(id, stamp)` observed by an audit, mapped to the content
    /// hash it carried at first sight.  A second sighting with a
    /// different hash means an in-place mutation skipped its stamp bump.
    static SEEN: RefCell<HashMap<(u64, u64), u64>> = RefCell::new(HashMap::new());
}

/// Cap on the `(id, stamp)` sighting map; stamps are never reused, so
/// dropping history can miss an alias but can never fabricate one.
const SEEN_CAP: usize = 65_536;

/// Whether shadow audits run on this thread.
pub fn enabled() -> bool {
    if FORCE.with(|f| f.get()) {
        return true;
    }
    if !cfg!(debug_assertions) {
        return false;
    }
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| matches!(std::env::var("HASS_CHECK").as_deref(), Ok("1")))
}

/// Force-enable audits on the current thread (tests; the standard
/// harness runs each test on its own thread, so the flag cannot leak).
pub fn force_enable_for_tests(on: bool) {
    FORCE.with(|f| f.set(on));
}

/// Content hash of a materialized page — must equal [`super::PageSrc::hash`]
/// over the same bytes (a full page is its own source view: every slot
/// valid, padding already zeroed), so registry bucket keys can be
/// re-verified against live pages.
fn page_hash(p: &Page) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    eat(p.layers as u64);
    eat(p.page_size as u64);
    let denom = p.layers * p.page_size;
    let rs = if denom == 0 { 0 } else { p.k.len() / denom };
    eat(rs as u64);
    for buf in [&p.k, &p.v] {
        for &f in buf.iter() {
            eat(f.to_bits() as u64);
        }
    }
    h
}

/// Record sightings of the given block table and panic if any
/// `(id, stamp)` key has been seen with different content — the
/// stamp-discipline invariant, checked on bytes instead of conventions.
pub(super) fn note_pages(pages: &[Option<PageRef>]) {
    SEEN.with(|s| {
        let mut seen = s.borrow_mut();
        if seen.len() > SEEN_CAP {
            seen.clear();
        }
        for p in pages.iter().flatten() {
            note_one(&mut seen, p);
        }
    });
}

fn note_one(seen: &mut HashMap<(u64, u64), u64>, p: &Page) {
    let h = page_hash(p);
    let key = (p.id, p.stamp());
    match seen.get(&key) {
        Some(&prev) if prev != h => panic!(
            "hass-check[stamp]: page (id={}, stamp={}) observed with two different \
             contents — a write skipped its stamp bump",
            key.0, key.1
        ),
        Some(_) => {}
        None => {
            seen.insert(key, h);
        }
    }
}

/// Re-verify the pool-wide dedup registry: every live entry, in every
/// shard, must still hash to the bucket it was registered under.  The
/// COW gate guarantees this (a page with outstanding weak refs is
/// cloned, never mutated in place); a violation means a write path
/// bypassed [`KvCache::page_mut`].  Shards are visited strictly one at
/// a time — the leaf discipline for `lockorder::PAGE_SHARD`.
pub(super) fn check_registry() {
    for shard in super::registry().iter() {
        let _t = crate::util::lockorder::trace(crate::util::lockorder::PAGE_SHARD);
        let reg = shard.lock().unwrap_or_else(|p| p.into_inner());
        for (&bucket_hash, bucket) in reg.buckets.iter() {
            for e in bucket {
                let Some(p) = e.w.upgrade() else { continue };
                let h = page_hash(&p);
                if h != bucket_hash {
                    panic!(
                        "hass-check[registry]: page id={} registered under hash \
                         {bucket_hash:#018x} now hashes {h:#018x} — mutated in place \
                         while registered",
                        p.id
                    );
                }
            }
        }
    }
}

/// Full paged-vs-contiguous equality for a solo cache right after
/// [`KvCache::sync_image`] refreshed it: every staged key matches the
/// live block table, every backed region is bit-identical to its page,
/// every unbacked region is bit-zero.  Also records stamp sightings.
pub(super) fn check_image(
    pages: &[Option<PageRef>],
    image: &CacheImage,
    layers: usize,
    slots: usize,
    ps: usize,
    rs: usize,
) {
    note_pages(pages);
    for (pi, slot) in pages.iter().enumerate() {
        let key = slot.as_ref().map(|p| (p.id, p.stamp()));
        if image.staged[pi] != key {
            panic!(
                "hass-check[image]: page {pi} staged as {:?} but block table holds {key:?} \
                 — stale staging key after refresh",
                image.staged[pi]
            );
        }
        let p0 = pi * ps;
        let valid = ps.min(slots - p0);
        for l in 0..layers {
            let io = l * slots * rs + p0 * rs;
            match slot {
                Some(p) => {
                    let po = l * ps * rs;
                    if !bits_eq(&image.k[io..io + valid * rs], &p.k[po..po + valid * rs])
                        || !bits_eq(&image.v[io..io + valid * rs], &p.v[po..po + valid * rs])
                    {
                        panic!(
                            "hass-check[image]: page {pi} layer {l} diverged between paged \
                             storage and the contiguous image"
                        );
                    }
                }
                None => {
                    let zero = |b: &[f32]| b.iter().all(|f| f.to_bits() == 0);
                    if !zero(&image.k[io..io + valid * rs]) || !zero(&image.v[io..io + valid * rs])
                    {
                        panic!(
                            "hass-check[image]: unbacked page {pi} layer {l} holds non-zero \
                             image bytes"
                        );
                    }
                }
            }
        }
    }
}

/// Verify a [`FusedScratch::pack`]: rebuild the fused-slot -> page
/// assignment independently from the layout and compare the staged
/// keys and the staged bytes against the live pages.
pub(super) fn check_pack(scr: &FusedScratch, layout: &PackedLayout, members: &[Vec<PageRef>]) {
    let ps = layout.page_size;
    let n_fused = if ps == 0 { 0 } else { layout.base / ps };
    let mut by_fused: Vec<Option<&PageRef>> = vec![None; n_fused];
    for (j, pages) in members.iter().enumerate() {
        for (p, pg) in pages.iter().enumerate() {
            let f = layout.prefix_pages[j][p];
            by_fused[f] = Some(pg);
        }
    }
    SEEN.with(|s| {
        let mut seen = s.borrow_mut();
        for pg in by_fused.iter().flatten() {
            note_one(&mut seen, pg);
        }
    });
    for (f, slot) in by_fused.iter().enumerate() {
        let Some(pg) = slot else {
            panic!("hass-check[pack]: fused page {f} has no backing member page");
        };
        let key = Some((pg.id, pg.stamp()));
        if scr.staged[f] != key {
            panic!(
                "hass-check[pack]: fused page {f} staged as {:?} but members hold {key:?}",
                scr.staged[f]
            );
        }
        let p0 = f * ps;
        for l in 0..scr.layers {
            let io = l * scr.slots * scr.rs + p0 * scr.rs;
            let po = l * ps * scr.rs;
            let n = ps * scr.rs;
            if !bits_eq(&scr.k[io..io + n], &pg.k[po..po + n])
                || !bits_eq(&scr.v[io..io + n], &pg.v[po..po + n])
            {
                panic!(
                    "hass-check[pack]: fused page {f} layer {l} diverged between the \
                     scratch image and page id={}",
                    pg.id
                );
            }
        }
    }
}

/// Slot-set a row of [`PackedLayout::mask`] may legally see: the valid
/// slots of its member's page segments plus the permitted in-block
/// ancestors plus nothing else.  Recomputed slot-by-slot (the
/// production composer is row-major and additive; this one asks, per
/// slot, "who is allowed to see you?").
pub(super) fn check_mask(
    layout: &PackedLayout,
    width: usize,
    ancs: &[Option<&[Vec<bool>]>],
    data: &[i32],
) {
    for r in 0..width {
        let member = member_of(layout, r);
        for s in 0..layout.slots {
            let want = match member {
                None => false,
                Some((j, i)) => {
                    if in_member_segments(layout, j, s, layout.prefix_len[j]) {
                        true
                    } else {
                        let block0 = layout.base + layout.row_off[j];
                        if s >= block0 && s < block0 + layout.rows[j] {
                            let b = s - block0;
                            match ancs.get(j).copied().flatten() {
                                Some(anc) => anc[i][b],
                                None => b <= i,
                            }
                        } else {
                            false
                        }
                    }
                }
            };
            let got = data[r * layout.slots + s] != 0;
            if got != want {
                panic!(
                    "hass-check[mask]: row {r} slot {s}: composed {got}, audit derives {want}"
                );
            }
        }
    }
}

/// Same per-slot recomputation for [`PackedLayout::mask_sparse`]: a row
/// sees its member's committed prefix (through the page segments), the
/// slots it explicitly listed, and its own block slot — nothing else.
pub(super) fn check_mask_sparse(
    layout: &PackedLayout,
    width: usize,
    vis: &[MemberVis],
    data: &[i32],
) {
    for r in 0..width {
        let member = member_of(layout, r);
        for s in 0..layout.slots {
            let want = match member {
                None => false,
                Some((j, i)) => {
                    let block0 = layout.base + layout.row_off[j];
                    let mut ok = in_member_segments(layout, j, s, vis[j].committed);
                    ok = ok || s == block0 + i;
                    for &e in &vis[j].extra[i] {
                        let mapped = if e < layout.prefix_len[j] {
                            let f = layout.prefix_pages[j][e / layout.page_size];
                            f * layout.page_size + e % layout.page_size
                        } else {
                            block0 + (e - layout.prefix_len[j])
                        };
                        ok = ok || s == mapped;
                    }
                    ok
                }
            };
            let got = data[r * layout.slots + s] != 0;
            if got != want {
                panic!(
                    "hass-check[mask-sparse]: row {r} slot {s}: composed {got}, audit \
                     derives {want}"
                );
            }
        }
    }
}

/// Which member owns fused block row `r`, as `(member, member-local row)`.
fn member_of(layout: &PackedLayout, r: usize) -> Option<(usize, usize)> {
    for j in 0..layout.rows.len() {
        if r >= layout.row_off[j] && r < layout.row_off[j] + layout.rows[j] {
            return Some((j, r - layout.row_off[j]));
        }
    }
    None
}

/// Is fused slot `s` inside member `j`'s page segments, within the
/// first `limit` member-local slots (prefix length or committed mark)?
fn in_member_segments(layout: &PackedLayout, j: usize, s: usize, limit: usize) -> bool {
    for (p, &f) in layout.prefix_pages[j].iter().enumerate() {
        let lo = p * layout.page_size;
        if lo >= limit {
            break;
        }
        let valid = layout.page_size.min(limit - lo);
        let s0 = f * layout.page_size;
        if s >= s0 && s < s0 + valid {
            return true;
        }
    }
    false
}

/// Verify a scatter landed: rows `[src, src+n)` of the graph-output
/// tensors must now read back bit-identically at `[dst, dst+n)` through
/// the cache's contiguous image (which itself gets audited against the
/// paged storage on the way).  Called by the fused verify/draft paths
/// after [`KvCache::write_rows_from`].
pub fn check_scatter(
    cache: &mut KvCache,
    k: &TensorF,
    v: &TensorF,
    src: usize,
    dst: usize,
    n: usize,
) {
    if !enabled() {
        return;
    }
    let rs = cache.row_size();
    let (layers, slots) = (cache.layers, cache.slots);
    let (ik, iv) = cache.sync_image();
    for l in 0..layers {
        for r in 0..n {
            let so = l * slots * rs + (src + r) * rs;
            let d = l * slots * rs + (dst + r) * rs;
            if !bits_eq(&ik[d..d + rs], &k.data[so..so + rs])
                || !bits_eq(&iv[d..d + rs], &v.data[so..so + rs])
            {
                panic!(
                    "hass-check[scatter]: layer {l} row {r} (src {src} -> dst {dst}) \
                     diverged from the graph output"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::PackMember;
    use super::*;

    fn filled(layers: usize, slots: usize, rs: usize, seed: f32) -> (TensorF, TensorF) {
        let n = layers * slots * rs;
        let k = TensorF {
            dims: vec![layers, slots, rs, 1],
            data: (0..n).map(|i| i as f32 + seed).collect(),
        };
        let v = TensorF {
            dims: vec![layers, slots, rs, 1],
            data: (0..n).map(|i| -(i as f32 + seed)).collect(),
        };
        (k, v)
    }

    #[test]
    fn happy_path_is_silent() {
        force_enable_for_tests(true);
        let mut c = KvCache::with_page_size(2, 16, 2, 2, 4);
        let (k, v) = filled(2, 16, 4, 1.0);
        c.absorb(k.clone(), v.clone(), 7).unwrap();
        c.committed = 7;
        c.write_rows_from(&k, &v, 7, 7, 4).unwrap();
        let _ = c.sync_image();
        c.compact_accepted(&[1, 3]).unwrap();
        let _ = c.sync_image();
        let mut scr = FusedScratch::new();
        let pages = c.committed_pages();
        let ids: Vec<u64> = pages.iter().map(|p| p.id()).collect();
        let m = PackMember { page_ids: ids, prefix_len: c.committed, rows: 2 };
        let layout = PackedLayout::plan(&[m], 16, 4, 4).unwrap();
        scr.pack(&layout, &[pages], 2, 4).unwrap();
        let mask = layout.mask(4, &[None]).unwrap();
        assert_eq!(mask.dims, vec![4, 16]);
        force_enable_for_tests(false);
    }

    #[test]
    #[should_panic(expected = "hass-check[stamp]")]
    fn stamp_alias_is_caught() {
        let mk = |fill: f32| {
            std::sync::Arc::new(Page {
                id: 7,
                stamp: std::sync::atomic::AtomicU64::new(9),
                layers: 1,
                page_size: 2,
                k: vec![fill; 4],
                v: vec![fill; 4],
            })
        };
        note_pages(&[Some(mk(1.0))]);
        note_pages(&[Some(mk(2.0))]); // same (id, stamp), different bytes
    }

    #[test]
    #[should_panic(expected = "hass-check[image]")]
    fn image_corruption_is_caught() {
        let mut c = KvCache::with_page_size(1, 8, 1, 2, 4);
        let (k, v) = filled(1, 8, 2, 3.0);
        c.absorb(k, v, 8).unwrap();
        let _ = c.sync_image();
        if let Some(img) = c.image.as_mut() {
            img.k[3] += 0.5; // silent bit-flip in the staged image
        }
        check_image(&c.pages, c.image.as_ref().unwrap(), 1, 8, 4, 2);
    }

    #[test]
    #[should_panic(expected = "hass-check[mask]")]
    fn mask_overexposure_is_caught() {
        let m = PackMember { page_ids: vec![11], prefix_len: 3, rows: 2 };
        let layout = PackedLayout::plan(&[m], 12, 4, 4).unwrap();
        let mut mask = layout.mask(4, &[None]).unwrap();
        // padding slot 3 of the tail page must be visible to no one
        mask.data[3] = 1;
        check_mask(&layout, 4, &[None], &mask.data);
    }

    #[test]
    fn registry_check_is_silent_after_absorb() {
        force_enable_for_tests(true);
        let mut a = KvCache::with_page_size(1, 8, 1, 2, 4);
        let mut b = KvCache::with_page_size(1, 8, 1, 2, 4);
        let (k, v) = filled(1, 8, 2, 5.0);
        a.absorb(k.clone(), v.clone(), 8).unwrap();
        b.absorb(k, v, 8).unwrap(); // dedup hit: same prompt pages
        check_registry();
        force_enable_for_tests(false);
    }
}
