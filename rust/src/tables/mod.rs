//! Paper-table / figure regeneration harnesses (DESIGN.md §5 index).
//!
//! Each `table_N` / `figure_N` function reproduces the corresponding
//! table/figure of the paper on this repo's testbed: same methods, same
//! ablation grid, same metrics (τ, speedup, per-step α).  Speedups are
//! reported under both accountings of DESIGN.md §7 (modeled = the paper's
//! memory-bound regime; measured = honest CPU wall-clock).
//!
//! Ablation rows whose draft checkpoint has not been trained are skipped
//! with a note (train them with `python -m compile.train --stage <name>`).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use crate::engine::{build_method, calibrate, run_suite, CostModel, SuiteResult};
use crate::runtime::Runtime;
use crate::sampling::SampleParams;
use crate::spec::MethodCfg;
use crate::workload::{Workloads, SUITES, TRANSLATION_SUITES};

pub struct Harness {
    pub rt: Rc<Runtime>,
    pub wl: Workloads,
    pub cost: CostModel,
    pub n_prompts: usize,
    pub max_new: usize,
    cache: HashMap<String, SuiteResult>,
}

impl Harness {
    pub fn new(rt: Rc<Runtime>, wl: Workloads, n_prompts: usize, max_new: usize) -> Result<Harness> {
        let cost = calibrate(&rt, 24)?;
        eprintln!("[harness] calibrated t_ar = {:.2} ms/token", cost.t_ar * 1e3);
        Ok(Harness { rt, wl, cost, n_prompts, max_new, cache: HashMap::new() })
    }

    /// Evaluate (method, suite, temperature); results are cached per run.
    pub fn eval(&mut self, method: &str, cfg: &MethodCfg, suite: &str, temp: f32) -> Result<SuiteResult> {
        let key = format!("{method}|{:?}|{suite}|{temp}", (cfg.depth, cfg.total_tokens, &cfg.draft_ckpt));
        if let Some(r) = self.cache.get(&key) {
            return Ok(r.clone());
        }
        let prompts: Vec<String> = self.wl.suite(suite)?[..self.n_prompts.min(self.wl.suite(suite)?.len())].to_vec();
        let mut m = build_method(&self.rt, method, cfg)?;
        let params = SampleParams { temperature: temp, seed: 42, ..Default::default() };
        let r = run_suite(m.as_mut(), suite, &prompts, self.max_new, &params)?;
        self.cache.insert(key, r.clone());
        Ok(r)
    }

    /// (modeled speedup, measured speedup vs the cached vanilla run).
    pub fn speedups(&mut self, r: &SuiteResult, suite: &str, temp: f32) -> Result<(f64, f64)> {
        let vanilla = self.eval("vanilla", &MethodCfg::default(), suite, temp)?;
        let modeled = self.cost.modeled_speedup(&r.metrics, r.metrics.phases.host_s);
        let measured = (vanilla.wall_s / vanilla.tokens as f64) / (r.wall_s / r.tokens.max(1) as f64);
        Ok((modeled, measured))
    }

    /// Checkpoint availability (ablation rows degrade gracefully).
    pub fn have(&self, ckpt: &str) -> bool {
        self.rt.has_checkpoint(self.resolve(ckpt))
    }

    /// `hass_align3` (Table 4 protocol: continual from eagle) is the same
    /// configuration as the base `hass` checkpoint; fall back when the
    /// continual variant hasn't been trained.
    pub fn resolve<'a>(&self, ckpt: &'a str) -> &'a str {
        if ckpt == "hass_align3" && !self.rt.has_checkpoint(ckpt) {
            "hass"
        } else {
            ckpt
        }
    }
}

fn hdr(title: &str) {
    println!("\n=== {title} ===");
}

const TEMPS: [f32; 2] = [0.0, 1.0];

/// Methods rows of Tables 1/2 (greedy-only methods are skipped at T=1,
/// like the paper's PLD/Lookahead blanks).
fn table12_methods() -> Vec<(&'static str, bool)> {
    vec![
        ("pld", true),
        ("lookahead", true),
        ("sps", false),
        ("medusa", false),
        ("eagle", false),
        ("eagle2", false),
        ("hass", false),
    ]
}

/// Table 1: acceptance lengths τ.
pub fn table_1(h: &mut Harness) -> Result<()> {
    hdr("Table 1: acceptance lengths tau (paper: HASS 4.92-5.58 > EAGLE-2 by 8-16%)");
    println!("{:<12} {:<6} {:>9} {:>9} {:>9} {:>7}", "method", "T", "dialogue", "code", "math", "mean");
    for t in TEMPS {
        for (m, greedy_only) in table12_methods() {
            if greedy_only && t > 0.0 {
                continue;
            }
            let mut row = Vec::new();
            for s in SUITES {
                row.push(h.eval(m, &MethodCfg::default(), s, t)?.tau);
            }
            let mean = row.iter().sum::<f64>() / row.len() as f64;
            println!(
                "{:<12} {:<6} {:>9.2} {:>9.2} {:>9.2} {:>7.2}",
                m, t, row[0], row[1], row[2], mean
            );
        }
        println!();
    }
    Ok(())
}

/// Table 2: speedup ratios (modeled | measured).
pub fn table_2(h: &mut Harness) -> Result<()> {
    hdr("Table 2: speedup ratios, modeled/measured (paper: HASS 2.81x-4.05x)");
    println!(
        "{:<12} {:<4} {:>16} {:>16} {:>16} {:>10}",
        "method", "T", "dialogue", "code", "math", "mean(mod)"
    );
    for t in TEMPS {
        for (m, greedy_only) in table12_methods() {
            if greedy_only && t > 0.0 {
                continue;
            }
            let mut mods = Vec::new();
            let mut cells = Vec::new();
            for s in SUITES {
                let r = h.eval(m, &MethodCfg::default(), s, t)?;
                let (modeled, measured) = h.speedups(&r, s, t)?;
                mods.push(modeled);
                cells.push(format!("{modeled:>6.2}x/{measured:>5.2}x"));
            }
            let mean = mods.iter().sum::<f64>() / mods.len() as f64;
            println!(
                "{:<12} {:<4} {:>16} {:>16} {:>16} {:>9.2}x",
                m, t, cells[0], cells[1], cells[2], mean
            );
        }
        println!();
    }
    Ok(())
}

/// Figure 1: mean speedups bar data (derived from Table 2's grid).
pub fn figure_1(h: &mut Harness) -> Result<()> {
    hdr("Figure 1: mean modeled speedup across suites (bar-chart data)");
    for t in TEMPS {
        print!("T={t}: ");
        for (m, greedy_only) in table12_methods() {
            if greedy_only && t > 0.0 {
                continue;
            }
            let mut mods = Vec::new();
            for s in SUITES {
                let r = h.eval(m, &MethodCfg::default(), s, t)?;
                mods.push(h.speedups(&r, s, t)?.0);
            }
            print!("{m}={:.2}x ", mods.iter().sum::<f64>() / mods.len() as f64);
        }
        println!();
    }
    Ok(())
}

/// Table 3: harmonized objective distillation loss functions.
pub fn table_3(h: &mut Harness) -> Result<()> {
    hdr("Table 3: distillation loss functions, tau on dialogue (paper: Top-K best mean 4.92)");
    let rows = [
        ("Top-K Loss", "hass_align3"),
        ("Top-P Loss", "hass_topp"),
        ("Normed Top-K (Linear)", "hass_ntk_lin"),
        ("Normed Top-K (Softmax)", "hass_ntk_soft"),
        ("Bi-directional Top-K", "hass_bidir"),
        ("Recall@k Surrogate", "hass_recallk"),
        ("BiLD Loss", "hass_bild"),
    ];
    println!("{:<26} {:>7} {:>7} {:>7}", "loss", "T=0", "T=1", "mean");
    for (label, ckpt) in rows {
        if !h.have(ckpt) {
            println!("{label:<26} (checkpoint '{ckpt}' not trained; skipped)");
            continue;
        }
        let m = format!("hass:{}", h.resolve(ckpt));
        let a = h.eval(&m, &MethodCfg::default(), "dialogue", 0.0)?.tau;
        let b = h.eval(&m, &MethodCfg::default(), "dialogue", 1.0)?.tau;
        println!("{label:<26} {a:>7.2} {b:>7.2} {:>7.2}", (a + b) / 2.0);
    }
    Ok(())
}

/// Table 4: harmonized context alignment steps.
pub fn table_4(h: &mut Harness) -> Result<()> {
    hdr("Table 4: aligning steps (paper: align-3/4 best, align-5 declines)");
    let rows = [
        ("EAGLE-2 + Top-K", "eagle2_topk"),
        ("HASS Align-2", "hass_align2"),
        ("HASS Align-3", "hass_align3"),
        ("HASS Align-4", "hass_align4"),
        ("HASS Align-5", "hass_align5"),
    ];
    println!("{:<18} {:<4} {:>9} {:>9} {:>9} {:>7}", "variant", "T", "dialogue", "code", "math", "mean");
    for t in TEMPS {
        for (label, ckpt) in rows {
            if !h.have(ckpt) {
                println!("{label:<18} (checkpoint '{ckpt}' not trained; skipped)");
                continue;
            }
            let m = format!("hass:{}", h.resolve(ckpt));
            let mut row = Vec::new();
            for s in SUITES {
                row.push(h.eval(&m, &MethodCfg::default(), s, t)?.tau);
            }
            let mean = row.iter().sum::<f64>() / 3.0;
            println!(
                "{:<18} {:<4} {:>9.2} {:>9.2} {:>9.2} {:>7.2}",
                label, t, row[0], row[1], row[2], mean
            );
        }
        println!();
    }
    Ok(())
}

/// Table 5 + Figure 6: loss reweighting factor β.
pub fn table_5(h: &mut Harness) -> Result<()> {
    hdr("Table 5 / Figure 6: reweight factor beta (paper: beta=0.5 best)");
    let rows = [
        ("1.0 (Default)", "hass_align3"),
        ("0.7", "hass_beta07"),
        ("0.5", "hass_beta05"),
        ("0.3", "hass_beta03"),
    ];
    println!("{:<14} {:>7} {:>7} {:>7}   alphas(T=0)", "beta", "T=0", "T=1", "mean");
    for (label, ckpt) in rows {
        if !h.have(ckpt) {
            println!("{label:<14} (checkpoint '{ckpt}' not trained; skipped)");
            continue;
        }
        let m = format!("hass:{}", h.resolve(ckpt));
        let r0 = h.eval(&m, &MethodCfg::default(), "dialogue", 0.0)?;
        let r1 = h.eval(&m, &MethodCfg::default(), "dialogue", 1.0)?;
        let alphas: Vec<String> = r0.alphas.iter().take(6).map(|a| format!("{:.2}", a)).collect();
        println!(
            "{label:<14} {:>7.2} {:>7.2} {:>7.2}   [{}]",
            r0.tau,
            r1.tau,
            (r0.tau + r1.tau) / 2.0,
            alphas.join(" ")
        );
    }
    Ok(())
}

/// Figure 5: per-speculation-step acceptance rates, HASS vs EAGLE-2.
pub fn figure_5(h: &mut Harness) -> Result<()> {
    hdr("Figure 5: acceptance rate alpha per speculation step (dialogue)");
    for t in TEMPS {
        for m in ["eagle2", "hass"] {
            let r = h.eval(m, &MethodCfg::default(), "dialogue", t)?;
            let alphas: Vec<String> = r.alphas.iter().take(6).map(|a| format!("{:.3}", a)).collect();
            println!("T={t} {m:<8} alpha[0..6] = [{}]", alphas.join(", "));
        }
    }
    println!("(paper shape: HASS >= EAGLE-2 at later steps)");
    Ok(())
}

/// Figure 4 / Table 7: Top-K loss hyper-parameters K and w.
pub fn table_7(h: &mut Harness) -> Result<()> {
    hdr("Table 7 / Figure 4: Top-K loss K and w sweeps, tau mean over suites");
    let ks = [("K=1", "hass_k1"), ("K=5", "hass_k5"), ("K=10", "hass_align3"),
              ("K=50", "hass_k50"), ("K=100", "hass_k100")];
    let ws = [("w=0.0", "hass_w00"), ("w=0.1", "hass_w01"), ("w=0.2", "hass_w02"),
              ("w=0.5", "hass_w05"), ("w=1.0", "hass_align3"), ("w=2.0", "hass_w20")];
    for (name, rows) in [("K sweep (w=1.0)", &ks[..]), ("w sweep (K=10)", &ws[..])] {
        println!("-- {name}");
        for t in TEMPS {
            for (label, ckpt) in rows {
                if !h.have(ckpt) {
                    println!("  {label:<8} T={t} (not trained; skipped)");
                    continue;
                }
                let m = format!("hass:{}", h.resolve(ckpt));
                let mut taus = Vec::new();
                let mut mods = Vec::new();
                for s in SUITES {
                    let r = h.eval(&m, &MethodCfg::default(), s, t)?;
                    mods.push(h.speedups(&r, s, t)?.0);
                    taus.push(r.tau);
                }
                println!(
                    "  {label:<8} T={t}  tau={:.2}  speedup={:.2}x",
                    taus.iter().sum::<f64>() / 3.0,
                    mods.iter().sum::<f64>() / 3.0
                );
            }
        }
    }
    Ok(())
}

/// Table 6 / Figure 7: feature vs token alignment.
pub fn table_6(h: &mut Harness) -> Result<()> {
    hdr("Table 6 / Figure 7: token alignment hurts (paper: feature-only best)");
    let rows = [
        ("EAGLE-2", "eagle".to_string(), "eagle2"),
        ("Feature Only", "hass_featonly".to_string(), "hass"),
        ("Feature+Token(0.1)", "hass_tok01".to_string(), "hass"),
        ("Feature+Token(0.2)", "hass_tok02".to_string(), "hass"),
        ("Feature+Token(1.0)", "hass_tok10".to_string(), "hass"),
    ];
    println!("{:<20} {:>7} {:>7} {:>7}", "variant", "T=0", "T=1", "mean");
    for (label, ckpt, base) in rows {
        if !h.have(&ckpt) {
            println!("{label:<20} (checkpoint '{ckpt}' not trained; skipped)");
            continue;
        }
        let m = if base == "eagle2" { "eagle2".to_string() } else { format!("hass:{ckpt}") };
        let a = h.eval(&m, &MethodCfg::default(), "dialogue", 0.0)?.tau;
        let b = h.eval(&m, &MethodCfg::default(), "dialogue", 1.0)?.tau;
        println!("{label:<20} {a:>7.2} {b:>7.2} {:>7.2}", (a + b) / 2.0);
    }
    Ok(())
}

/// Table 8: self-distillation (fixed vs model-generated training data).
pub fn table_8(h: &mut Harness) -> Result<()> {
    hdr("Table 8: self-distillation (paper: MG helps HASS on 7B, tau +0.3)");
    let rows = [
        ("EAGLE-2  F", "eagle2".to_string()),
        ("EAGLE-2  MG", "eagle2:eagle_mg".to_string()),
        ("HASS     F", "hass".to_string()),
        ("HASS     MG", "hass:hass_mg".to_string()),
    ];
    println!("{:<14} {:<4} {:>9} {:>9} {:>9} {:>7}", "method/data", "T", "dialogue", "code", "math", "mean");
    for t in TEMPS {
        for (label, m) in &rows {
            let ckpt = m.split(':').last().unwrap();
            let need = if m.contains(':') { ckpt } else if m == "hass" { "hass" } else { "eagle" };
            if !h.have(need) {
                println!("{label:<14} (checkpoint '{need}' not trained; skipped)");
                continue;
            }
            let mut row = Vec::new();
            for s in SUITES {
                row.push(h.eval(m, &MethodCfg::default(), s, t)?.tau);
            }
            println!(
                "{:<14} {:<4} {:>9.2} {:>9.2} {:>9.2} {:>7.2}",
                label, t, row[0], row[1], row[2],
                row.iter().sum::<f64>() / 3.0
            );
        }
        println!();
    }
    Ok(())
}

/// Table 9: drafting hyper-parameters (tree depth × total tokens).
pub fn table_9(h: &mut Harness) -> Result<()> {
    hdr("Table 9: dynamic-tree depth x #tokens, modeled speedup on dialogue (paper: depth 6-8, 60-100 best)");
    println!("{:<8} {:>8} {:>8} {:>8} {:>8}", "depth", "#40", "#60", "#80", "#100");
    for t in TEMPS {
        println!("T={t}  (hass)");
        for depth in [5usize, 6, 7, 8, 9] {
            print!("{depth:<8}");
            for total in [40usize, 60, 80, 100] {
                let cfg = MethodCfg { depth, total_tokens: total, ..Default::default() };
                let r = h.eval("hass", &cfg, "dialogue", t)?;
                let (modeled, _) = h.speedups(&r, "dialogue", t)?;
                print!(" {modeled:>7.2}x");
            }
            println!();
        }
    }
    Ok(())
}

/// Table 10 / Figure 8: training-data proportion scaling.
pub fn table_10(h: &mut Harness) -> Result<()> {
    hdr("Table 10 / Figure 8: training-data proportions (paper: HASS@1/4 ~ EAGLE-2@1/1)");
    let rows = [
        ("EAGLE-2 1/8", "eagle2:eagle_p8"), ("EAGLE-2 1/4", "eagle2:eagle_p4"),
        ("EAGLE-2 1/2", "eagle2:eagle_p2"), ("EAGLE-2 1/1", "eagle2"),
        ("HASS    1/8", "hass:hass_p8"), ("HASS    1/4", "hass:hass_p4"),
        ("HASS    1/2", "hass:hass_p2"), ("HASS    1/1", "hass"),
    ];
    println!("{:<14} {:<4} {:>7} {:>11}", "variant", "T", "tau", "speedup");
    for t in TEMPS {
        for (label, m) in rows {
            let ckpt = m.split(':').last().unwrap();
            let need = if m.contains(':') { ckpt } else if m == "hass" { "hass" } else { "eagle" };
            if !h.have(need) {
                println!("{label:<14} (checkpoint '{need}' not trained; skipped)");
                continue;
            }
            let mut taus = Vec::new();
            let mut mods = Vec::new();
            for s in SUITES {
                let r = h.eval(m, &MethodCfg::default(), s, t)?;
                mods.push(h.speedups(&r, s, t)?.0);
                taus.push(r.tau);
            }
            println!(
                "{label:<14} {t:<4} {:>7.2} {:>10.2}x",
                taus.iter().sum::<f64>() / 3.0,
                mods.iter().sum::<f64>() / 3.0
            );
        }
        println!();
    }
    Ok(())
}

/// Table 11: translation suites.
pub fn table_11(h: &mut Harness) -> Result<()> {
    hdr("Table 11: translation stand-ins (paper: HASS > EAGLE-2 by 8-14% tau)");
    println!("{:<8} {:<4} {}", "method", "T", TRANSLATION_SUITES.join("     "));
    for t in TEMPS {
        for m in ["eagle2", "hass"] {
            print!("{m:<8} {t:<4}");
            let mut mean = 0.0;
            for s in TRANSLATION_SUITES {
                let tau = h.eval(m, &MethodCfg::default(), s, t)?.tau;
                mean += tau;
                print!(" {tau:>5.2}");
            }
            println!("   mean {:.2}", mean / TRANSLATION_SUITES.len() as f64);
        }
    }
    Ok(())
}

/// Dispatch by paper table number.
pub fn run_table(h: &mut Harness, id: &str) -> Result<()> {
    match id {
        // "main" shares one result cache across Tables 1+2 and Figs 1+5
        "main" => {
            table_1(h)?;
            table_2(h)?;
            figure_1(h)?;
            figure_5(h)
        }
        // "rest": every ablation table in one process (shared cache)
        "rest" => {
            for t in ["3", "4", "5", "6", "7", "8", "9", "10", "11"] {
                if let Err(e) = run_table(h, t) {
                    println!("table {t}: {e:#}");
                }
            }
            Ok(())
        }
        "1" => table_1(h),
        "2" => table_2(h),
        "3" => table_3(h),
        "4" => table_4(h),
        "5" => table_5(h),
        "6" => table_6(h),
        "7" => table_7(h),
        "8" => table_8(h),
        "9" => table_9(h),
        "10" => table_10(h),
        "11" => table_11(h),
        other => anyhow::bail!("unknown table '{other}' (1-11)"),
    }
}

pub fn run_figure(h: &mut Harness, id: &str) -> Result<()> {
    match id {
        "1" => figure_1(h),
        "4" => table_7(h),
        "5" => figure_5(h),
        "6" => table_5(h),
        "7" => table_6(h),
        "8" => table_10(h),
        other => anyhow::bail!("figure '{other}' not mapped (1,4,5,6,7,8; 9-11 are python-side: make fig9-11)"),
    }
}
