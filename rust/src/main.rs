//! `hass` — CLI for the HASS speculative-serving reproduction.
//!
//! Subcommands:
//!   generate   --method hass --prompt "..." [--tokens 64 --temp 0.0]
//!   compare    [--tokens 48 --temp 0.0]      run every method on one prompt
//!   table <N>  [--prompts 8 --tokens 48]     regenerate paper table N (1-11)
//!   figure <N>                               regenerate paper figure N
//!   serve      [--port 7777 --queue 64 --workers 1 --max-active 2]
//!                                            TCP JSON-lines server; each worker
//!                                            interleaves up to --max-active jobs
//!   client     --prompt "..." [--addr ... --stats --stream --deadline-ms N
//!                              --priority N --retries N]
//!                                            one-shot request to a server
//!                                            (--stats fetches pool counters,
//!                                             --stream prints per-cycle deltas,
//!                                             --retries N retries overloaded/
//!                                             worker_lost with jittered backoff)
//!   analyze    [paths...]                    run the in-repo lint (hass-analyze)
//!                                            over rust/src (default) or paths
//!   goldens                                  verify vs python goldens
//!   calibrate                                measure the device cost model
//!   stats      --method hass                 per-graph call-time breakdown

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Result};

use hass::engine::{build_method, calibrate, generate_once, run_suite};
use hass::runtime::Runtime;
use hass::sampling::SampleParams;
use hass::scheduler::Scheduler;
use hass::spec::{GenRequest, MethodCfg};
use hass::tables::{run_figure, run_table, Harness};
use hass::tokenizer;
use hass::util::cli::Args;
use hass::workload::Workloads;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn method_cfg(args: &Args) -> MethodCfg {
    MethodCfg {
        draft_ckpt: args.get_or("ckpt", "hass"),
        depth: args.usize_or("depth", 6),
        total_tokens: args.usize_or("total", 60),
        beam: args.usize_or("beam", 10),
        gamma: args.usize_or("gamma", 4),
        lookup_len: args.usize_or("lookup-len", 5),
    }
}

fn params(args: &Args) -> SampleParams {
    SampleParams {
        temperature: args.f64_or("temp", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        top_p: args.f64_or("top-p", 1.0) as f32,
        seed: args.usize_or("seed", 0) as u64,
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "generate" => {
            let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
            let prompt = args.get_or("prompt", "User: Can you tell me about chess openings?\nAssistant:");
            let method = args.get_or("method", "hass");
            let (text, out) = generate_once(
                &rt, &method, &method_cfg(args), &prompt,
                args.usize_or("tokens", 64), &params(args),
            )?;
            println!("--- prompt ---\n{prompt}\n--- completion ({}) ---\n{text}", method);
            println!(
                "\ntau={:.2}  cycles={}  target_calls={}  draft_calls={}  alphas={:?}",
                out.metrics.tau(), out.metrics.cycles, out.metrics.target_calls,
                out.metrics.draft_calls,
                out.metrics.alphas(6).iter().map(|a| (a * 100.0).round() / 100.0).collect::<Vec<_>>()
            );
            Ok(())
        }
        "compare" => {
            let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
            let prompt = args.get_or("prompt", "User: Can you tell me about chess openings?\nAssistant:");
            let p = params(args);
            println!("{:<12} {:>6} {:>8} {:>9} {:>9}", "method", "tau", "tokens", "t_call", "d_call");
            for m in ["vanilla", "pld", "lookahead", "sps", "medusa", "eagle", "eagle2", "hass"] {
                match generate_once(&rt, m, &method_cfg(args), &prompt, args.usize_or("tokens", 48), &p) {
                    Ok((_, out)) => println!(
                        "{m:<12} {:>6.2} {:>8} {:>9} {:>9}",
                        out.metrics.tau(), out.tokens.len(),
                        out.metrics.target_calls, out.metrics.draft_calls
                    ),
                    Err(e) => println!("{m:<12} failed: {e:#}"),
                }
            }
            Ok(())
        }
        "table" | "figure" => {
            let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
            let wl = Workloads::load(&hass::artifact_dir())?;
            let mut h = Harness::new(
                rt, wl,
                args.usize_or("prompts", 8),
                args.usize_or("tokens", 48),
            )?;
            let id = args.positionals.first().map(String::as_str).unwrap_or("1");
            if args.subcommand == "table" {
                run_table(&mut h, id)
            } else {
                run_figure(&mut h, id)
            }
        }
        "serve" => {
            let port = args.usize_or("port", 7777);
            let sched = Arc::new(Scheduler::start(
                hass::artifact_dir(),
                method_cfg(args),
                args.usize_or("queue", 64),
                args.usize_or("workers", 1),
                args.usize_or("max-active", 2),
            ));
            let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))?;
            hass::server::serve(listener, sched)
        }
        "client" => {
            let addr = args.get_or("addr", "127.0.0.1:7777");
            let mut c = hass::server::Client::connect(&addr)?;
            if args.has("stats") {
                let stats = c.stats()?;
                println!("{stats}");
                // headline batch occupancy (fused cross-session verification)
                if let Some(agg) = stats.get("stats").and_then(|s| s.get("aggregate")) {
                    println!(
                        "batch occupancy: fused={} solo={} mean_rows_per_fused={}",
                        agg.usize_at("fused_calls").unwrap_or(0),
                        agg.usize_at("solo_calls").unwrap_or(0),
                        agg.f64_at("mean_fused_rows").unwrap_or(0.0),
                    );
                    println!(
                        "draft batching: fused={} solo={} mean_rows_per_fused={}",
                        agg.usize_at("draft_fused_calls").unwrap_or(0),
                        agg.usize_at("draft_solo_calls").unwrap_or(0),
                        agg.f64_at("mean_draft_fused_rows").unwrap_or(0.0),
                    );
                    println!(
                        "paged kv: pack_pages_copied={} pack_pages_reused={} shared_pages={} \
                         draft_pack_copied={} draft_pack_reused={}",
                        agg.usize_at("pack_pages_copied").unwrap_or(0),
                        agg.usize_at("pack_pages_reused").unwrap_or(0),
                        agg.usize_at("shared_pages").unwrap_or(0),
                        agg.usize_at("draft_pack_pages_copied").unwrap_or(0),
                        agg.usize_at("draft_pack_pages_reused").unwrap_or(0),
                    );
                    println!(
                        "routing: affinity_hits={} affinity_misses={} \
                         cross_worker_shared_pages={} registry_entries={} \
                         registry_evictions={}",
                        agg.usize_at("affinity_hits").unwrap_or(0),
                        agg.usize_at("affinity_misses").unwrap_or(0),
                        agg.usize_at("cross_worker_shared_pages").unwrap_or(0),
                        agg.usize_at("registry_entries").unwrap_or(0),
                        agg.usize_at("registry_evictions").unwrap_or(0),
                    );
                    println!(
                        "occupancy: busy_ms={} idle_ms={}",
                        agg.f64_at("busy_ms").unwrap_or(0.0),
                        agg.f64_at("idle_ms").unwrap_or(0.0),
                    );
                    println!(
                        "overload: admission_rejects={} preemptions={} resumes={} \
                         breaker_trips={} live_pages={} free_pages={} page_budget={}",
                        agg.usize_at("admission_rejects").unwrap_or(0),
                        agg.usize_at("preemptions").unwrap_or(0),
                        agg.usize_at("resumes").unwrap_or(0),
                        agg.usize_at("breaker_trips").unwrap_or(0),
                        agg.usize_at("live_pages").unwrap_or(0),
                        agg.usize_at("free_pages").unwrap_or(0),
                        agg.usize_at("page_budget").unwrap_or(0),
                    );
                    println!(
                        "slo: mean_queue_wait_ms={} mean_ttft_ms={}",
                        agg.f64_at("mean_queue_wait_ms").unwrap_or(0.0),
                        agg.f64_at("mean_ttft_ms").unwrap_or(0.0),
                    );
                    println!(
                        "robustness: worker_deaths={} requeues={} replays={} \
                         mean_recovery_ms={}",
                        agg.usize_at("worker_deaths").unwrap_or(0),
                        agg.usize_at("requeues").unwrap_or(0),
                        agg.usize_at("replays").unwrap_or(0),
                        agg.f64_at("mean_recovery_ms").unwrap_or(0.0),
                    );
                }
                // per-point fault-injection trigger counters (non-zero
                // only; empty outside HASS_FAULTS runs)
                if let Some(fp) = stats.get("stats").and_then(|s| s.get("failpoints")) {
                    println!("failpoints: {fp}");
                }
                return Ok(());
            }
            let opts = hass::server::ReqOpts {
                method: args.get_or("method", "hass"),
                max_tokens: args.usize_or("tokens", 64),
                temperature: args.f64_or("temp", 0.0) as f32,
                seed: args.usize_or("seed", 0) as u64,
                stream: args.has("stream"),
                deadline_ms: args.u64_opt("deadline-ms"),
                priority: args.usize_or("priority", 0).min(u8::MAX as usize) as u8,
            };
            let prompt =
                args.get_or("prompt", "User: How does photosynthesis work?\nAssistant:");
            let streaming = opts.stream;
            let retries = args.usize_or("retries", 0);
            let resp = c.generate_with_retry(&prompt, &opts, retries, |delta| {
                print!("{delta}");
                let _ = std::io::Write::flush(&mut std::io::stdout());
            })?;
            if streaming {
                println!();
            }
            println!("{resp}");
            Ok(())
        }
        "analyze" => {
            // forward the analyzer flags; paths default inside run_cli
            let mut argv: Vec<String> = Vec::new();
            for flag in ["format", "baseline"] {
                let v = args.get_or(flag, "");
                if !v.is_empty() {
                    argv.push(format!("--{flag}={v}"));
                }
            }
            if args.has("update-baseline") {
                argv.push("--update-baseline".to_string());
            }
            argv.extend(args.positionals.iter().cloned());
            let code = hass_analyze::run_cli(&argv);
            if code != 0 {
                bail!("hass-analyze found violations (exit {code})");
            }
            Ok(())
        }
        "goldens" => {
            let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
            let goldens = rt.meta().goldens.clone();
            if goldens.is_empty() {
                bail!("no goldens in artifacts/meta.json (re-run `make artifacts` after training)");
            }
            let mut m = build_method(&rt, "vanilla", &MethodCfg::default())?;
            let mut failures = 0;
            for (i, g) in goldens.iter().enumerate() {
                let req = GenRequest {
                    prompt_tokens: g.prompt_tokens.clone(),
                    max_new: g.greedy_tokens.len(),
                    params: SampleParams { temperature: 0.0, ..Default::default() },
                };
                let out = m.generate(&req)?;
                let want = &g.greedy_tokens[..out.tokens.len().min(g.greedy_tokens.len())];
                if out.tokens != want {
                    failures += 1;
                    println!("golden {i}: MISMATCH\n  rust:   {:?}\n  python: {:?}", out.tokens, want);
                } else {
                    println!("golden {i}: OK ({} tokens) -> {:?}", out.tokens.len(),
                             tokenizer::decode(&out.tokens));
                }
            }
            if failures > 0 {
                bail!("{failures} golden(s) failed");
            }
            Ok(())
        }
        "calibrate" => {
            let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
            let cm = calibrate(&rt, 32)?;
            println!(
                "t_ar = {:.3} ms/token  (modeled: verify={:.2}x AR, draft={:.2}x AR)",
                cm.t_ar * 1e3, cm.verify_factor, cm.draft_ratio
            );
            Ok(())
        }
        "stats" => {
            let rt = Rc::new(Runtime::new(&hass::artifact_dir())?);
            let wl = Workloads::load(&hass::artifact_dir())?;
            let method = args.get_or("method", "hass");
            let mut m = build_method(&rt, &method, &method_cfg(args))?;
            let prompts = wl.suite("dialogue")?[..4.min(wl.suite("dialogue")?.len())].to_vec();
            let r = run_suite(m.as_mut(), "dialogue", &prompts, args.usize_or("tokens", 48), &params(args))?;
            println!("method={} tau={:.2} tok/s={:.1}", r.method, r.tau, r.tok_per_s);
            println!("phases: draft={:.3}s verify={:.3}s sample={:.3}s host={:.3}s",
                     r.metrics.phases.draft_s, r.metrics.phases.verify_s,
                     r.metrics.phases.sample_s, r.metrics.phases.host_s);
            println!("\nper-graph call stats:");
            for (g, s) in rt.call_stats() {
                println!(
                    "  {g:<22} calls={:>6}  rows/call={:>6.1}  total={:>8.3}s  mean={:>7.3}ms",
                    s.calls, s.rows_per_call(), s.secs,
                    s.secs / s.calls.max(1) as f64 * 1e3);
            }
            Ok(())
        }
        "" | "help" => {
            println!(
                "usage: hass <generate|compare|table N|figure N|serve|client|analyze|goldens|calibrate|stats> [flags]"
            );
            println!("see rust/src/main.rs header for flags; artifacts from `make artifacts`.");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try: hass help)"),
    }
}
