//! Feature-level speculative sampling: EAGLE (static tree), EAGLE-2
//! (dynamic tree), and HASS (EAGLE-2 decoding + HASS-trained checkpoint —
//! the paper's point is that *decoding is identical*, all gains come from
//! harmonized training).
//!
//! Per cycle (one `step` call):
//!   1. **commit call** — the tokens accepted last cycle (+ bonus) run
//!      through the draft net with their *target* features (now known from
//!      verification), writing committed draft-KV rows; the last row's
//!      output doubles as the tree root's feature + child distribution.
//!   2. **tree expansion** — up to `depth-1` further draft calls; EAGLE-2
//!      keeps a global top-`beam` frontier by cumulative log-prob, EAGLE
//!      follows the fixed template.  Draft-KV rows for tree nodes live in
//!      a scratch region above the committed boundary, visible only via
//!      per-node ancestor masks.
//!   3. **rerank** (dynamic only): keep the best `total_tokens` nodes
//!      (ancestor-closed), flatten BFS.
//!   4. **verify** — one target call over the block; lossless acceptance
//!      walk; accepted rows compact into the target cache.
//!
//! Since PR 5 the commit+expand loop is a resumable per-level walk
//! ([`DraftWalk`], driven through `Method::draft_next`/`draft_feed`) so a
//! scheduler can fuse the same level of many co-active sessions into ONE
//! `draft_decode` call; `plan` drives any unfinished walk to completion
//! solo, which is also the fused-failure fallback.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::engine::sessions::{DraftSession, TargetSession};
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{log_softmax, process_logits, sample_token, topk};
use crate::spec::{
    accept_walk, DraftPhase, DraftRows, GenRequest, GenState, Method, StepOutcome, StepPlan,
    VerifyOut, VerifyRows,
};
use crate::tree::{eagle_static_template, Tree, VerifyPlan};
use crate::util::stats::Stopwatch;

#[derive(Clone, Copy, PartialEq)]
pub enum TreeKind {
    /// EAGLE-1 fixed template
    Static,
    /// EAGLE-2 / HASS dynamic tree
    Dynamic,
}

pub struct Eagle {
    label: String,
    target: TargetSession,
    draft: DraftSession,
    kind: TreeKind,
    template: Vec<Vec<usize>>,
    pub depth: usize,
    pub beam: usize,
    pub total_tokens: usize,
}

/// Per-session carry-over between draft-expand-verify cycles.
struct EagleState {
    /// tokens emitted last cycle, paired with their parents' features —
    /// the next cycle's commit rows
    pending_tokens: Vec<i32>,
    pending_feats: Vec<Vec<f32>>,
    /// the in-progress draft tree build (one cycle's commit + expansion),
    /// resumable level by level so a scheduler can fuse levels across
    /// sessions
    walk: Option<DraftWalk>,
    /// the tree `plan` flattened for verification, awaiting `absorb`
    pending_plan: Option<VerifyPlan>,
}

struct NodeInfo {
    /// draft feature g (known once the node has been expanded)
    g: Option<Vec<f32>>,
    /// draft-cache slot (for expanded nodes)
    slot: Option<usize>,
    /// scratch slots of non-committed ancestors (excludes the root)
    anc_slots: Vec<usize>,
    /// rank path (static template bookkeeping)
    path: Vec<usize>,
}

/// Resumable state of one cycle's draft-tree build.  Level 0 is the
/// commit call (pending tokens + root expansion); levels `1..depth` are
/// frontier expansions.  `pending` holds the rows `draft_next` emitted
/// but `draft_feed` has not consumed — `draft_next` is idempotent while
/// it is set, so a fused executor that fails can walk away and the solo
/// drive resumes from the same rows.
struct DraftWalk {
    tree: Tree,
    info: Vec<NodeInfo>,
    frontier: Vec<usize>,
    /// sequence position of the tree root
    base_pos: usize,
    /// next level to feed (0 = commit call)
    level: usize,
    /// scratch watermark: slot where the next level's rows land (levels
    /// pack densely — `beam > block` chunks into extra calls instead of
    /// overlapping a fixed stride)
    watermark: usize,
    pending: Option<PendingLevel>,
    /// tree complete; `plan` emits the verify rows
    ready: bool,
}

struct PendingLevel {
    rows: DraftRows,
    /// tree nodes the rows expand (empty for the commit level)
    expand: Vec<usize>,
}

/// Children for a static-template node as (template rank, draft log-prob,
/// token) triples.  Ranks the vocabulary cannot fill are skipped: the old
/// `ordered[r]` indexing panicked whenever `topk` returned fewer than
/// `max_rank + 1` entries (vocab smaller than the template fan-out).
pub fn static_tree_children(
    sm: &[f32],
    parent_path: &[usize],
    template: &[Vec<usize>],
) -> Vec<(usize, f32, i32)> {
    let mut ranks: Vec<usize> = template
        .iter()
        .filter(|p| p.len() == parent_path.len() + 1 && p[..parent_path.len()] == parent_path[..])
        .map(|p| p[parent_path.len()])
        .collect();
    ranks.sort_unstable();
    ranks.dedup();
    let ordered = topk(sm, ranks.iter().max().map_or(0, |m| m + 1));
    ranks
        .into_iter()
        .filter_map(|r| ordered.get(r).map(|&(lp, tok)| (r, lp, tok as i32)))
        .collect()
}

/// Widest level of a rank-path template: the most nodes any single
/// expansion level can feed through the draft net (level l expands nodes
/// whose path length is l, of which the template holds at most
/// `|{paths of length l}|`).
fn template_level_width(template: &[Vec<usize>]) -> usize {
    let mut counts: Vec<usize> = Vec::new();
    for p in template {
        let l = p.len();
        if counts.len() <= l {
            counts.resize(l + 1, 0);
        }
        counts[l] += 1;
    }
    counts.into_iter().max().unwrap_or(1).max(1)
}

/// Expand `parent`'s children from its draft logits into the tree
/// (dynamic: top-`beam`; static: template ranks), with per-node ancestor
/// slot bookkeeping.
fn add_children(
    tree: &mut Tree,
    info: &mut Vec<NodeInfo>,
    parent: usize,
    logits: &[f32],
    kind: TreeKind,
    template: &[Vec<usize>],
    beam: usize,
) {
    let sm = log_softmax(logits);
    match kind {
        TreeKind::Dynamic => {
            for (lp, tok) in topk(&sm, beam) {
                let _idx = tree.add_child(parent, tok as i32, lp);
                let mut anc = info[parent].anc_slots.clone();
                if let Some(s) = info[parent].slot {
                    anc.push(s);
                }
                info.push(NodeInfo { g: None, slot: None, anc_slots: anc, path: vec![] });
            }
        }
        TreeKind::Static => {
            let ppath = info[parent].path.clone();
            for (r, lp, tok) in static_tree_children(&sm, &ppath, template) {
                let _idx = tree.add_child(parent, tok, lp);
                let mut anc = info[parent].anc_slots.clone();
                if let Some(s) = info[parent].slot {
                    anc.push(s);
                }
                let mut path = ppath.clone();
                path.push(r);
                info.push(NodeInfo { g: None, slot: None, anc_slots: anc, path });
            }
        }
    }
}

/// Construct an EAGLE-family method (static or dynamic tree).
#[allow(clippy::too_many_arguments)]
pub fn build_eagle(
    rt: Rc<Runtime>,
    target_w: Rc<Checkpoint>,
    draft_w: Rc<Checkpoint>,
    kind: TreeKind,
    label: &str,
    depth: usize,
    beam: usize,
    total_tokens: usize,
) -> Result<Eagle> {
    let draft = DraftSession::new(rt.clone(), draft_w, &target_w)?;
    Ok(Eagle {
        label: label.to_string(),
        target: TargetSession::new(rt, target_w)?,
        draft,
        kind,
        template: eagle_static_template(),
        depth,
        beam,
        total_tokens,
    })
}

impl Method for Eagle {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let plen = req.prompt_tokens.len();
        self.target.reset();
        self.draft.reset();

        let mut state = GenState::new(
            req,
            EagleState {
                pending_tokens: Vec::new(),
                pending_feats: Vec::new(),
                walk: None,
                pending_plan: None,
            },
        );
        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;
        let sw = Stopwatch::start();
        self.draft.prefill(&req.prompt_tokens, &self.target.feats)?;
        state.metrics.phases.draft_s += sw.secs();
        state.metrics.draft_calls += 1;

        let probs = process_logits(&last_logits, &req.params);
        let first = sample_token(&probs, &mut state.rng) as i32;
        state.tokens.push(first);
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("fresh eagle state")?;
        inner.pending_tokens = vec![first];
        inner.pending_feats = vec![self.target.feats[plen - 1].clone()];
        state.clamp();
        Ok(state)
    }

    fn fused_handle(&mut self) -> Option<&mut TargetSession> {
        Some(&mut self.target)
    }

    fn draft_handle(&mut self) -> Option<&mut DraftSession> {
        Some(&mut self.draft)
    }

    /// Next draft-tree level: the commit call (level 0, which opens the
    /// walk behind a capacity gate), or a frontier expansion.  Idempotent
    /// while a level is pending.
    fn draft_next(&mut self, state: &mut GenState) -> Result<DraftPhase> {
        let block = self.draft.block;
        // per-level row ceiling: the dynamic beam (chunked when it
        // exceeds the widest artifact), or the template's widest level —
        // NOT the widest artifact, which over-reserves the capacity gate
        // by an order of magnitude once wide draft blocks are compiled
        let lvl_cap = match self.kind {
            TreeKind::Dynamic => self.beam.max(1),
            TreeKind::Static => template_level_width(&self.template).min(block),
        };
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("eagle draft on a foreign GenState")?;
        if let Some(w) = inner.walk.as_mut() {
            if let Some(p) = &w.pending {
                return Ok(DraftPhase::Rows(p.rows.clone()));
            }
            if w.ready || w.level >= self.depth {
                w.ready = true;
                return Ok(DraftPhase::Ready);
            }
            // choose which frontier nodes to run through the draft net
            let expand: Vec<usize> = match self.kind {
                TreeKind::Dynamic => w.tree.select_beam(&w.frontier, self.beam),
                TreeKind::Static => w
                    .frontier
                    .iter()
                    .copied()
                    .filter(|&n| {
                        let p = &w.info[n].path;
                        self.template
                            .iter()
                            .any(|t| t.len() == p.len() + 1 && t[..p.len()] == p[..])
                    })
                    .take(block)
                    .collect(),
            };
            if expand.is_empty() {
                w.ready = true;
                return Ok(DraftPhase::Ready);
            }
            let tokens: Vec<i32> = expand.iter().map(|&n| w.tree.nodes[n].token).collect();
            let feats: Vec<Vec<f32>> = expand
                .iter()
                .map(|&n| {
                    let parent = w.tree.nodes[n].parent.expect("non-root node has a parent");
                    w.info[parent].g.clone().expect("parent expanded")
                })
                .collect();
            let positions: Vec<usize> =
                expand.iter().map(|&n| w.base_pos + w.tree.nodes[n].depth).collect();
            let extra: Vec<Vec<usize>> =
                expand.iter().map(|&n| w.info[n].anc_slots.clone()).collect();
            let rows = DraftRows {
                tokens,
                feats,
                positions,
                extra_visible: extra,
                write_start: w.watermark,
            };
            w.pending = Some(PendingLevel { rows: rows.clone(), expand });
            return Ok(DraftPhase::Rows(rows));
        }

        // ---- open a new walk: capacity gate + the commit level ----
        // the verify call consumes a full padded decode block of cache
        // slots, so capacity is checked against that, not the raw rows
        let rows_max = (self.total_tokens + 1).max(self.template.len() + 1);
        let verify_n = crate::engine::sessions::padded_span(rows_max);
        let pending = inner.pending_tokens.len();
        // the widest single draft call this cycle (commit rows or one
        // level) is padded to its compiled width; every earlier call's
        // rows land densely below it, so this is the only padding the
        // gate must reserve
        let pad = crate::engine::sessions::pick_width(
            self.draft.widths(),
            lvl_cap.max(pending).min(block),
        )
        .unwrap_or(block);
        if state.done
            || self.target.cache.remaining() < verify_n + 2
            || self.draft.remaining() < pending + self.depth * lvl_cap + pad + 2
        {
            state.finish();
            return Ok(DraftPhase::Finished(StepOutcome { emitted: 0, done: true }));
        }
        let plen = state.req.prompt_tokens.len();
        let last = *state.tokens.last().context("session has no tokens")?;
        let k = inner.pending_tokens.len();
        let write_start = self.draft.committed();
        let base_pos = plen + state.tokens.len() - 1; // seq position of the root
        let positions: Vec<usize> = (0..k).map(|i| base_pos + 1 + i - k).collect();
        let extra: Vec<Vec<usize>> =
            (0..k).map(|i| (write_start..write_start + i).collect()).collect();
        let rows = DraftRows {
            tokens: inner.pending_tokens.clone(),
            feats: inner.pending_feats.clone(),
            positions,
            extra_visible: extra,
            write_start,
        };
        inner.walk = Some(DraftWalk {
            tree: Tree::new(last),
            info: vec![NodeInfo { g: None, slot: None, anc_slots: vec![], path: vec![] }],
            frontier: Vec::new(),
            base_pos,
            level: 0,
            watermark: write_start,
            pending: Some(PendingLevel { rows: rows.clone(), expand: Vec::new() }),
            ready: false,
        });
        Ok(DraftPhase::Rows(rows))
    }

    /// Absorb one executed level: level 0 commits the pending rows and
    /// roots the tree, later levels expand their frontier nodes.  The
    /// executor (solo or fused) already wrote the level's KV rows.
    fn draft_feed(&mut self, state: &mut GenState, out: &VerifyOut) -> Result<()> {
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("eagle draft_feed on a foreign GenState")?;
        let w = inner.walk.as_mut().context("eagle draft_feed without a walk")?;
        let p = w.pending.take().context("eagle draft_feed without pending rows")?;
        if w.level == 0 {
            let k = p.rows.tokens.len();
            self.draft.commit(k)?;
            w.info[0].g = Some(out.feats.row(k - 1).to_vec());
            // root slot stays None: committed -> visible via the committed
            // prefix mask
            add_children(
                &mut w.tree,
                &mut w.info,
                0,
                out.logits.row(k - 1),
                self.kind,
                &self.template,
                self.beam,
            );
            w.frontier = (1..w.tree.len()).collect();
            w.watermark = self.draft.committed();
        } else {
            let mut next_frontier = Vec::new();
            for (i, &n) in p.expand.iter().enumerate() {
                w.info[n].g = Some(out.feats.row(i).to_vec());
                w.info[n].slot = Some(p.rows.write_start + i);
                let before = w.tree.len();
                add_children(
                    &mut w.tree,
                    &mut w.info,
                    n,
                    out.logits.row(i),
                    self.kind,
                    &self.template,
                    self.beam,
                );
                next_frontier.extend(before..w.tree.len());
            }
            w.frontier = next_frontier;
            w.watermark = p.rows.write_start + p.expand.len();
        }
        w.level += 1;
        if w.level >= self.depth {
            w.ready = true;
        }
        state.metrics.draft_calls += 1;
        Ok(())
    }

    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        // ---- 1+2. drive the draft walk (commit + expansion) to
        // completion solo; fused schedulers feed levels externally before
        // calling plan, so a completed walk costs no draft calls here —
        // and a partially fused walk (fused call failed mid-cycle)
        // resumes solo from its pending level
        let sw = Stopwatch::start();
        loop {
            match self.draft_next(state)? {
                DraftPhase::Finished(o) => {
                    state.metrics.phases.draft_s += sw.secs();
                    return Ok(StepPlan::Finished(o));
                }
                DraftPhase::Ready => break,
                DraftPhase::Rows(rows) => {
                    let out = self.draft.decode_rows(&rows)?;
                    self.draft_feed(state, &out)?;
                }
                DraftPhase::None => bail!("eagle draft walk unavailable"),
            }
        }
        state.metrics.phases.draft_s += sw.secs();

        // ---- 3. rerank + flatten (the verify rows for this cycle) ----
        let sw = Stopwatch::start();
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("eagle plan on a foreign GenState")?;
        let w = inner.walk.take().context("eagle plan without a draft walk")?;
        let plan = match self.kind {
            TreeKind::Dynamic => w.tree.rerank(self.total_tokens),
            TreeKind::Static => w.tree.flatten_all(),
        };
        let positions: Vec<usize> = plan.depths.iter().map(|&d| w.base_pos + d).collect();
        let anc = plan.block_mask();
        state.metrics.phases.host_s += sw.secs();
        let rows = VerifyRows { tokens: plan.tokens.clone(), positions, block_anc: Some(anc) };
        inner.pending_plan = Some(plan);
        Ok(StepPlan::Verify(rows))
    }

    fn absorb(&mut self, state: &mut GenState, ver: &VerifyOut) -> Result<StepOutcome> {
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("eagle absorb on a foreign GenState")?;
        let plan = inner
            .pending_plan
            .take()
            .context("eagle absorb without a planned cycle")?;
        let sw = Stopwatch::start();
        let walk = accept_walk(&plan, ver, &state.req.params, &mut state.rng, &mut state.metrics);
        self.target.commit_rows(&walk.accepted_rows, &ver.feats)?;
        inner.pending_feats = walk
            .accepted_rows
            .iter()
            .map(|&r| ver.feats.row(r).to_vec())
            .collect();
        inner.pending_tokens = walk.new_tokens.clone();
        let before = state.tokens.len();
        state.tokens.extend(&walk.new_tokens);
        state.metrics.phases.sample_s += sw.secs();
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_children_follow_template_ranks() {
        let template = eagle_static_template();
        let sm = log_softmax(&[0.1, 0.9, 0.3, 0.5, 0.2, 0.05, 0.7, 0.6]);
        let kids = static_tree_children(&sm, &[], &template);
        // the template's root fan-out is 4: ranks 0..=3
        assert_eq!(kids.len(), 4);
        let ranks: Vec<usize> = kids.iter().map(|k| k.0).collect();
        assert_eq!(ranks, vec![0, 1, 2, 3]);
        // rank 0 carries the argmax token
        assert_eq!(kids[0].2, 1);
        // log-probs are descending in rank
        assert!(kids.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    /// The capacity gate's static per-level cap is the template's widest
    /// level, not the widest compiled artifact — with wide draft blocks
    /// (b80) the old `depth·block + block` reservation exceeded the whole
    /// 512-slot cache and killed every static-EAGLE session at cycle 1.
    #[test]
    fn template_level_width_is_the_widest_level() {
        assert_eq!(template_level_width(&eagle_static_template()), 6);
        assert_eq!(template_level_width(&[]), 1);
        assert_eq!(template_level_width(&[vec![0], vec![1], vec![0, 0]]), 2);
        // the default gate stays well under the cache: depth 6 levels of
        // <= 6 nodes plus one maximally padded call (b80) fits 512 slots
        // with room to spare even at pending + 2 overhead
        let lvl = template_level_width(&eagle_static_template());
        assert!(7 + 6 * lvl + 80 + 2 < 512);
    }

    /// Satellite regression: vocab smaller than the template fan-out must
    /// skip the unfillable ranks instead of panicking on `ordered[r]`.
    #[test]
    fn static_children_tiny_vocab_skips_missing_ranks() {
        let template = eagle_static_template();
        let sm = log_softmax(&[0.2, 0.8]); // vocab 2 < root fan-out 4
        let kids = static_tree_children(&sm, &[], &template);
        assert_eq!(kids.len(), 2);
        assert!(kids.iter().all(|k| k.2 == 0 || k.2 == 1));
        assert_eq!(kids[0].2, 1, "rank 0 is still the argmax");
        // deeper paths keep working too
        let kids = static_tree_children(&sm, &[0, 0], &template);
        assert_eq!(kids.len(), 2); // template has [0,0,0] and [0,0,1]
        // a parent path outside the template yields no children
        assert!(static_tree_children(&sm, &[3, 3], &template).is_empty());
    }
}
