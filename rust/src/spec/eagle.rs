//! Feature-level speculative sampling: EAGLE (static tree), EAGLE-2
//! (dynamic tree), and HASS (EAGLE-2 decoding + HASS-trained checkpoint —
//! the paper's point is that *decoding is identical*, all gains come from
//! harmonized training).
//!
//! Per cycle (one `step` call):
//!   1. **commit call** — the tokens accepted last cycle (+ bonus) run
//!      through the draft net with their *target* features (now known from
//!      verification), writing committed draft-KV rows; the last row's
//!      output doubles as the tree root's feature + child distribution.
//!   2. **tree expansion** — `depth-1` further draft calls; EAGLE-2 keeps a
//!      global top-`beam` frontier by cumulative log-prob, EAGLE follows
//!      the fixed template.  Draft-KV rows for tree nodes live in a scratch
//!      region above the committed boundary, visible only via per-node
//!      ancestor masks.
//!   3. **rerank** (dynamic only): keep the best `total_tokens` nodes
//!      (ancestor-closed), flatten BFS.
//!   4. **verify** — one target call over the block; lossless acceptance
//!      walk; accepted rows compact into the target cache.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::engine::sessions::{DraftSession, TargetSession};
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{log_softmax, process_logits, sample_token, topk};
use crate::spec::{
    accept_walk, GenRequest, GenState, Method, StepOutcome, StepPlan, VerifyOut, VerifyRows,
};
use crate::tree::{eagle_static_template, Tree, VerifyPlan};
use crate::util::stats::Stopwatch;

#[derive(Clone, Copy, PartialEq)]
pub enum TreeKind {
    /// EAGLE-1 fixed template
    Static,
    /// EAGLE-2 / HASS dynamic tree
    Dynamic,
}

pub struct Eagle {
    label: String,
    target: TargetSession,
    draft: DraftSession,
    kind: TreeKind,
    template: Vec<Vec<usize>>,
    pub depth: usize,
    pub beam: usize,
    pub total_tokens: usize,
}

/// Per-session carry-over between draft-expand-verify cycles.
struct EagleState {
    /// tokens emitted last cycle, paired with their parents' features —
    /// the next cycle's commit rows
    pending_tokens: Vec<i32>,
    pending_feats: Vec<Vec<f32>>,
    /// the tree `plan` flattened for verification, awaiting `absorb`
    pending_plan: Option<VerifyPlan>,
}

struct NodeInfo {
    /// draft feature g (known once the node has been expanded)
    g: Option<Vec<f32>>,
    /// draft-cache slot (for expanded nodes)
    slot: Option<usize>,
    /// scratch slots of non-committed ancestors (excludes the root)
    anc_slots: Vec<usize>,
    /// rank path (static template bookkeeping)
    path: Vec<usize>,
}

/// Children for a static-template node as (template rank, draft log-prob,
/// token) triples.  Ranks the vocabulary cannot fill are skipped: the old
/// `ordered[r]` indexing panicked whenever `topk` returned fewer than
/// `max_rank + 1` entries (vocab smaller than the template fan-out).
pub fn static_tree_children(
    sm: &[f32],
    parent_path: &[usize],
    template: &[Vec<usize>],
) -> Vec<(usize, f32, i32)> {
    let mut ranks: Vec<usize> = template
        .iter()
        .filter(|p| p.len() == parent_path.len() + 1 && p[..parent_path.len()] == parent_path[..])
        .map(|p| p[parent_path.len()])
        .collect();
    ranks.sort_unstable();
    ranks.dedup();
    let ordered = topk(sm, ranks.iter().max().map_or(0, |m| m + 1));
    ranks
        .into_iter()
        .filter_map(|r| ordered.get(r).map(|&(lp, tok)| (r, lp, tok as i32)))
        .collect()
}

/// Construct an EAGLE-family method (static or dynamic tree).
#[allow(clippy::too_many_arguments)]
pub fn build_eagle(
    rt: Rc<Runtime>,
    target_w: Rc<Checkpoint>,
    draft_w: Rc<Checkpoint>,
    kind: TreeKind,
    label: &str,
    depth: usize,
    beam: usize,
    total_tokens: usize,
) -> Result<Eagle> {
    let draft = DraftSession::new(rt.clone(), draft_w, &target_w)?;
    Ok(Eagle {
        label: label.to_string(),
        target: TargetSession::new(rt, target_w)?,
        draft,
        kind,
        template: eagle_static_template(),
        depth,
        beam,
        total_tokens,
    })
}

impl Method for Eagle {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let plen = req.prompt_tokens.len();
        self.target.reset();
        self.draft.reset();

        let mut state = GenState::new(
            req,
            EagleState {
                pending_tokens: Vec::new(),
                pending_feats: Vec::new(),
                pending_plan: None,
            },
        );
        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;
        let sw = Stopwatch::start();
        self.draft.prefill(&req.prompt_tokens, &self.target.feats)?;
        state.metrics.phases.draft_s += sw.secs();
        state.metrics.draft_calls += 1;

        let probs = process_logits(&last_logits, &req.params);
        let first = sample_token(&probs, &mut state.rng) as i32;
        state.tokens.push(first);
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("fresh eagle state")?;
        inner.pending_tokens = vec![first];
        inner.pending_feats = vec![self.target.feats[plen - 1].clone()];
        state.clamp();
        Ok(state)
    }

    fn fused_handle(&mut self) -> Option<&mut TargetSession> {
        Some(&mut self.target)
    }

    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        let block = self.draft.block;
        // the verify call consumes a full padded decode block of cache
        // slots, so capacity is checked against that, not the raw rows
        let rows_max = (self.total_tokens + 1).max(self.template.len() + 1);
        let verify_n = crate::engine::sessions::padded_span(rows_max);
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("eagle plan on a foreign GenState")?;
        if state.done
            || self.target.cache.remaining() < verify_n + 2
            || self.draft.remaining() < inner.pending_tokens.len() + self.depth * block + 2
        {
            state.finish();
            return Ok(StepPlan::Finished(StepOutcome { emitted: 0, done: true }));
        }
        let plen = state.req.prompt_tokens.len();
        let last = *state.tokens.last().context("session has no tokens")?;

        // ---- 1. commit call (also the root expansion) ----
        let sw = Stopwatch::start();
        let k = inner.pending_tokens.len();
        let write_start = self.draft.committed;
        let base_pos = plen + state.tokens.len() - 1; // seq position of the root
        let positions: Vec<usize> = (0..k).map(|i| base_pos + 1 + i - k).collect();
        let extra: Vec<Vec<usize>> =
            (0..k).map(|i| (write_start..write_start + i).collect()).collect();
        let feats_refs: Vec<&[f32]> = inner.pending_feats.iter().map(|f| f.as_slice()).collect();
        let commit_out = self.draft.decode(
            &inner.pending_tokens,
            &feats_refs,
            &positions,
            &extra,
            write_start,
        )?;
        self.draft.commit(k)?;
        state.metrics.draft_calls += 1;

        // ---- 2. tree expansion ----
        let root_token = last;
        let mut tree = Tree::new(root_token);
        let mut info: Vec<NodeInfo> = vec![NodeInfo {
            g: Some(commit_out.feats.row(k - 1).to_vec()),
            slot: None, // committed -> visible via the committed mask
            anc_slots: vec![],
            path: vec![],
        }];
        let add_children =
            |tree: &mut Tree,
             info: &mut Vec<NodeInfo>,
             parent: usize,
             logits: &[f32],
             kind: TreeKind,
             template: &[Vec<usize>],
             beam: usize| {
                let sm = log_softmax(logits);
                match kind {
                    TreeKind::Dynamic => {
                        for (lp, tok) in topk(&sm, beam) {
                            let _idx = tree.add_child(parent, tok as i32, lp);
                            let mut anc = info[parent].anc_slots.clone();
                            if let Some(s) = info[parent].slot {
                                anc.push(s);
                            }
                            info.push(NodeInfo {
                                g: None,
                                slot: None,
                                anc_slots: anc,
                                path: vec![],
                            });
                        }
                    }
                    TreeKind::Static => {
                        let ppath = info[parent].path.clone();
                        for (r, lp, tok) in static_tree_children(&sm, &ppath, template) {
                            let _idx = tree.add_child(parent, tok, lp);
                            let mut anc = info[parent].anc_slots.clone();
                            if let Some(s) = info[parent].slot {
                                anc.push(s);
                            }
                            let mut path = ppath.clone();
                            path.push(r);
                            info.push(NodeInfo { g: None, slot: None, anc_slots: anc, path });
                        }
                    }
                }
            };

        add_children(
            &mut tree,
            &mut info,
            0,
            commit_out.logits.row(k - 1),
            self.kind,
            &self.template,
            self.beam,
        );
        let mut frontier: Vec<usize> = (1..tree.len()).collect();

        let scratch_base = self.draft.committed;
        for level in 1..self.depth {
            // choose which frontier nodes to run through the draft net
            let expand: Vec<usize> = match self.kind {
                TreeKind::Dynamic => tree.select_beam(&frontier, self.beam),
                TreeKind::Static => frontier
                    .iter()
                    .copied()
                    .filter(|&n| {
                        let p = &info[n].path;
                        self.template
                            .iter()
                            .any(|t| t.len() == p.len() + 1 && t[..p.len()] == p[..])
                    })
                    .take(block)
                    .collect(),
            };
            if expand.is_empty() {
                break;
            }
            let level_base = scratch_base + (level - 1) * block;
            let tokens: Vec<i32> = expand.iter().map(|&n| tree.nodes[n].token).collect();
            let feats: Vec<&[f32]> = expand
                .iter()
                .map(|&n| {
                    let parent = tree.nodes[n].parent.unwrap();
                    info[parent].g.as_deref().expect("parent expanded")
                })
                .collect();
            let positions: Vec<usize> =
                expand.iter().map(|&n| base_pos + tree.nodes[n].depth).collect();
            let extra: Vec<Vec<usize>> =
                expand.iter().map(|&n| info[n].anc_slots.clone()).collect();
            let out = self
                .draft
                .decode(&tokens, &feats, &positions, &extra, level_base)?;
            state.metrics.draft_calls += 1;

            let mut next_frontier = Vec::new();
            for (i, &n) in expand.iter().enumerate() {
                info[n].g = Some(out.feats.row(i).to_vec());
                info[n].slot = Some(level_base + i);
                let before = tree.len();
                add_children(
                    &mut tree,
                    &mut info,
                    n,
                    out.logits.row(i),
                    self.kind,
                    &self.template,
                    self.beam,
                );
                next_frontier.extend(before..tree.len());
            }
            frontier = next_frontier;
        }
        state.metrics.phases.draft_s += sw.secs();

        // ---- 3. rerank + flatten (the verify rows for this cycle) ----
        let sw = Stopwatch::start();
        let plan = match self.kind {
            TreeKind::Dynamic => tree.rerank(self.total_tokens),
            TreeKind::Static => tree.flatten_all(),
        };
        let positions: Vec<usize> = plan.depths.iter().map(|&d| base_pos + d).collect();
        let anc = plan.block_mask();
        state.metrics.phases.host_s += sw.secs();
        let rows = VerifyRows { tokens: plan.tokens.clone(), positions, block_anc: Some(anc) };
        inner.pending_plan = Some(plan);
        Ok(StepPlan::Verify(rows))
    }

    fn absorb(&mut self, state: &mut GenState, ver: &VerifyOut) -> Result<StepOutcome> {
        let inner = state
            .inner
            .downcast_mut::<EagleState>()
            .context("eagle absorb on a foreign GenState")?;
        let plan = inner
            .pending_plan
            .take()
            .context("eagle absorb without a planned cycle")?;
        let sw = Stopwatch::start();
        let walk = accept_walk(&plan, ver, &state.req.params, &mut state.rng, &mut state.metrics);
        self.target.commit_rows(&walk.accepted_rows, &ver.feats)?;
        inner.pending_feats = walk
            .accepted_rows
            .iter()
            .map(|&r| ver.feats.row(r).to_vec())
            .collect();
        inner.pending_tokens = walk.new_tokens.clone();
        let before = state.tokens.len();
        state.tokens.extend(&walk.new_tokens);
        state.metrics.phases.sample_s += sw.secs();
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_children_follow_template_ranks() {
        let template = eagle_static_template();
        let sm = log_softmax(&[0.1, 0.9, 0.3, 0.5, 0.2, 0.05, 0.7, 0.6]);
        let kids = static_tree_children(&sm, &[], &template);
        // the template's root fan-out is 4: ranks 0..=3
        assert_eq!(kids.len(), 4);
        let ranks: Vec<usize> = kids.iter().map(|k| k.0).collect();
        assert_eq!(ranks, vec![0, 1, 2, 3]);
        // rank 0 carries the argmax token
        assert_eq!(kids[0].2, 1);
        // log-probs are descending in rank
        assert!(kids.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    /// Satellite regression: vocab smaller than the template fan-out must
    /// skip the unfillable ranks instead of panicking on `ordered[r]`.
    #[test]
    fn static_children_tiny_vocab_skips_missing_ranks() {
        let template = eagle_static_template();
        let sm = log_softmax(&[0.2, 0.8]); // vocab 2 < root fan-out 4
        let kids = static_tree_children(&sm, &[], &template);
        assert_eq!(kids.len(), 2);
        assert!(kids.iter().all(|k| k.2 == 0 || k.2 == 1));
        assert_eq!(kids[0].2, 1, "rank 0 is still the argmax");
        // deeper paths keep working too
        let kids = static_tree_children(&sm, &[0, 0], &template);
        assert_eq!(kids.len(), 2); // template has [0,0,0] and [0,0,1]
        // a parent path outside the template yields no children
        assert!(static_tree_children(&sm, &[3, 3], &template).is_empty());
    }
}
