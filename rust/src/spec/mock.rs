//! Deterministic runtime-free method for serving-path tests and demos.
//!
//! `mock` emits one pseudo-random printable-ASCII token per step from the
//! request seed — no `Runtime`, no artifacts, no KV cache.  It exists so
//! the scheduler/server machinery (continuous batching, streaming,
//! cancellation, deadlines) can be exercised end-to-end on machines
//! without trained artifacts, where every real method errors at init.

use anyhow::Result;

use crate::spec::{GenRequest, GenState, Method, StepOutcome};

pub struct Mock;

struct MockState;

fn next_token(state: &mut GenState) -> i32 {
    // printable ASCII (32..=126): ids decode to themselves, so streamed
    // deltas concatenate to exactly the full decoded text
    32 + state.rng.gen_range(95) as i32
}

impl Method for Mock {
    fn name(&self) -> String {
        "mock".into()
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let mut state = GenState::new(req, MockState);
        let tok = next_token(&mut state);
        state.tokens.push(tok);
        state.metrics.record_cycle(0, 1);
        state.clamp();
        Ok(state)
    }

    fn step(&mut self, state: &mut GenState) -> Result<StepOutcome> {
        if state.done {
            return Ok(StepOutcome { emitted: 0, done: true });
        }
        let tok = next_token(state);
        state.tokens.push(tok);
        state.metrics.record_cycle(0, 1);
        let done = state.clamp();
        Ok(StepOutcome { emitted: 1, done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::SampleParams;
    use crate::tokenizer;

    fn req(max_new: usize, seed: u64) -> GenRequest {
        GenRequest {
            prompt_tokens: vec![1],
            max_new,
            params: SampleParams { seed, ..Default::default() },
        }
    }

    #[test]
    fn mock_is_deterministic_per_seed() {
        let mut m = Mock;
        let a = m.generate(&req(12, 7)).unwrap();
        let b = m.generate(&req(12, 7)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 12);
        let c = m.generate(&req(12, 8)).unwrap();
        assert_ne!(a.tokens, c.tokens, "different seeds must differ");
        // printable: decode roundtrips with no '?' or dropped ids
        let text = tokenizer::decode(&a.tokens);
        assert_eq!(text.len(), 12);
    }

    /// The default `generate` loop must equal a manual start/step drive —
    /// the tentpole invariant every refactored method relies on.
    #[test]
    fn stepwise_drive_matches_generate() {
        let mut m = Mock;
        let whole = m.generate(&req(9, 3)).unwrap();
        let mut st = m.start(&req(9, 3)).unwrap();
        let mut emitted = st.tokens.len();
        while !st.done {
            let o = m.step(&mut st).unwrap();
            emitted += o.emitted;
        }
        assert_eq!(st.tokens, whole.tokens);
        assert_eq!(emitted, whole.tokens.len());
        assert_eq!(st.metrics.cycles, whole.metrics.cycles);
    }

    #[test]
    fn mock_respects_degenerate_max_new() {
        let mut m = Mock;
        let out = m.generate(&req(1, 0)).unwrap();
        assert_eq!(out.tokens.len(), 1);
        let out = m.generate(&req(0, 0)).unwrap();
        assert!(out.tokens.is_empty());
    }
}
