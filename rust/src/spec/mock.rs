//! Deterministic runtime-free method for serving-path tests and demos.
//!
//! `mock` is a miniature speculative method over a host-side "target
//! model": [`mock_logits`] is a pure hash of (token, position) with its
//! mass on printable ASCII, so decoded ids concatenate to exactly the
//! streamed text.  Each cycle drafts a short chain ([`MOCK_GAMMA`] tokens,
//! deliberately missing the target argmax every third position so partial
//! acceptance paths are exercised), plans it as [`StepPlan::Verify`] rows,
//! and absorbs the verified logits through the real `accept_walk` — no
//! `Runtime`, no artifacts, no KV cache.
//!
//! Because the model is a [`HostVerifier`] (a pure batch function), a
//! scheduler can pack many mock sessions' rows into ONE host call and
//! scatter the outputs — the exact choreography of the compiled fused
//! path — which is what lets CI exercise cross-session batched
//! verification on machines without trained artifacts, where every real
//! method errors at init.  Only the first token draws from the request
//! RNG (seed-dependent streams); everything after is a deterministic
//! function of it, so fused and solo drives are token-for-token equal.
//!
//! Drafting is likewise a level-synchronous walk (PR 5): each chain link
//! is one `draft_next` level executed through the host draft model
//! [`mock_draft_logits`] (a [`HostVerifier`]-shaped batch fn), so a
//! scheduler can fuse the same level of many mock sessions into ONE host
//! draft call — CI's stand-in for the compiled `fused_draft_decode`
//! path.  `plan` drives any unfinished chain to completion solo.

use anyhow::{bail, Context, Result};

use crate::spec::{
    accept_walk, DraftPhase, DraftRows, GenRequest, GenState, HostVerifier, Method, StepOutcome,
    StepPlan, VerifyOut, VerifyRows,
};
use crate::tokenizer;
use crate::tree::{Tree, VerifyPlan};

/// Draft-chain length per cycle.
pub const MOCK_GAMMA: usize = 4;

fn mock_hash(token: i32, position: usize) -> u64 {
    let mut z = (token as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((position as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// First- and second-choice printable tokens at (token, position).
fn mock_top2(token: i32, position: usize) -> (i32, i32) {
    let h = mock_hash(token, position);
    (32 + (h % 95) as i32, 32 + ((h >> 7) % 95) as i32)
}

/// The mock target's next-token logits at (token, position): one row over
/// the real tokenizer vocab, peaked on two hash-derived printable tokens.
pub fn mock_logits_row(token: i32, position: usize) -> Vec<f32> {
    let (a, b) = mock_top2(token, position);
    let mut row = vec![-8.0f32; tokenizer::VOCAB];
    row[a as usize] = 6.0;
    if b != a {
        row[b as usize] = 4.0;
    }
    row
}

/// Batch verifier over packed rows from any number of sessions — each
/// row's logits depend only on its own (token, position), so one host
/// call over a concatenation is exact (see module docs).
pub fn mock_verify(tokens: &[i32], positions: &[usize]) -> VerifyOut {
    let n = tokens.len();
    let v = tokenizer::VOCAB;
    let mut logits = Vec::with_capacity(n * v);
    for i in 0..n {
        logits.extend_from_slice(&mock_logits_row(tokens[i], positions[i]));
    }
    VerifyOut {
        logits: crate::runtime::TensorF { dims: vec![n, v], data: logits },
        feats: crate::runtime::TensorF::zeros(&[n, 1]),
    }
}

/// Draft proposal at (token, position): the target's argmax, except every
/// third absolute position proposes the runner-up (a deliberate miss so
/// rejection + bonus paths run).
fn mock_draft(token: i32, position: usize) -> i32 {
    let (best, second) = mock_top2(token, position);
    if position % 3 == 2 {
        second
    } else {
        best
    }
}

/// Host draft model over packed rows from any number of sessions: row i
/// is peaked on the token [`mock_draft`] proposes at (token, position),
/// so `draft_feed`'s argmax recovers exactly the per-level chain draft.
/// One call over a concatenation equals per-row calls (each row depends
/// only on its own inputs) — the draft-side mirror of [`mock_verify`].
pub fn mock_draft_logits(tokens: &[i32], positions: &[usize]) -> VerifyOut {
    let n = tokens.len();
    let v = tokenizer::VOCAB;
    let mut logits = vec![-8.0f32; n * v];
    for i in 0..n {
        logits[i * v + mock_draft(tokens[i], positions[i]) as usize] = 6.0;
    }
    VerifyOut {
        logits: crate::runtime::TensorF { dims: vec![n, v], data: logits },
        feats: crate::runtime::TensorF::zeros(&[n, 1]),
    }
}

pub struct Mock;

/// Resumable per-cycle draft chain (level-synchronous walk).
struct MockWalk {
    /// root followed by the tokens drafted so far
    chain: Vec<i32>,
    base_pos: usize,
}

struct MockState {
    walk: Option<MockWalk>,
    pending_plan: Option<VerifyPlan>,
}

impl Method for Mock {
    fn name(&self) -> String {
        "mock".into()
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let mut state = GenState::new(req, MockState { walk: None, pending_plan: None });
        // printable ASCII (32..=126): ids decode to themselves, so the
        // first (seed-dependent) token is stream-safe like all the rest
        let tok = 32 + state.rng.gen_range(95) as i32;
        state.tokens.push(tok);
        state.metrics.record_cycle(0, 1);
        state.clamp();
        Ok(state)
    }

    fn host_verifier(&self) -> Option<HostVerifier> {
        Some(mock_verify)
    }

    fn host_drafter(&self) -> Option<HostVerifier> {
        Some(mock_draft_logits)
    }

    /// Next chain link as a one-row draft level (host model: no features,
    /// no KV, `write_start` 0).  Idempotent — the chain only advances on
    /// `draft_feed`.
    fn draft_next(&mut self, state: &mut GenState) -> Result<DraftPhase> {
        let inner = state
            .inner
            .downcast_mut::<MockState>()
            .context("mock draft on a foreign GenState")?;
        if state.done {
            state.finish();
            return Ok(DraftPhase::Finished(StepOutcome { emitted: 0, done: true }));
        }
        if inner.walk.is_none() {
            let root = *state.tokens.last().context("session has no tokens")?;
            let base_pos = state.req.prompt_tokens.len() + state.tokens.len() - 1;
            inner.walk = Some(MockWalk { chain: vec![root], base_pos });
        }
        let w = inner.walk.as_ref().expect("walk just ensured");
        if w.chain.len() > MOCK_GAMMA {
            return Ok(DraftPhase::Ready);
        }
        let level = w.chain.len() - 1;
        Ok(DraftPhase::Rows(DraftRows {
            tokens: vec![*w.chain.last().expect("chain has a root")],
            feats: vec![Vec::new()],
            positions: vec![w.base_pos + level],
            extra_visible: vec![Vec::new()],
            write_start: 0,
        }))
    }

    fn draft_feed(&mut self, state: &mut GenState, out: &VerifyOut) -> Result<()> {
        let inner = state
            .inner
            .downcast_mut::<MockState>()
            .context("mock draft_feed on a foreign GenState")?;
        let w = inner.walk.as_mut().context("mock draft_feed without a walk")?;
        if w.chain.len() > MOCK_GAMMA {
            bail!("mock draft chain already complete");
        }
        let row = out.logits.row(0);
        let next = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .context("empty draft logits")?;
        w.chain.push(next);
        state.metrics.draft_calls += 1;
        Ok(())
    }

    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        // drive any unfinished draft chain to completion through the host
        // draft model (solo path; fused schedulers feed levels externally)
        loop {
            match self.draft_next(state)? {
                DraftPhase::Finished(o) => return Ok(StepPlan::Finished(o)),
                DraftPhase::Ready => break,
                DraftPhase::Rows(rows) => {
                    let out = mock_draft_logits(&rows.tokens, &rows.positions);
                    self.draft_feed(state, &out)?;
                }
                DraftPhase::None => bail!("mock draft walk unavailable"),
            }
        }
        let inner = state
            .inner
            .downcast_mut::<MockState>()
            .context("mock plan on a foreign GenState")?;
        let w = inner.walk.take().context("mock plan without a draft walk")?;
        let mut tree = Tree::new(w.chain[0]);
        let mut parent = 0usize;
        for &tok in &w.chain[1..] {
            parent = tree.add_child(parent, tok, -0.1);
        }
        let plan = tree.flatten_all();
        let positions: Vec<usize> = plan.depths.iter().map(|&d| w.base_pos + d).collect();
        let rows = VerifyRows {
            tokens: plan.tokens.clone(),
            positions,
            block_anc: Some(plan.block_mask()),
        };
        inner.pending_plan = Some(plan);
        Ok(StepPlan::Verify(rows))
    }

    fn absorb(&mut self, state: &mut GenState, out: &VerifyOut) -> Result<StepOutcome> {
        let inner = state
            .inner
            .downcast_mut::<MockState>()
            .context("mock absorb on a foreign GenState")?;
        let plan = inner
            .pending_plan
            .take()
            .context("mock absorb without a planned cycle")?;
        let walk = accept_walk(&plan, out, &state.req.params, &mut state.rng, &mut state.metrics);
        let before = state.tokens.len();
        state.tokens.extend(&walk.new_tokens);
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::SampleParams;
    use crate::tokenizer;

    fn req(max_new: usize, seed: u64) -> GenRequest {
        GenRequest {
            prompt_tokens: vec![1],
            max_new,
            params: SampleParams { seed, temperature: 0.0, ..Default::default() },
        }
    }

    #[test]
    fn mock_is_deterministic_per_seed() {
        let mut m = Mock;
        let a = m.generate(&req(12, 7)).unwrap();
        let b = m.generate(&req(12, 7)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 12);
        let c = m.generate(&req(12, 8)).unwrap();
        assert_ne!(a.tokens, c.tokens, "different seeds must differ");
        // printable: decode roundtrips with no '?' or dropped ids
        let text = tokenizer::decode(&a.tokens);
        assert_eq!(text.len(), 12);
    }

    /// The default `generate` loop must equal a manual start/step drive —
    /// the tentpole invariant every refactored method relies on.
    #[test]
    fn stepwise_drive_matches_generate() {
        let mut m = Mock;
        let whole = m.generate(&req(9, 3)).unwrap();
        let mut st = m.start(&req(9, 3)).unwrap();
        let mut emitted = st.tokens.len();
        while !st.done {
            let o = m.step(&mut st).unwrap();
            emitted += o.emitted;
        }
        assert_eq!(st.tokens, whole.tokens);
        assert_eq!(emitted, whole.tokens.len());
        assert_eq!(st.metrics.cycles, whole.metrics.cycles);
    }

    /// A manual plan -> (batched) verify -> absorb drive must equal the
    /// step drive token-for-token AND metric-for-metric — the per-session
    /// half of the fused-verification equivalence contract.
    #[test]
    fn plan_absorb_drive_matches_step_drive() {
        let mut m = Mock;
        let whole = m.generate(&req(20, 5)).unwrap();
        let mut st = m.start(&req(20, 5)).unwrap();
        while !st.done {
            match m.plan(&mut st).unwrap() {
                StepPlan::Finished(_) => break,
                StepPlan::Unbatchable => panic!("mock must be batchable"),
                StepPlan::Verify(rows) => {
                    // through the host verifier, as a fused scheduler would
                    let hv = m.host_verifier().expect("mock has a host verifier");
                    let out = hv(&rows.tokens, &rows.positions);
                    m.absorb(&mut st, &out).unwrap();
                }
            }
        }
        assert_eq!(st.tokens, whole.tokens);
        assert_eq!(st.metrics.cycles, whole.metrics.cycles);
        assert_eq!(st.metrics.new_tokens, whole.metrics.new_tokens);
    }

    /// Speculation must actually happen: multi-token cycles (tau > 1) and
    /// at least one rejection (the drafted miss every third position).
    #[test]
    fn mock_speculates_with_partial_acceptance() {
        let mut m = Mock;
        let out = m.generate(&req(40, 11)).unwrap();
        assert_eq!(out.tokens.len(), 40);
        assert!(out.metrics.tau() > 1.0, "tau={}", out.metrics.tau());
        assert!(out.metrics.cycles < 40, "no speculation happened");
        assert!(
            out.metrics.draft_tokens_verified > 0,
            "verification must see draft tokens"
        );
    }

    /// The draft-phase protocol: each cycle's chain is MOCK_GAMMA
    /// externally drivable levels, `draft_next` is idempotent until fed,
    /// a completed walk costs `plan` zero draft calls, and the externally
    /// driven session equals the solo `generate` token-for-token — the
    /// per-session half of the fused-draft equivalence contract.
    #[test]
    fn externally_driven_draft_levels_match_solo() {
        let mut m = Mock;
        let whole = m.generate(&req(16, 9)).unwrap();
        let mut st = m.start(&req(16, 9)).unwrap();
        while !st.done {
            let mut levels = 0usize;
            let finished = loop {
                let rows = match m.draft_next(&mut st).unwrap() {
                    DraftPhase::Rows(r) => r,
                    DraftPhase::Ready => break false,
                    DraftPhase::Finished(_) => break true,
                    DraftPhase::None => panic!("mock must expose a draft walk"),
                };
                // idempotent until fed (the fused-failure fallback relies
                // on re-reading the same pending level)
                match m.draft_next(&mut st).unwrap() {
                    DraftPhase::Rows(again) => {
                        assert_eq!(rows.tokens, again.tokens);
                        assert_eq!(rows.positions, again.positions);
                    }
                    _ => panic!("pending level must be re-emitted"),
                }
                let hd = m.host_drafter().expect("mock has a host drafter");
                let out = hd(&rows.tokens, &rows.positions);
                m.draft_feed(&mut st, &out).unwrap();
                levels += 1;
            };
            if finished || st.done {
                break;
            }
            assert_eq!(levels, MOCK_GAMMA, "one chain link per level");
            let before = st.metrics.draft_calls;
            match m.plan(&mut st).unwrap() {
                StepPlan::Finished(_) => break,
                StepPlan::Verify(rows) => {
                    assert_eq!(
                        st.metrics.draft_calls, before,
                        "completed walk must cost plan no draft calls"
                    );
                    let hv = m.host_verifier().unwrap();
                    let out = hv(&rows.tokens, &rows.positions);
                    m.absorb(&mut st, &out).unwrap();
                }
                StepPlan::Unbatchable => panic!("mock must be batchable"),
            }
        }
        assert_eq!(st.tokens, whole.tokens, "externally driven drafting diverged");
        assert_eq!(st.metrics.cycles, whole.metrics.cycles);
        assert_eq!(st.metrics.draft_calls, whole.metrics.draft_calls);
    }

    #[test]
    fn mock_respects_degenerate_max_new() {
        let mut m = Mock;
        let out = m.generate(&req(1, 0)).unwrap();
        assert_eq!(out.tokens.len(), 1);
        let out = m.generate(&req(0, 0)).unwrap();
        assert!(out.tokens.is_empty());
    }

    #[test]
    fn mock_verify_batches_like_per_row_calls() {
        let tokens = [40i32, 55, 70];
        let positions = [3usize, 9, 4];
        let batched = mock_verify(&tokens, &positions);
        assert_eq!(batched.logits.dims, vec![3, tokenizer::VOCAB]);
        for i in 0..3 {
            let solo = mock_verify(&tokens[i..i + 1], &positions[i..i + 1]);
            assert_eq!(batched.logits.row(i), solo.logits.row(0), "row {i} scattered wrong");
        }
    }
}
