//! Deterministic runtime-free method for serving-path tests and demos.
//!
//! `mock` is a miniature speculative method over a host-side "target
//! model": [`mock_logits`] is a pure hash of (token, position) with its
//! mass on printable ASCII, so decoded ids concatenate to exactly the
//! streamed text.  Each cycle drafts a short chain ([`MOCK_GAMMA`] tokens,
//! deliberately missing the target argmax every third position so partial
//! acceptance paths are exercised), plans it as [`StepPlan::Verify`] rows,
//! and absorbs the verified logits through the real `accept_walk` — no
//! `Runtime`, no artifacts, no KV cache.
//!
//! Because the model is a [`HostVerifier`] (a pure batch function), a
//! scheduler can pack many mock sessions' rows into ONE host call and
//! scatter the outputs — the exact choreography of the compiled fused
//! path — which is what lets CI exercise cross-session batched
//! verification on machines without trained artifacts, where every real
//! method errors at init.  Only the first token draws from the request
//! RNG (seed-dependent streams); everything after is a deterministic
//! function of it, so fused and solo drives are token-for-token equal.

use anyhow::{Context, Result};

use crate::spec::{
    accept_walk, GenRequest, GenState, HostVerifier, Method, StepOutcome, StepPlan, VerifyOut,
    VerifyRows,
};
use crate::tokenizer;
use crate::tree::{Tree, VerifyPlan};

/// Draft-chain length per cycle.
pub const MOCK_GAMMA: usize = 4;

fn mock_hash(token: i32, position: usize) -> u64 {
    let mut z = (token as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((position as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// First- and second-choice printable tokens at (token, position).
fn mock_top2(token: i32, position: usize) -> (i32, i32) {
    let h = mock_hash(token, position);
    (32 + (h % 95) as i32, 32 + ((h >> 7) % 95) as i32)
}

/// The mock target's next-token logits at (token, position): one row over
/// the real tokenizer vocab, peaked on two hash-derived printable tokens.
pub fn mock_logits_row(token: i32, position: usize) -> Vec<f32> {
    let (a, b) = mock_top2(token, position);
    let mut row = vec![-8.0f32; tokenizer::VOCAB];
    row[a as usize] = 6.0;
    if b != a {
        row[b as usize] = 4.0;
    }
    row
}

/// Batch verifier over packed rows from any number of sessions — each
/// row's logits depend only on its own (token, position), so one host
/// call over a concatenation is exact (see module docs).
pub fn mock_verify(tokens: &[i32], positions: &[usize]) -> VerifyOut {
    let n = tokens.len();
    let v = tokenizer::VOCAB;
    let mut logits = Vec::with_capacity(n * v);
    for i in 0..n {
        logits.extend_from_slice(&mock_logits_row(tokens[i], positions[i]));
    }
    VerifyOut {
        logits: crate::runtime::TensorF { dims: vec![n, v], data: logits },
        feats: crate::runtime::TensorF::zeros(&[n, 1]),
    }
}

/// Draft proposal at (token, position): the target's argmax, except every
/// third absolute position proposes the runner-up (a deliberate miss so
/// rejection + bonus paths run).
fn mock_draft(token: i32, position: usize) -> i32 {
    let (best, second) = mock_top2(token, position);
    if position % 3 == 2 {
        second
    } else {
        best
    }
}

pub struct Mock;

struct MockState {
    pending_plan: Option<VerifyPlan>,
}

impl Method for Mock {
    fn name(&self) -> String {
        "mock".into()
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let mut state = GenState::new(req, MockState { pending_plan: None });
        // printable ASCII (32..=126): ids decode to themselves, so the
        // first (seed-dependent) token is stream-safe like all the rest
        let tok = 32 + state.rng.gen_range(95) as i32;
        state.tokens.push(tok);
        state.metrics.record_cycle(0, 1);
        state.clamp();
        Ok(state)
    }

    fn host_verifier(&self) -> Option<HostVerifier> {
        Some(mock_verify)
    }

    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        let inner = state
            .inner
            .downcast_mut::<MockState>()
            .context("mock plan on a foreign GenState")?;
        if state.done {
            state.finish();
            return Ok(StepPlan::Finished(StepOutcome { emitted: 0, done: true }));
        }
        let root = *state.tokens.last().context("session has no tokens")?;
        let base_pos = state.req.prompt_tokens.len() + state.tokens.len() - 1;

        let mut tree = Tree::new(root);
        let mut parent = 0usize;
        let mut tok = root;
        for i in 0..MOCK_GAMMA {
            let next = mock_draft(tok, base_pos + i);
            parent = tree.add_child(parent, next, -0.1);
            tok = next;
        }
        let plan = tree.flatten_all();
        let positions: Vec<usize> = plan.depths.iter().map(|&d| base_pos + d).collect();
        state.metrics.draft_calls += 1;
        let rows = VerifyRows {
            tokens: plan.tokens.clone(),
            positions,
            block_anc: Some(plan.block_mask()),
        };
        inner.pending_plan = Some(plan);
        Ok(StepPlan::Verify(rows))
    }

    fn absorb(&mut self, state: &mut GenState, out: &VerifyOut) -> Result<StepOutcome> {
        let inner = state
            .inner
            .downcast_mut::<MockState>()
            .context("mock absorb on a foreign GenState")?;
        let plan = inner
            .pending_plan
            .take()
            .context("mock absorb without a planned cycle")?;
        let walk = accept_walk(&plan, out, &state.req.params, &mut state.rng, &mut state.metrics);
        let before = state.tokens.len();
        state.tokens.extend(&walk.new_tokens);
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::SampleParams;
    use crate::tokenizer;

    fn req(max_new: usize, seed: u64) -> GenRequest {
        GenRequest {
            prompt_tokens: vec![1],
            max_new,
            params: SampleParams { seed, temperature: 0.0, ..Default::default() },
        }
    }

    #[test]
    fn mock_is_deterministic_per_seed() {
        let mut m = Mock;
        let a = m.generate(&req(12, 7)).unwrap();
        let b = m.generate(&req(12, 7)).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 12);
        let c = m.generate(&req(12, 8)).unwrap();
        assert_ne!(a.tokens, c.tokens, "different seeds must differ");
        // printable: decode roundtrips with no '?' or dropped ids
        let text = tokenizer::decode(&a.tokens);
        assert_eq!(text.len(), 12);
    }

    /// The default `generate` loop must equal a manual start/step drive —
    /// the tentpole invariant every refactored method relies on.
    #[test]
    fn stepwise_drive_matches_generate() {
        let mut m = Mock;
        let whole = m.generate(&req(9, 3)).unwrap();
        let mut st = m.start(&req(9, 3)).unwrap();
        let mut emitted = st.tokens.len();
        while !st.done {
            let o = m.step(&mut st).unwrap();
            emitted += o.emitted;
        }
        assert_eq!(st.tokens, whole.tokens);
        assert_eq!(emitted, whole.tokens.len());
        assert_eq!(st.metrics.cycles, whole.metrics.cycles);
    }

    /// A manual plan -> (batched) verify -> absorb drive must equal the
    /// step drive token-for-token AND metric-for-metric — the per-session
    /// half of the fused-verification equivalence contract.
    #[test]
    fn plan_absorb_drive_matches_step_drive() {
        let mut m = Mock;
        let whole = m.generate(&req(20, 5)).unwrap();
        let mut st = m.start(&req(20, 5)).unwrap();
        while !st.done {
            match m.plan(&mut st).unwrap() {
                StepPlan::Finished(_) => break,
                StepPlan::Unbatchable => panic!("mock must be batchable"),
                StepPlan::Verify(rows) => {
                    // through the host verifier, as a fused scheduler would
                    let hv = m.host_verifier().expect("mock has a host verifier");
                    let out = hv(&rows.tokens, &rows.positions);
                    m.absorb(&mut st, &out).unwrap();
                }
            }
        }
        assert_eq!(st.tokens, whole.tokens);
        assert_eq!(st.metrics.cycles, whole.metrics.cycles);
        assert_eq!(st.metrics.new_tokens, whole.metrics.new_tokens);
    }

    /// Speculation must actually happen: multi-token cycles (tau > 1) and
    /// at least one rejection (the drafted miss every third position).
    #[test]
    fn mock_speculates_with_partial_acceptance() {
        let mut m = Mock;
        let out = m.generate(&req(40, 11)).unwrap();
        assert_eq!(out.tokens.len(), 40);
        assert!(out.metrics.tau() > 1.0, "tau={}", out.metrics.tau());
        assert!(out.metrics.cycles < 40, "no speculation happened");
        assert!(
            out.metrics.draft_tokens_verified > 0,
            "verification must see draft tokens"
        );
    }

    #[test]
    fn mock_respects_degenerate_max_new() {
        let mut m = Mock;
        let out = m.generate(&req(1, 0)).unwrap();
        assert_eq!(out.tokens.len(), 1);
        let out = m.generate(&req(0, 0)).unwrap();
        assert!(out.tokens.is_empty());
    }

    #[test]
    fn mock_verify_batches_like_per_row_calls() {
        let tokens = [40i32, 55, 70];
        let positions = [3usize, 9, 4];
        let batched = mock_verify(&tokens, &positions);
        assert_eq!(batched.logits.dims, vec![3, tokenizer::VOCAB]);
        for i in 0..3 {
            let solo = mock_verify(&tokens[i..i + 1], &positions[i..i + 1]);
            assert_eq!(batched.logits.row(i), solo.logits.row(0), "row {i} scattered wrong");
        }
    }
}
