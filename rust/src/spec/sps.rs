//! Vanilla speculative sampling (Leviathan et al. 2023 / Chen et al. 2023):
//! an independent tiny LM drafts a γ-token chain sampled from its own
//! distribution; the target verifies in one call; canonical rejection
//! sampling (accept w.p. min(1, p/q), residual on reject) keeps the output
//! exactly target-distributed.

use std::rc::Rc;

use anyhow::Result;

use crate::engine::metrics::Metrics;
use crate::engine::sessions::{SpsSession, TargetSession};
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token, verify_chain, SampleParams};
use crate::spec::{truncate_eos, GenOutput, GenRequest, Method};
use crate::tokenizer::EOS;
use crate::util::rng::Rng;
use crate::util::stats::Stopwatch;

pub struct Sps {
    target: TargetSession,
    draft: SpsSession,
    gamma: usize,
}

impl Sps {
    pub fn new(
        rt: Rc<Runtime>,
        target_w: Rc<Checkpoint>,
        sps_w: Rc<Checkpoint>,
        gamma: usize,
    ) -> Result<Sps> {
        Ok(Sps {
            target: TargetSession::new(rt.clone(), target_w)?,
            draft: SpsSession::new(rt, sps_w)?,
            gamma,
        })
    }
}

impl Method for Sps {
    fn name(&self) -> String {
        format!("sps(gamma={})", self.gamma)
    }

    fn generate(&mut self, req: &GenRequest) -> Result<GenOutput> {
        let mut metrics = Metrics::default();
        let mut rng = Rng::new(req.params.seed);
        self.target.reset();
        self.draft.reset();
        let plen = req.prompt_tokens.len();

        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        metrics.phases.verify_s += sw.secs();
        metrics.target_calls += 1;
        let sw = Stopwatch::start();
        self.draft.prefill(&req.prompt_tokens)?;
        metrics.phases.draft_s += sw.secs();

        let mut out_tokens: Vec<i32> = Vec::new();
        let probs = process_logits(&last_logits, &req.params);
        out_tokens.push(sample_token(&probs, &mut rng) as i32);

        // tokens emitted but not yet in the draft LM's cache
        let mut draft_backlog: Vec<i32> = vec![*out_tokens.last().unwrap()];

        while out_tokens.len() < req.max_new
            && *out_tokens.last().unwrap() != EOS
            && self.target.cache.remaining() > self.gamma + 2
            && self.draft.cache.remaining() > self.gamma + draft_backlog.len() + 2
        {
            let root = *out_tokens.last().unwrap();
            // ---- draft a chain of gamma tokens sampled from q ----
            let sw = Stopwatch::start();
            let mut chain: Vec<i32> = Vec::new();
            let mut chain_q: Vec<Vec<f32>> = Vec::new();
            // catch the draft cache up on the backlog (incl. current root)
            let mut logits = Vec::new();
            for (i, &t) in draft_backlog.iter().enumerate() {
                let pos = plen + out_tokens.len() - draft_backlog.len() + i;
                logits = self.draft.decode1(t, pos)?;
                metrics.draft_calls += 1;
            }
            draft_backlog.clear();
            for g in 0..self.gamma {
                let q = process_logits(&logits, &req.params);
                let tok = sample_token(&q, &mut rng) as i32;
                chain.push(tok);
                chain_q.push(q);
                if g + 1 < self.gamma {
                    let pos = plen + out_tokens.len() + g;
                    logits = self.draft.decode1(tok, pos)?;
                    metrics.draft_calls += 1;
                }
            }
            metrics.phases.draft_s += sw.secs();

            // ---- verify [root, chain...] in one target call ----
            let sw = Stopwatch::start();
            let mut block = vec![root];
            block.extend(&chain);
            let base_pos = plen + out_tokens.len() - 1;
            let positions: Vec<usize> = (0..block.len()).map(|i| base_pos + i).collect();
            let ver = self.target.decode(&block, &positions, None)?;
            metrics.phases.verify_s += sw.secs();
            metrics.target_calls += 1;
            metrics.draft_tokens_verified += chain.len();

            // ---- rejection sampling ----
            let sw = Stopwatch::start();
            let target_probs: Vec<Vec<f32>> = (0..block.len())
                .map(|i| process_logits(ver.logits.row(i), &req.params))
                .collect();
            let verdict = verify_chain(&chain, &chain_q, &target_probs, &mut rng);
            metrics.phases.sample_s += sw.secs();

            let accepted_rows: Vec<usize> = (0..=verdict.accepted).collect();
            self.target.commit_rows(&accepted_rows, &ver.feats)?;
            let mut emitted: Vec<i32> = chain[..verdict.accepted].to_vec();
            emitted.push(verdict.bonus);
            metrics.record_cycle(verdict.accepted, emitted.len());

            // draft cache holds [root, chain[..gamma-1]]: keep root +
            // accepted prefix that it has seen; roll back the rest.
            let in_cache = self.gamma; // root + gamma-1 chain tokens
            let keep = 1 + verdict.accepted.min(self.gamma - 1);
            self.draft.rollback(in_cache - keep);
            // backlog: accepted chain tail not in cache (the last accepted
            // token if it was chain[gamma-1]) + the bonus token
            if verdict.accepted == self.gamma {
                draft_backlog.push(chain[self.gamma - 1]);
            }
            draft_backlog.push(verdict.bonus);

            out_tokens.extend(emitted);
        }
        if out_tokens.len() > req.max_new {
            out_tokens.truncate(req.max_new);
        }
        truncate_eos(&mut out_tokens);
        let _ = &req.params as &SampleParams;
        Ok(GenOutput { tokens: out_tokens, metrics })
    }
}
