//! Vanilla speculative sampling (Leviathan et al. 2023 / Chen et al. 2023):
//! an independent tiny LM drafts a γ-token chain sampled from its own
//! distribution; the target verifies in one call; canonical rejection
//! sampling (accept w.p. min(1, p/q), residual on reject) keeps the output
//! exactly target-distributed.  One γ-chain per `step` call.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::engine::sessions::{SpsSession, TargetSession};
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token, verify_chain};
use crate::spec::{GenRequest, GenState, Method, StepOutcome, StepPlan, VerifyOut, VerifyRows};
use crate::util::stats::Stopwatch;

pub struct Sps {
    target: TargetSession,
    draft: SpsSession,
    gamma: usize,
}

/// Per-session carry-over between γ-chain cycles.
struct SpsState {
    /// tokens emitted but not yet in the draft LM's cache
    draft_backlog: Vec<i32>,
    /// the γ-chain drafted by `plan`, awaiting `absorb`
    pending: Option<SpsPending>,
}

/// A drafted chain in flight between `plan` and `absorb`.
struct SpsPending {
    chain: Vec<i32>,
    /// full draft distribution at each chain position (rejection sampling)
    chain_q: Vec<Vec<f32>>,
}

impl Sps {
    pub fn new(
        rt: Rc<Runtime>,
        target_w: Rc<Checkpoint>,
        sps_w: Rc<Checkpoint>,
        gamma: usize,
    ) -> Result<Sps> {
        Ok(Sps {
            target: TargetSession::new(rt.clone(), target_w)?,
            draft: SpsSession::new(rt, sps_w)?,
            gamma,
        })
    }
}

impl Method for Sps {
    fn name(&self) -> String {
        format!("sps(gamma={})", self.gamma)
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let mut state = GenState::new(req, SpsState { draft_backlog: Vec::new(), pending: None });
        self.target.reset();
        self.draft.reset();

        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;
        let sw = Stopwatch::start();
        self.draft.prefill(&req.prompt_tokens)?;
        state.metrics.phases.draft_s += sw.secs();

        let probs = process_logits(&last_logits, &req.params);
        let first = sample_token(&probs, &mut state.rng) as i32;
        state.tokens.push(first);
        state
            .inner
            .downcast_mut::<SpsState>()
            .context("fresh sps state")?
            .draft_backlog
            .push(first);
        state.clamp();
        Ok(state)
    }

    fn fused_handle(&mut self) -> Option<&mut TargetSession> {
        Some(&mut self.target)
    }

    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        let gamma = self.gamma;
        let inner = state
            .inner
            .downcast_mut::<SpsState>()
            .context("sps plan on a foreign GenState")?;
        // the verify call burns a full padded decode block of target slots
        let verify_n = crate::engine::sessions::padded_span(gamma + 1);
        if state.done
            || self.target.cache.remaining() <= verify_n + 1
            || self.draft.cache.remaining() <= gamma + inner.draft_backlog.len() + 2
        {
            state.finish();
            return Ok(StepPlan::Finished(StepOutcome { emitted: 0, done: true }));
        }
        let plen = state.req.prompt_tokens.len();
        let root = *state.tokens.last().context("session has no tokens")?;

        // ---- draft a chain of gamma tokens sampled from q ----
        let sw = Stopwatch::start();
        let mut chain: Vec<i32> = Vec::new();
        let mut chain_q: Vec<Vec<f32>> = Vec::new();
        // catch the draft cache up on the backlog (incl. current root)
        let mut logits = Vec::new();
        for (i, &t) in inner.draft_backlog.iter().enumerate() {
            let pos = plen + state.tokens.len() - inner.draft_backlog.len() + i;
            logits = self.draft.decode1(t, pos)?;
            state.metrics.draft_calls += 1;
        }
        inner.draft_backlog.clear();
        for g in 0..gamma {
            let q = process_logits(&logits, &state.req.params);
            let tok = sample_token(&q, &mut state.rng) as i32;
            chain.push(tok);
            chain_q.push(q);
            if g + 1 < gamma {
                let pos = plen + state.tokens.len() + g;
                logits = self.draft.decode1(tok, pos)?;
                state.metrics.draft_calls += 1;
            }
        }
        state.metrics.phases.draft_s += sw.secs();

        // ---- the verify rows: [root, chain...] as one chain block ----
        let mut block = vec![root];
        block.extend(&chain);
        let base_pos = plen + state.tokens.len() - 1;
        let positions: Vec<usize> = (0..block.len()).map(|i| base_pos + i).collect();
        inner.pending = Some(SpsPending { chain, chain_q });
        Ok(StepPlan::Verify(VerifyRows { tokens: block, positions, block_anc: None }))
    }

    fn absorb(&mut self, state: &mut GenState, ver: &VerifyOut) -> Result<StepOutcome> {
        let gamma = self.gamma;
        let inner = state
            .inner
            .downcast_mut::<SpsState>()
            .context("sps absorb on a foreign GenState")?;
        let SpsPending { chain, chain_q } = inner
            .pending
            .take()
            .context("sps absorb without a planned cycle")?;
        state.metrics.draft_tokens_verified += chain.len();

        // ---- rejection sampling ----
        let sw = Stopwatch::start();
        let target_probs: Vec<Vec<f32>> = (0..chain.len() + 1)
            .map(|i| process_logits(ver.logits.row(i), &state.req.params))
            .collect();
        let verdict = verify_chain(&chain, &chain_q, &target_probs, &mut state.rng);
        state.metrics.phases.sample_s += sw.secs();

        let accepted_rows: Vec<usize> = (0..=verdict.accepted).collect();
        self.target.commit_rows(&accepted_rows, &ver.feats)?;
        let mut emitted: Vec<i32> = chain[..verdict.accepted].to_vec();
        emitted.push(verdict.bonus);
        state.metrics.record_cycle(verdict.accepted, emitted.len());

        // draft cache holds [root, chain[..gamma-1]]: keep root +
        // accepted prefix that it has seen; roll back the rest.
        let in_cache = gamma; // root + gamma-1 chain tokens
        let keep = 1 + verdict.accepted.min(gamma - 1);
        self.draft.rollback(in_cache - keep);
        // backlog: accepted chain tail not in cache (the last accepted
        // token if it was chain[gamma-1]) + the bonus token
        if verdict.accepted == gamma {
            inner.draft_backlog.push(chain[gamma - 1]);
        }
        inner.draft_backlog.push(verdict.bonus);

        let before = state.tokens.len();
        state.tokens.extend(emitted);
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}
