//! Vanilla auto-regressive decoding — the 1.00x baseline every speedup in
//! Table 2 is measured against.

use std::rc::Rc;

use anyhow::Result;

use crate::engine::metrics::Metrics;
use crate::engine::sessions::TargetSession;
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token};
use crate::spec::{truncate_eos, GenOutput, GenRequest, Method};
use crate::util::rng::Rng;
use crate::util::stats::Stopwatch;

pub struct Vanilla {
    target: TargetSession,
}

impl Vanilla {
    pub fn new(rt: Rc<Runtime>, target_w: Rc<Checkpoint>) -> Result<Vanilla> {
        Ok(Vanilla { target: TargetSession::new(rt, target_w)? })
    }
}

impl Method for Vanilla {
    fn name(&self) -> String {
        "vanilla".into()
    }

    fn generate(&mut self, req: &GenRequest) -> Result<GenOutput> {
        let mut metrics = Metrics::default();
        let mut rng = Rng::new(req.params.seed);
        self.target.reset();

        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        metrics.phases.verify_s += sw.secs();
        metrics.target_calls += 1;

        let mut out_tokens = Vec::new();
        let probs = process_logits(&last_logits, &req.params);
        let mut next = sample_token(&probs, &mut rng) as i32;
        out_tokens.push(next);

        while out_tokens.len() < req.max_new
            && *out_tokens.last().unwrap() != crate::tokenizer::EOS
            && self.target.cache.remaining() > 1
        {
            let pos = req.prompt_tokens.len() + out_tokens.len() - 1;
            let sw = Stopwatch::start();
            let out = self.target.decode(&[next], &[pos], None)?;
            metrics.phases.verify_s += sw.secs();
            metrics.target_calls += 1;
            self.target.commit_rows(&[0], &out.feats)?;

            let sw = Stopwatch::start();
            let probs = process_logits(out.logits.row(0), &req.params);
            next = sample_token(&probs, &mut rng) as i32;
            metrics.phases.sample_s += sw.secs();
            out_tokens.push(next);
            metrics.record_cycle(0, 1);
        }
        truncate_eos(&mut out_tokens);
        Ok(GenOutput { tokens: out_tokens, metrics })
    }
}
