//! Vanilla auto-regressive decoding — the 1.00x baseline every speedup in
//! Table 2 is measured against.  One target AR step per `step` call.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::engine::sessions::TargetSession;
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token};
use crate::spec::{GenRequest, GenState, Method, StepOutcome, StepPlan, VerifyOut, VerifyRows};
use crate::util::stats::Stopwatch;

pub struct Vanilla {
    target: TargetSession,
}

/// Marker state: vanilla carries nothing between steps (the prompt
/// length comes from `GenState::req`), but the typed marker still
/// catches a `GenState` from a different method.
struct VanillaState;

impl Vanilla {
    pub fn new(rt: Rc<Runtime>, target_w: Rc<Checkpoint>) -> Result<Vanilla> {
        Ok(Vanilla { target: TargetSession::new(rt, target_w)? })
    }
}

impl Method for Vanilla {
    fn name(&self) -> String {
        "vanilla".into()
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let mut state = GenState::new(req, VanillaState);
        self.target.reset();

        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;

        let probs = process_logits(&last_logits, &req.params);
        let first = sample_token(&probs, &mut state.rng) as i32;
        state.tokens.push(first);
        state.clamp();
        Ok(state)
    }

    fn fused_handle(&mut self) -> Option<&mut TargetSession> {
        Some(&mut self.target)
    }

    /// One AR step as a single-row verify block: even the baseline rides
    /// the fused path, so a pool mixing vanilla and tree methods still
    /// runs one target forward per cycle.
    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        state
            .inner
            .downcast_ref::<VanillaState>()
            .context("vanilla plan on a foreign GenState")?;
        let plen = state.req.prompt_tokens.len();
        if state.done || self.target.cache.remaining() <= 1 {
            state.finish();
            return Ok(StepPlan::Finished(StepOutcome { emitted: 0, done: true }));
        }
        let next = *state.tokens.last().context("session has no tokens")?;
        let pos = plen + state.tokens.len() - 1;
        Ok(StepPlan::Verify(VerifyRows {
            tokens: vec![next],
            positions: vec![pos],
            block_anc: None,
        }))
    }

    fn absorb(&mut self, state: &mut GenState, out: &VerifyOut) -> Result<StepOutcome> {
        state
            .inner
            .downcast_ref::<VanillaState>()
            .context("vanilla absorb on a foreign GenState")?;
        self.target.commit_rows(&[0], &out.feats)?;

        let sw = Stopwatch::start();
        let probs = process_logits(out.logits.row(0), &state.req.params);
        let tok = sample_token(&probs, &mut state.rng) as i32;
        state.metrics.phases.sample_s += sw.secs();

        let before = state.tokens.len();
        state.tokens.push(tok);
        state.metrics.record_cycle(0, 1);
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}
