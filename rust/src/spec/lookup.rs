//! Training-free lookup drafts:
//!
//! * **PLD** (prompt lookup decoding, Saxena 2023): match the current
//!   suffix n-gram against the prompt + generated history; propose the
//!   tokens that followed the match.
//! * **Lookahead** (Fu et al. 2023), simplified: an online n-gram pool
//!   harvested from the generated stream proposes continuations.  (The full
//!   Jacobi-trajectory pool is out of scope; this preserves the
//!   verification branch + n-gram cache essence — see DESIGN.md §2.)
//!
//! Both verify a proposed chain with one target call and accept by
//! sample-then-match (argmax matching at T=0, the only temperature the
//! paper reports for these methods).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use crate::engine::metrics::Metrics;
use crate::engine::sessions::TargetSession;
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token};
use crate::spec::{truncate_eos, GenOutput, GenRequest, Method};
use crate::tokenizer::EOS;
use crate::util::rng::Rng;
use crate::util::stats::Stopwatch;

#[derive(Clone, Copy, PartialEq)]
pub enum LookupKind {
    Pld,
    Lookahead,
}

pub struct Lookup {
    target: TargetSession,
    kind: LookupKind,
    max_chain: usize,
    ngram: usize,
}

impl Lookup {
    pub fn new(
        rt: Rc<Runtime>,
        target_w: Rc<Checkpoint>,
        kind: LookupKind,
        max_chain: usize,
    ) -> Result<Lookup> {
        Ok(Lookup {
            target: TargetSession::new(rt, target_w)?,
            kind,
            max_chain,
            ngram: 3,
        })
    }

    /// PLD: longest-suffix match in history; returns following tokens.
    fn propose_pld(&self, history: &[i32]) -> Vec<i32> {
        for n in (1..=self.ngram.min(history.len().saturating_sub(1))).rev() {
            let suffix = &history[history.len() - n..];
            // scan backwards for the most recent earlier occurrence
            let limit = history.len() - n;
            for start in (0..limit).rev() {
                if &history[start..start + n] == suffix {
                    let from = start + n;
                    let to = (from + self.max_chain).min(history.len() - n);
                    if from < to {
                        return history[from..to].to_vec();
                    }
                }
            }
        }
        Vec::new()
    }

    /// Lookahead: pool of bigram -> continuation harvested online.
    fn propose_pool(
        &self,
        pool: &HashMap<(i32, i32), Vec<i32>>,
        history: &[i32],
    ) -> Vec<i32> {
        if history.len() < 2 {
            return Vec::new();
        }
        let key = (history[history.len() - 2], history[history.len() - 1]);
        let mut out = Vec::new();
        let mut cur = key;
        while out.len() < self.max_chain {
            match pool.get(&cur) {
                Some(cont) if !cont.is_empty() => {
                    let nxt = cont[cont.len() - 1]; // most recent continuation
                    out.push(nxt);
                    cur = (cur.1, nxt);
                }
                _ => break,
            }
        }
        out
    }
}

impl Method for Lookup {
    fn name(&self) -> String {
        match self.kind {
            LookupKind::Pld => "pld".into(),
            LookupKind::Lookahead => "lookahead".into(),
        }
    }

    fn generate(&mut self, req: &GenRequest) -> Result<GenOutput> {
        let mut metrics = Metrics::default();
        let mut rng = Rng::new(req.params.seed);
        self.target.reset();
        let plen = req.prompt_tokens.len();

        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        metrics.phases.verify_s += sw.secs();
        metrics.target_calls += 1;

        let mut out_tokens = Vec::new();
        let probs = process_logits(&last_logits, &req.params);
        out_tokens.push(sample_token(&probs, &mut rng) as i32);

        let mut pool: HashMap<(i32, i32), Vec<i32>> = HashMap::new();
        // seed the pool from the prompt
        for w in req.prompt_tokens.windows(3) {
            pool.entry((w[0], w[1])).or_default().push(w[2]);
        }

        while out_tokens.len() < req.max_new
            && *out_tokens.last().unwrap() != EOS
            && self.target.cache.remaining() > self.max_chain + 2
        {
            let root = *out_tokens.last().unwrap();
            let mut history = req.prompt_tokens.clone();
            history.extend(&out_tokens);

            let sw = Stopwatch::start();
            let chain = match self.kind {
                LookupKind::Pld => self.propose_pld(&history),
                LookupKind::Lookahead => self.propose_pool(&pool, &history),
            };
            metrics.phases.draft_s += sw.secs();

            let mut block = vec![root];
            block.extend(&chain);
            let base_pos = plen + out_tokens.len() - 1;
            let positions: Vec<usize> = (0..block.len()).map(|i| base_pos + i).collect();

            let sw = Stopwatch::start();
            let ver = self.target.decode(&block, &positions, None)?;
            metrics.phases.verify_s += sw.secs();
            metrics.target_calls += 1;
            metrics.draft_tokens_verified += chain.len();

            // chain walk: sample at each position; accept while it matches
            let sw = Stopwatch::start();
            let mut accepted = 0usize;
            let mut emitted: Vec<i32> = Vec::new();
            loop {
                let probs = process_logits(ver.logits.row(accepted), &req.params);
                let x = if req.params.greedy() {
                    crate::sampling::argmax(&probs) as i32
                } else {
                    sample_token(&probs, &mut rng) as i32
                };
                if accepted < chain.len() && x == chain[accepted] && x != EOS {
                    emitted.push(x);
                    accepted += 1;
                } else {
                    emitted.push(x);
                    break;
                }
            }
            metrics.phases.sample_s += sw.secs();

            let accepted_rows: Vec<usize> = (0..=accepted).collect();
            self.target.commit_rows(&accepted_rows, &ver.feats)?;
            metrics.record_cycle(accepted, emitted.len());

            // harvest pool n-grams from newly emitted tokens
            let mut h2 = history.clone();
            h2.extend(&emitted);
            let start = h2.len().saturating_sub(emitted.len() + 2);
            for w in h2[start..].windows(3) {
                let e = pool.entry((w[0], w[1])).or_default();
                e.push(w[2]);
                if e.len() > 8 {
                    e.remove(0);
                }
            }
            out_tokens.extend(emitted);
        }
        if out_tokens.len() > req.max_new {
            out_tokens.truncate(req.max_new);
        }
        truncate_eos(&mut out_tokens);
        Ok(GenOutput { tokens: out_tokens, metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // propose_pld is pure — test without a runtime
    fn mk() -> Lookup {
        // SAFETY: construct via raw parts is impossible; instead test the
        // algorithm through a tiny shim replicating propose_pld.
        unimplemented!()
    }

    #[test]
    fn pld_matching_logic() {
        // replicate propose_pld standalone to keep it runtime-free
        fn propose(history: &[i32], ngram: usize, max_chain: usize) -> Vec<i32> {
            for n in (1..=ngram.min(history.len().saturating_sub(1))).rev() {
                let suffix = &history[history.len() - n..];
                let limit = history.len() - n;
                for start in (0..limit).rev() {
                    if &history[start..start + n] == suffix {
                        let from = start + n;
                        let to = (from + max_chain).min(history.len() - n);
                        if from < to {
                            return history[from..to].to_vec();
                        }
                    }
                }
            }
            Vec::new()
        }
        // history: "a b c X a b c" -> suffix [a,b,c] matches at 0, proposes [X]
        let h = [10, 11, 12, 99, 10, 11, 12];
        assert_eq!(propose(&h, 3, 5), vec![99]);
        // no repeat -> empty
        assert_eq!(propose(&[1, 2, 3, 4], 3, 5), Vec::<i32>::new());
        let _ = mk as fn() -> Lookup; // silence dead_code for the shim
    }
}
