//! Training-free lookup drafts:
//!
//! * **PLD** (prompt lookup decoding, Saxena 2023): match the current
//!   suffix n-gram against the prompt + generated history; propose the
//!   tokens that followed the match.
//! * **Lookahead** (Fu et al. 2023), simplified: an online n-gram pool
//!   harvested from the generated stream proposes continuations.  (The full
//!   Jacobi-trajectory pool is out of scope; this preserves the
//!   verification branch + n-gram cache essence — see DESIGN.md §2.)
//!
//! Both verify a proposed chain with one target call and accept by
//! sample-then-match (argmax matching at T=0, the only temperature the
//! paper reports for these methods).  One proposed chain per `step` call.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::engine::sessions::TargetSession;
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token};
use crate::spec::{GenRequest, GenState, Method, StepOutcome, StepPlan};
use crate::tokenizer::EOS;
use crate::util::stats::Stopwatch;

#[derive(Clone, Copy, PartialEq)]
pub enum LookupKind {
    Pld,
    Lookahead,
}

pub struct Lookup {
    target: TargetSession,
    kind: LookupKind,
    max_chain: usize,
    ngram: usize,
}

/// Per-session carry-over: the online n-gram pool (Lookahead).
struct LookupState {
    pool: HashMap<(i32, i32), Vec<i32>>,
}

impl Lookup {
    pub fn new(
        rt: Rc<Runtime>,
        target_w: Rc<Checkpoint>,
        kind: LookupKind,
        max_chain: usize,
    ) -> Result<Lookup> {
        Ok(Lookup {
            target: TargetSession::new(rt, target_w)?,
            kind,
            max_chain,
            ngram: 3,
        })
    }

    /// PLD: longest-suffix match in history; returns following tokens.
    fn propose_pld(&self, history: &[i32]) -> Vec<i32> {
        for n in (1..=self.ngram.min(history.len().saturating_sub(1))).rev() {
            let suffix = &history[history.len() - n..];
            // scan backwards for the most recent earlier occurrence
            let limit = history.len() - n;
            for start in (0..limit).rev() {
                if &history[start..start + n] == suffix {
                    let from = start + n;
                    let to = (from + self.max_chain).min(history.len() - n);
                    if from < to {
                        return history[from..to].to_vec();
                    }
                }
            }
        }
        Vec::new()
    }

    /// Lookahead: pool of bigram -> continuation harvested online.
    fn propose_pool(
        &self,
        pool: &HashMap<(i32, i32), Vec<i32>>,
        history: &[i32],
    ) -> Vec<i32> {
        if history.len() < 2 {
            return Vec::new();
        }
        let key = (history[history.len() - 2], history[history.len() - 1]);
        let mut out = Vec::new();
        let mut cur = key;
        while out.len() < self.max_chain {
            match pool.get(&cur) {
                Some(cont) if !cont.is_empty() => {
                    let nxt = cont[cont.len() - 1]; // most recent continuation
                    out.push(nxt);
                    cur = (cur.1, nxt);
                }
                _ => break,
            }
        }
        out
    }
}

impl Method for Lookup {
    fn name(&self) -> String {
        match self.kind {
            LookupKind::Pld => "pld".into(),
            LookupKind::Lookahead => "lookahead".into(),
        }
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        // seed the pool from the prompt
        let mut pool: HashMap<(i32, i32), Vec<i32>> = HashMap::new();
        for w in req.prompt_tokens.windows(3) {
            pool.entry((w[0], w[1])).or_default().push(w[2]);
        }
        let mut state = GenState::new(req, LookupState { pool });
        self.target.reset();

        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;

        let probs = process_logits(&last_logits, &req.params);
        let first = sample_token(&probs, &mut state.rng) as i32;
        state.tokens.push(first);
        state.clamp();
        Ok(state)
    }

    /// Lookup chains cannot batch: the proposal depends on the emitted
    /// history *at verify time* (the n-gram pool is harvested from the
    /// accept walk), and the chain walk re-reads the proposal inline — so
    /// the method declares itself unbatchable and keeps the solo `step`
    /// path.  Explicit (rather than inheriting the default) so the intent
    /// survives refactors.
    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        let _ = state;
        Ok(StepPlan::Unbatchable)
    }

    fn step(&mut self, state: &mut GenState) -> Result<StepOutcome> {
        let inner = state
            .inner
            .downcast_mut::<LookupState>()
            .context("lookup step on a foreign GenState")?;
        // the verify call burns a full padded decode block of target slots
        let verify_n = crate::engine::sessions::padded_span(self.max_chain + 1);
        if state.done || self.target.cache.remaining() <= verify_n + 1 {
            state.finish();
            return Ok(StepOutcome { emitted: 0, done: true });
        }
        let plen = state.req.prompt_tokens.len();
        let root = *state.tokens.last().context("session has no tokens")?;
        let mut history = state.req.prompt_tokens.clone();
        history.extend(&state.tokens);

        let sw = Stopwatch::start();
        let chain = match self.kind {
            LookupKind::Pld => self.propose_pld(&history),
            LookupKind::Lookahead => self.propose_pool(&inner.pool, &history),
        };
        state.metrics.phases.draft_s += sw.secs();

        let mut block = vec![root];
        block.extend(&chain);
        let base_pos = plen + state.tokens.len() - 1;
        let positions: Vec<usize> = (0..block.len()).map(|i| base_pos + i).collect();

        let sw = Stopwatch::start();
        let ver = self.target.decode(&block, &positions, None)?;
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;
        state.metrics.draft_tokens_verified += chain.len();

        // chain walk: sample at each position; accept while it matches
        let sw = Stopwatch::start();
        let mut accepted = 0usize;
        let mut emitted: Vec<i32> = Vec::new();
        loop {
            let probs = process_logits(ver.logits.row(accepted), &state.req.params);
            let x = if state.req.params.greedy() {
                crate::sampling::argmax(&probs) as i32
            } else {
                sample_token(&probs, &mut state.rng) as i32
            };
            if accepted < chain.len() && x == chain[accepted] && x != EOS {
                emitted.push(x);
                accepted += 1;
            } else {
                emitted.push(x);
                break;
            }
        }
        state.metrics.phases.sample_s += sw.secs();

        let accepted_rows: Vec<usize> = (0..=accepted).collect();
        self.target.commit_rows(&accepted_rows, &ver.feats)?;
        state.metrics.record_cycle(accepted, emitted.len());

        // harvest pool n-grams from newly emitted tokens
        let mut h2 = history.clone();
        h2.extend(&emitted);
        let start = h2.len().saturating_sub(emitted.len() + 2);
        for w in h2[start..].windows(3) {
            let e = inner.pool.entry((w[0], w[1])).or_default();
            e.push(w[2]);
            if e.len() > 8 {
                e.remove(0);
            }
        }
        let before = state.tokens.len();
        state.tokens.extend(emitted);
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn pld_matching_logic() {
        // replicate propose_pld standalone to keep it runtime-free
        fn propose(history: &[i32], ngram: usize, max_chain: usize) -> Vec<i32> {
            for n in (1..=ngram.min(history.len().saturating_sub(1))).rev() {
                let suffix = &history[history.len() - n..];
                let limit = history.len() - n;
                for start in (0..limit).rev() {
                    if &history[start..start + n] == suffix {
                        let from = start + n;
                        let to = (from + max_chain).min(history.len() - n);
                        if from < to {
                            return history[from..to].to_vec();
                        }
                    }
                }
            }
            Vec::new()
        }
        // history: "a b c X a b c" -> suffix [a,b,c] matches at 0, proposes [X]
        let h = [10, 11, 12, 99, 10, 11, 12];
        assert_eq!(propose(&h, 3, 5), vec![99]);
        // no repeat -> empty
        assert_eq!(propose(&[1, 2, 3, 4], 3, 5), Vec::<i32>::new());
    }
}
