//! Medusa (Cai et al. 2024): K independent feature heads on the frozen
//! target predict tokens t+1..t+K; a sparse static tree over per-head
//! top-k ranks is verified in one target call.  One head-predict +
//! tree-verify cycle per `step` call.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::engine::sessions::{MedusaHeads, TargetSession};
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token, topk};
use crate::spec::{
    accept_walk, GenRequest, GenState, Method, StepOutcome, StepPlan, VerifyOut, VerifyRows,
};
use crate::tree::{medusa_template, Tree, VerifyPlan};
use crate::util::stats::Stopwatch;

pub struct Medusa {
    target: TargetSession,
    heads: MedusaHeads,
    template: Vec<Vec<usize>>,
}

/// Per-session carry-over: the feature row the heads read next cycle,
/// plus the flattened tree awaiting `absorb`.
struct MedusaState {
    head_feat: Vec<f32>,
    pending_plan: Option<VerifyPlan>,
}

impl Medusa {
    pub fn new(
        rt: Rc<Runtime>,
        target_w: Rc<Checkpoint>,
        medusa_w: Rc<Checkpoint>,
    ) -> Result<Medusa> {
        let heads = MedusaHeads::new(rt.clone(), medusa_w, &target_w)?;
        Ok(Medusa {
            target: TargetSession::new(rt, target_w)?,
            heads,
            template: medusa_template(),
        })
    }

    /// Build the static tree from per-head top-k logits.  A node with rank
    /// path [r1..rd] carries head_d's rank-r_d token; its score is the sum
    /// of the heads' log-probs (ordering only).
    fn build_tree(&self, root_token: i32, head_logits: &[Vec<f32>]) -> Tree {
        let max_rank = 1 + self
            .template
            .iter()
            .flat_map(|p| p.iter().copied())
            .max()
            .unwrap_or(0);
        let head_top: Vec<Vec<(f32, usize)>> = head_logits
            .iter()
            .map(|l| {
                let sm = crate::sampling::log_softmax(l);
                topk(&sm, max_rank)
            })
            .collect();
        let mut tree = Tree::new(root_token);
        let mut node_of_path: std::collections::HashMap<Vec<usize>, usize> =
            std::collections::HashMap::new();
        let mut paths = self.template.clone();
        paths.sort_by_key(|p| p.len()); // parents first
        for path in paths {
            let depth = path.len();
            if depth > head_top.len() {
                continue;
            }
            let parent = if depth == 1 {
                0
            } else {
                match node_of_path.get(&path[..depth - 1].to_vec()) {
                    Some(&p) => p,
                    None => continue,
                }
            };
            let rank = path[depth - 1];
            // skip ranks the vocabulary can't fill (same guard as the
            // static EAGLE tree: topk may return fewer than max_rank)
            let Some(&(lp, tok)) = head_top[depth - 1].get(rank) else {
                continue;
            };
            let idx = tree.add_child(parent, tok as i32, lp);
            node_of_path.insert(path.clone(), idx);
        }
        tree
    }
}

impl Method for Medusa {
    fn name(&self) -> String {
        "medusa".into()
    }

    fn start(&mut self, req: &GenRequest) -> Result<GenState> {
        let plen = req.prompt_tokens.len();
        self.target.reset();

        let mut state =
            GenState::new(req, MedusaState { head_feat: Vec::new(), pending_plan: None });
        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;

        let probs = process_logits(&last_logits, &req.params);
        let first = sample_token(&probs, &mut state.rng) as i32;
        state.tokens.push(first);
        // heads read the feature of the last committed position
        state
            .inner
            .downcast_mut::<MedusaState>()
            .context("fresh medusa state")?
            .head_feat = self.target.feats[plen - 1].clone();
        state.clamp();
        Ok(state)
    }

    fn fused_handle(&mut self) -> Option<&mut TargetSession> {
        Some(&mut self.target)
    }

    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        let inner = state
            .inner
            .downcast_mut::<MedusaState>()
            .context("medusa plan on a foreign GenState")?;
        // capacity vs the PADDED verify block (the call burns a full
        // compiled width of slots), plus the post-accept margin
        let verify_n = crate::engine::sessions::padded_span(self.template.len() + 1);
        if state.done || self.target.cache.remaining() <= verify_n + 2 {
            state.finish();
            return Ok(StepPlan::Finished(StepOutcome { emitted: 0, done: true }));
        }
        let plen = state.req.prompt_tokens.len();
        let root = *state.tokens.last().context("session has no tokens")?;

        let sw = Stopwatch::start();
        let head_logits = self.heads.predict(&inner.head_feat)?;
        state.metrics.draft_calls += 1;
        let tree = self.build_tree(root, &head_logits);
        let plan = tree.flatten_all();
        state.metrics.phases.draft_s += sw.secs();

        let base_pos = plen + state.tokens.len() - 1;
        let positions: Vec<usize> = plan.depths.iter().map(|&d| base_pos + d).collect();
        let anc = plan.block_mask();
        let rows = VerifyRows { tokens: plan.tokens.clone(), positions, block_anc: Some(anc) };
        inner.pending_plan = Some(plan);
        Ok(StepPlan::Verify(rows))
    }

    fn absorb(&mut self, state: &mut GenState, ver: &VerifyOut) -> Result<StepOutcome> {
        let inner = state
            .inner
            .downcast_mut::<MedusaState>()
            .context("medusa absorb on a foreign GenState")?;
        let plan = inner
            .pending_plan
            .take()
            .context("medusa absorb without a planned cycle")?;
        let sw = Stopwatch::start();
        let walk = accept_walk(&plan, ver, &state.req.params, &mut state.rng, &mut state.metrics);
        state.metrics.phases.sample_s += sw.secs();

        self.target.commit_rows(&walk.accepted_rows, &ver.feats)?;
        inner.head_feat = ver.feats.row(walk.bonus_parent_row).to_vec();
        let before = state.tokens.len();
        state.tokens.extend(&walk.new_tokens);
        let done = state.clamp();
        Ok(StepOutcome { emitted: state.tokens.len().saturating_sub(before), done })
    }
}
