//! Medusa (Cai et al. 2024): K independent feature heads on the frozen
//! target predict tokens t+1..t+K; a sparse static tree over per-head
//! top-k ranks is verified in one target call.

use std::rc::Rc;

use anyhow::Result;

use crate::engine::metrics::Metrics;
use crate::engine::sessions::{MedusaHeads, TargetSession};
use crate::runtime::{Checkpoint, Runtime};
use crate::sampling::{process_logits, sample_token, topk};
use crate::spec::{accept_walk, truncate_eos, GenOutput, GenRequest, Method};
use crate::tokenizer::EOS;
use crate::tree::{medusa_template, Tree};
use crate::util::rng::Rng;
use crate::util::stats::Stopwatch;

pub struct Medusa {
    target: TargetSession,
    heads: MedusaHeads,
    template: Vec<Vec<usize>>,
}

impl Medusa {
    pub fn new(
        rt: Rc<Runtime>,
        target_w: Rc<Checkpoint>,
        medusa_w: Rc<Checkpoint>,
    ) -> Result<Medusa> {
        let heads = MedusaHeads::new(rt.clone(), medusa_w, &target_w)?;
        Ok(Medusa {
            target: TargetSession::new(rt, target_w)?,
            heads,
            template: medusa_template(),
        })
    }

    /// Build the static tree from per-head top-k logits.  A node with rank
    /// path [r1..rd] carries head_d's rank-r_d token; its score is the sum
    /// of the heads' log-probs (ordering only).
    fn build_tree(&self, root_token: i32, head_logits: &[Vec<f32>]) -> Tree {
        let max_rank = 1 + self
            .template
            .iter()
            .flat_map(|p| p.iter().copied())
            .max()
            .unwrap_or(0);
        let head_top: Vec<Vec<(f32, usize)>> = head_logits
            .iter()
            .map(|l| {
                let sm = crate::sampling::log_softmax(l);
                topk(&sm, max_rank)
            })
            .collect();
        let mut tree = Tree::new(root_token);
        let mut node_of_path: std::collections::HashMap<Vec<usize>, usize> =
            std::collections::HashMap::new();
        let mut paths = self.template.clone();
        paths.sort_by_key(|p| p.len()); // parents first
        for path in paths {
            let depth = path.len();
            if depth > head_top.len() {
                continue;
            }
            let parent = if depth == 1 {
                0
            } else {
                match node_of_path.get(&path[..depth - 1].to_vec()) {
                    Some(&p) => p,
                    None => continue,
                }
            };
            let rank = path[depth - 1];
            // skip ranks the vocabulary can't fill (same guard as the
            // static EAGLE tree: topk may return fewer than max_rank)
            let Some(&(lp, tok)) = head_top[depth - 1].get(rank) else {
                continue;
            };
            let idx = tree.add_child(parent, tok as i32, lp);
            node_of_path.insert(path.clone(), idx);
        }
        tree
    }
}

impl Method for Medusa {
    fn name(&self) -> String {
        "medusa".into()
    }

    fn generate(&mut self, req: &GenRequest) -> Result<GenOutput> {
        let mut metrics = Metrics::default();
        let mut rng = Rng::new(req.params.seed);
        self.target.reset();
        let plen = req.prompt_tokens.len();

        let sw = Stopwatch::start();
        let last_logits = self.target.prefill(&req.prompt_tokens)?;
        metrics.phases.verify_s += sw.secs();
        metrics.target_calls += 1;

        let mut out_tokens = Vec::new();
        let probs = process_logits(&last_logits, &req.params);
        out_tokens.push(sample_token(&probs, &mut rng) as i32);
        // heads read the feature of the last committed position
        let mut head_feat: Vec<f32> = self.target.feats[plen - 1].clone();

        while out_tokens.len() < req.max_new
            && *out_tokens.last().unwrap() != EOS
            && self.target.cache.remaining() > self.template.len() + 3
        {
            let root = *out_tokens.last().unwrap();
            let sw = Stopwatch::start();
            let head_logits = self.heads.predict(&head_feat)?;
            metrics.draft_calls += 1;
            let tree = self.build_tree(root, &head_logits);
            let plan = tree.flatten_all();
            metrics.phases.draft_s += sw.secs();

            let base_pos = plen + out_tokens.len() - 1;
            let positions: Vec<usize> = plan.depths.iter().map(|&d| base_pos + d).collect();
            let anc = plan.block_mask();

            let sw = Stopwatch::start();
            let ver = self.target.decode(&plan.tokens, &positions, Some(&anc))?;
            metrics.phases.verify_s += sw.secs();
            metrics.target_calls += 1;

            let sw = Stopwatch::start();
            let walk = accept_walk(&plan, &ver, &req.params, &mut rng, &mut metrics);
            metrics.phases.sample_s += sw.secs();

            self.target.commit_rows(&walk.accepted_rows, &ver.feats)?;
            head_feat = ver.feats.row(walk.bonus_parent_row).to_vec();
            out_tokens.extend(&walk.new_tokens);
        }
        if out_tokens.len() > req.max_new {
            out_tokens.truncate(req.max_new);
        }
        truncate_eos(&mut out_tokens);
        Ok(GenOutput { tokens: out_tokens, metrics })
    }
}
