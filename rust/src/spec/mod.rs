//! Speculative-decoding methods (the paper's comparison set, Table 1/2):
//!
//! | method     | drafts from                  | structure      | module     |
//! |------------|------------------------------|----------------|------------|
//! | vanilla    | —                            | 1 token/step   | vanilla.rs |
//! | SpS        | independent tiny LM          | chain (γ)      | sps.rs     |
//! | PLD        | prompt n-gram lookup         | chain          | lookup.rs  |
//! | Lookahead  | online n-gram pool           | chain          | lookup.rs  |
//! | Medusa     | feature heads on the target  | static tree    | medusa.rs  |
//! | EAGLE      | feature-level draft net      | static tree    | eagle.rs   |
//! | EAGLE-2    | feature-level draft net      | dynamic tree   | eagle.rs   |
//! | HASS       | EAGLE-2 + HASS checkpoint    | dynamic tree   | eagle.rs   |
//!
//! All methods share the target session + the lossless verification walk;
//! HASS differs from EAGLE-2 *only* by its draft checkpoint — exactly the
//! paper's setup (training-time contribution, zero inference overhead).
//!
//! ## The plan/absorb protocol (cross-session batched verification)
//!
//! A drafting-verification cycle is split into two phases so a scheduler
//! can *fuse* the target forward passes of many live sessions into one
//! compiled decode-block call (the target forward dominates wall time,
//! so verification throughput — not draft quality — bounds speedup once
//! hardware is shared across requests):
//!
//! * [`Method::plan`] runs everything up to the target call — drafting,
//!   tree expansion, rerank — and returns a [`StepPlan`]:
//!   [`StepPlan::Verify`] carries the candidate rows ([`VerifyRows`]:
//!   tokens, absolute positions, per-row tree mask) for this cycle;
//!   [`StepPlan::Finished`] means the session ended while planning (cache
//!   exhausted, already done); [`StepPlan::Unbatchable`] means the method
//!   cannot express this cycle as an external verify (lookup chains) and
//!   the caller must fall back to the opaque [`Method::step`].
//! * [`Method::absorb`] consumes the externally produced target outputs
//!   ([`VerifyOut`]: per-row logits + features) for the planned rows —
//!   acceptance walk, KV commit, token emission — exactly as if the
//!   session had run the verify itself.
//!
//! [`Method::step`] is re-derived as `plan` + single-session verify +
//! `absorb` (the default [`Method::verify`] executor), so `generate`,
//! `run_suite`, bench and table callers are untouched, and a solo drive
//! is token-for-token identical to a fused one: each phase only touches
//! per-session state (own RNG stream, own KV caches, own metrics).
//! Schedulers call `plan` on every live session, pack the `Verify` rows
//! into one block-diagonal target call (`engine::sessions::fused_decode`
//! — page-granular since PR 4: each member's committed KV *pages* are
//! staged into a per-worker scratch image, unchanged pages are skipped,
//! and pages shared across sessions occupy one fused segment), scatter
//! the outputs, and `absorb` each session independently.
//!
//! ## The draft-phase protocol (level-synchronous fused expansion)
//!
//! PR 3 fused the *verify* forward, but each EAGLE/HASS session still
//! burned `depth` tiny solo draft calls per cycle — with N co-active
//! sessions the draft net dominates per-cycle graph-call count
//! (`N·depth` draft calls vs one fused verify).  The drafting half of a
//! cycle is therefore also externally drivable, one tree level at a
//! time:
//!
//! * [`Method::draft_next`] returns the next level's rows as a
//!   [`DraftPhase`]: [`DraftPhase::Rows`] carries the level
//!   ([`DraftRows`]: tokens, input features, positions, per-row extra
//!   visible slots, write offset); [`DraftPhase::Ready`] means the tree
//!   is complete (`plan` will emit the verify rows without further draft
//!   calls); [`DraftPhase::Finished`] means the session ended while
//!   drafting; [`DraftPhase::None`] means the method has no externally
//!   drivable draft phase (everything but the EAGLE family and `mock`).
//!   `draft_next` is IDEMPOTENT until the pending level is fed — a fused
//!   executor that fails can simply walk away and the solo path resumes
//!   from the same rows.
//! * [`Method::draft_feed`] consumes the level's draft outputs (child
//!   expansion, frontier/beam bookkeeping, commit of the pending rows on
//!   level 0) exactly as if the session had run the level itself.
//!
//! [`Method::plan`] is re-derived as drive-to-completion — it loops
//! `draft_next` → solo execute → `draft_feed` until `Ready` — so solo
//! callers are untouched and solo == fused token-for-token.  Schedulers
//! instead run the loop ACROSS sessions: each round they collect every
//! live session's level and fuse the rows into one
//! `engine::sessions::fused_draft_decode` graph call (draft pages packed
//! page-granular like verify packing; host-model methods batch through
//! their shared [`Method::host_drafter`]), feed each session, and
//! repeat until every tree is built — per-group draft calls per cycle
//! drop from `N·depth` to `~depth`.
//!
//! ## Audited invariants (`HASS_CHECK=1` shadow sanitizer)
//!
//! The solo == fused guarantee rests on a handful of cross-layer
//! invariants that no single module can see whole.  Debug builds with
//! `HASS_CHECK=1` re-verify them at every call boundary
//! (`kvcache::audit` + `util::lockorder`):
//!
//! * **page identity** — a live `(page id, stamp)` pair maps to exactly
//!   one content hash pool-wide, and every bump of a page's bytes bumps
//!   its stamp (the staleness signal `sync_image` keys on);
//! * **image equality** — a synced cache image (the incremental
//!   contiguous view) is byte-identical to materializing the page
//!   table from scratch;
//! * **pack equality** — every fused segment in a
//!   [`crate::kvcache::FusedScratch`] matches its member page's bytes,
//!   shared pages appearing once;
//! * **mask soundness** — each packed block-diagonal / sparse
//!   visibility mask equals an independent per-slot recomputation
//!   (members never see each other's rows);
//! * **scatter landing** — fused verify/draft outputs land on exactly
//!   the rows the member planned (`engine::sessions` re-reads them
//!   back);
//! * **lock order** — scheduler locks follow one global class order
//!   (queue < shared-rx < stats < cancels), checked per-acquisition.
//!
//! The static side of the same contract — no `unwrap` on the fused
//! path, `Send`-hygiene, stamp-discipline markers, wire-key drift,
//! panic isolation — is enforced offline by `rust/analyze`
//! (`cargo run -p hass-analyze -- rust/src`, also `hass analyze`).

pub mod eagle;
pub mod lookup;
pub mod medusa;
pub mod mock;
pub mod sps;
pub mod vanilla;

use std::any::Any;

use anyhow::Result;

use crate::engine::metrics::Metrics;
use crate::engine::sessions::{DecodeOut, DraftSession, TargetSession};
use crate::sampling::{accept_at_node, process_logits, SampleParams};
use crate::tokenizer::EOS;
use crate::tree::VerifyPlan;
use crate::util::rng::Rng;
use crate::util::stats::Stopwatch;

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt_tokens: Vec<i32>,
    pub max_new: usize,
    pub params: SampleParams,
}

#[derive(Clone, Debug)]
pub struct GenOutput {
    pub tokens: Vec<i32>,
    pub metrics: Metrics,
}

/// Resumable per-request generation state.  The shared fields (emitted
/// tokens, metrics, RNG stream) live here so schedulers can observe
/// progress between steps without knowing the method; `inner` carries the
/// method-specific carry-over (pending commit rows, n-gram pools, ...).
pub struct GenState {
    pub req: GenRequest,
    pub rng: Rng,
    /// tokens emitted so far (clamped to `max_new`, cut at EOS)
    pub tokens: Vec<i32>,
    pub metrics: Metrics,
    /// the output is final; further `step` calls are no-ops
    pub done: bool,
    /// method-specific resumable state (downcast by the owning method)
    pub inner: Box<dyn Any>,
    /// `tokens[..checked]` is known EOS-free (incremental clamp watermark)
    checked: usize,
}

impl GenState {
    pub fn new<T: Any>(req: &GenRequest, inner: T) -> GenState {
        GenState {
            req: req.clone(),
            rng: Rng::new(req.params.seed),
            tokens: Vec::new(),
            metrics: Metrics::default(),
            done: false,
            inner: Box::new(inner),
            checked: 0,
        }
    }

    /// Enforce the output invariants after a cycle extended `tokens`:
    /// truncate at (and including) the first EOS, clamp to `max_new`, and
    /// mark the session done when either fires.  Only the newly appended
    /// suffix is scanned, so per-step cost stays O(new tokens).
    pub fn clamp(&mut self) -> bool {
        if let Some(p) = self.tokens[self.checked..].iter().position(|&t| t == EOS) {
            self.tokens.truncate(self.checked + p + 1);
            self.done = true;
        }
        if self.tokens.len() >= self.req.max_new {
            self.tokens.truncate(self.req.max_new);
            self.done = true;
        }
        self.checked = self.tokens.len();
        self.done
    }

    /// Clamp and mark done unconditionally (cache exhausted, EOS, ...).
    pub fn finish(&mut self) {
        self.clamp();
        self.done = true;
    }

    pub fn into_output(self) -> GenOutput {
        GenOutput { tokens: self.tokens, metrics: self.metrics }
    }
}

/// What one `Method::step` call did.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// tokens appended to `GenState::tokens` by this step (post-clamp)
    pub emitted: usize,
    /// the session is finished (mirrors `GenState::done`)
    pub done: bool,
}

/// Target outputs for one session's planned verification rows (per-row
/// logits + post-LN features) — produced by a solo verify or scattered
/// out of a fused call.
pub type VerifyOut = DecodeOut;

/// Candidate rows one session wants target-verified this cycle (row 0 is
/// the tree root / chain head).
#[derive(Clone, Debug)]
pub struct VerifyRows {
    pub tokens: Vec<i32>,
    /// absolute sequence position of each row
    pub positions: Vec<usize>,
    /// intra-block visibility: `mask[a][b]` == row a may attend to row b
    /// (self included).  `None` = chain semantics (row i sees rows 0..=i).
    pub block_anc: Option<Vec<Vec<bool>>>,
}

impl VerifyRows {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// One draft-tree level a session wants executed (module docs: the
/// draft-phase protocol).  Row i's KV lands at `write_start + i`; slots
/// in `extra_visible[i]` name this session's draft cache — committed
/// prefix excluded (always visible), scratch ancestors and earlier rows
/// of this same level included.
#[derive(Clone, Debug)]
pub struct DraftRows {
    pub tokens: Vec<i32>,
    /// input feature per row (parent's draft feature; empty rows for
    /// host-model drafters, which condition on (token, position) alone)
    pub feats: Vec<Vec<f32>>,
    /// absolute sequence position of each row
    pub positions: Vec<usize>,
    /// per-row extra visible draft-cache slots beyond the committed prefix
    pub extra_visible: Vec<Vec<usize>>,
    /// draft-cache slot where this level's KV rows are written
    pub write_start: usize,
}

impl DraftRows {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// What `Method::draft_next` decided (module docs: the draft-phase
/// protocol).
pub enum DraftPhase {
    /// execute these rows through the draft net, then call `draft_feed`
    Rows(DraftRows),
    /// the draft tree is complete; `plan` emits the verify rows without
    /// further draft calls
    Ready,
    /// the session finished while drafting (cache exhausted, already done)
    Finished(StepOutcome),
    /// this method has no externally drivable draft phase
    None,
}

/// What `Method::plan` decided for this cycle (module docs).
pub enum StepPlan {
    /// verify these rows through one (possibly fused) target forward,
    /// then call `absorb` with the outputs
    Verify(VerifyRows),
    /// this cycle cannot be expressed as plan/absorb — drive the session
    /// with `step` instead (plan had no side effects)
    Unbatchable,
    /// the session finished while planning (no verify needed)
    Finished(StepOutcome),
}

/// A runtime-free batch verifier shared by every instance of a method
/// (e.g. `mock`): rows from many sessions are concatenated into one call
/// and the outputs scattered back, mirroring the compiled fused path.
pub type HostVerifier = fn(&[i32], &[usize]) -> VerifyOut;

/// A speculative-decoding method as a resumable state machine.
///
/// `start` prefills and samples the first token; each `step` advances one
/// unit of work — a draft-expand-verify cycle (eagle/medusa), one γ-chain
/// or lookup chain (sps/pld/lookahead), one AR token (vanilla).  Schedulers
/// interleave steps of many sessions for cycle-granular continuous
/// batching; `generate` is the run-to-completion wrapper the suite/bench
/// callers use.
///
/// A `Method` instance hosts at most ONE live session at a time (its model
/// sessions/KV caches are per-instance): calling `start` again invalidates
/// any earlier `GenState` from the same instance.
pub trait Method {
    fn name(&self) -> String;

    /// Begin a session: reset model sessions, prefill the prompt, sample
    /// the first token.  The returned state may already be `done` (e.g.
    /// `max_new <= 1`).
    fn start(&mut self, req: &GenRequest) -> Result<GenState>;

    /// Phase 0 of a cycle (optional): the next draft-tree level to
    /// execute, for level-synchronous cross-session fusion (module docs).
    /// Idempotent until the pending level is fed; the default declares
    /// the method free of an externally drivable draft phase.
    fn draft_next(&mut self, state: &mut GenState) -> Result<DraftPhase> {
        let _ = state;
        Ok(DraftPhase::None)
    }

    /// Consume the outputs of the level the last `draft_next` emitted
    /// (child expansion + frontier bookkeeping; KV rows were already
    /// written by the executor).
    fn draft_feed(&mut self, state: &mut GenState, out: &DecodeOut) -> Result<()> {
        let _ = (state, out);
        anyhow::bail!("method '{}' has no draft phase", self.name())
    }

    /// The draft session used for fused draft expansion, if this method
    /// drafts through a compiled draft graph.  Schedulers pack co-active
    /// sessions' levels into one `draft_decode` call.
    fn draft_handle(&mut self) -> Option<&mut DraftSession> {
        None
    }

    /// Runtime-free batch draft model (same shape as [`HostVerifier`]);
    /// methods expose one *instead of* a `draft_handle`.
    fn host_drafter(&self) -> Option<HostVerifier> {
        None
    }

    /// Phase 1 of a cycle: draft/expand and emit this cycle's candidate
    /// rows (module docs).  Methods with a draft phase drive any
    /// unfinished walk to completion here (the solo path and the
    /// fused-failure fallback).  The default declares the method
    /// unbatchable, which routes schedulers to the opaque `step`.
    fn plan(&mut self, state: &mut GenState) -> Result<StepPlan> {
        let _ = state;
        Ok(StepPlan::Unbatchable)
    }

    /// Phase 2 of a cycle: acceptance walk + KV commit from externally
    /// supplied target outputs for the rows the last `plan` emitted.
    fn absorb(&mut self, state: &mut GenState, out: &VerifyOut) -> Result<StepOutcome> {
        let _ = (state, out);
        anyhow::bail!("method '{}' does not implement plan/absorb", self.name())
    }

    /// The target session used for fused verification, if this method
    /// verifies through a compiled target graph.  Schedulers pack the
    /// sessions of co-active `plan`s into one decode-block call.
    fn fused_handle(&mut self) -> Option<&mut TargetSession> {
        None
    }

    /// Runtime-free batch verifier (see [`HostVerifier`]); methods expose
    /// one *instead of* a `fused_handle`.
    fn host_verifier(&self) -> Option<HostVerifier> {
        None
    }

    /// Single-session verify executor for the rows `plan` emitted: the
    /// solo counterpart of a fused call, charging the session one target
    /// call.  Methods normally inherit this.
    fn verify(&mut self, state: &mut GenState, rows: &VerifyRows) -> Result<VerifyOut> {
        let sw = Stopwatch::start();
        let out = if let Some(hv) = self.host_verifier() {
            hv(&rows.tokens, &rows.positions)
        } else if let Some(t) = self.fused_handle() {
            t.decode(&rows.tokens, &rows.positions, rows.block_anc.as_deref())?
        } else {
            anyhow::bail!("method '{}' has no verify executor", self.name())
        };
        state.metrics.phases.verify_s += sw.secs();
        state.metrics.target_calls += 1;
        Ok(out)
    }

    /// Advance the session by one cycle; sets `state.done` when final.
    /// Re-derived as `plan` + solo `verify` + `absorb`, so a step-driven
    /// session is token-for-token identical to a fused one.  Unbatchable
    /// methods override this directly.
    fn step(&mut self, state: &mut GenState) -> Result<StepOutcome> {
        match self.plan(state)? {
            StepPlan::Finished(o) => Ok(o),
            StepPlan::Verify(rows) => {
                let out = self.verify(state, &rows)?;
                self.absorb(state, &out)
            }
            StepPlan::Unbatchable => anyhow::bail!(
                "method '{}' implements neither `step` nor a batchable plan",
                self.name()
            ),
        }
    }

    /// Run a session to completion (default loop over `start` + `step`).
    fn generate(&mut self, req: &GenRequest) -> Result<GenOutput> {
        let mut state = self.start(req)?;
        while !state.done {
            self.step(&mut state)?;
        }
        Ok(state.into_output())
    }
}

/// Method configuration (paper hyper-parameters + ablation knobs).
#[derive(Clone, Debug)]
pub struct MethodCfg {
    /// draft checkpoint name (eagle.rs methods)
    pub draft_ckpt: String,
    /// dynamic-tree depth (EAGLE-2/HASS; paper default 6)
    pub depth: usize,
    /// dynamic-tree total draft tokens kept at rerank (paper default 60)
    pub total_tokens: usize,
    /// dynamic-tree expansion beam (EAGLE-2 top-k; default 10)
    pub beam: usize,
    /// SpS chain length γ
    pub gamma: usize,
    /// PLD/Lookahead max proposed chain
    pub lookup_len: usize,
}

impl Default for MethodCfg {
    fn default() -> Self {
        MethodCfg {
            draft_ckpt: "hass".into(),
            depth: 6,
            total_tokens: 60,
            beam: 10,
            gamma: 4,
            lookup_len: 5,
        }
    }
}

/// Result of the acceptance walk over a verified tree block.
pub struct WalkOutcome {
    /// block rows committed (root + accepted path), strictly increasing
    pub accepted_rows: Vec<usize>,
    /// tokens emitted this cycle (accepted path tokens + bonus)
    pub new_tokens: Vec<i32>,
    /// row whose target distribution produced the bonus (its feature is the
    /// draft input paired with the bonus token next cycle)
    pub bonus_parent_row: usize,
}

/// Lossless acceptance walk (sample-then-match; greedy == argmax matching).
/// `plan` rows must be in BFS order; `out.logits` row i is the target's
/// next-token logits at plan row i.
pub fn accept_walk(
    plan: &VerifyPlan,
    out: &DecodeOut,
    params: &SampleParams,
    rng: &mut Rng,
    metrics: &mut Metrics,
) -> WalkOutcome {
    let mut cur = 0usize;
    let mut accepted_rows = vec![0usize];
    let mut new_tokens = Vec::new();
    let mut depth_accepted = 0usize;
    loop {
        let probs = process_logits(out.logits.row(cur), params);
        let children = &plan.children_rows[cur];
        let child_tokens: Vec<i32> = children.iter().map(|&c| plan.tokens[c]).collect();
        let (hit, x) = accept_at_node(&probs, &child_tokens, rng, params.greedy());
        match hit {
            Some(j) if !children.is_empty() => {
                cur = children[j];
                accepted_rows.push(cur);
                new_tokens.push(plan.tokens[cur]);
                depth_accepted += 1;
                if plan.tokens[cur] == EOS {
                    // EOS accepted: no bonus beyond it
                    metrics.record_cycle(depth_accepted, new_tokens.len());
                    metrics.draft_tokens_verified += plan.len() - 1;
                    return WalkOutcome {
                        accepted_rows,
                        new_tokens,
                        bonus_parent_row: cur,
                    };
                }
            }
            _ => {
                new_tokens.push(x);
                metrics.record_cycle(depth_accepted, new_tokens.len());
                metrics.draft_tokens_verified += plan.len() - 1;
                return WalkOutcome { accepted_rows, new_tokens, bonus_parent_row: cur };
            }
        }
    }
}

/// Truncate an output stream at (and including) the first EOS.
pub fn truncate_eos(tokens: &mut Vec<i32>) -> bool {
    if let Some(p) = tokens.iter().position(|&t| t == EOS) {
        tokens.truncate(p + 1);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TensorF;
    use crate::tree::Tree;

    fn plan_and_logits(vocab: usize) -> (VerifyPlan, DecodeOut) {
        // root(tok 5) -> a(tok 7) -> aa(tok 9); plus sibling b(tok 8)
        let mut t = Tree::new(5);
        let a = t.add_child(0, 7, -0.1);
        let _b = t.add_child(0, 8, -1.0);
        let _aa = t.add_child(a, 9, -0.2);
        let plan = t.flatten_all();
        // logits rows: make row of node X put all mass on its best child
        let n = plan.len();
        let mut logits = vec![-10.0f32; n * vocab];
        for row in 0..n {
            // target prefers token (7,9,...) chain: root->7, a->9, others->0
            let tok = match plan.tokens[row] {
                5 => 7,
                7 => 9,
                _ => 0,
            };
            logits[row * vocab + tok as usize] = 10.0;
        }
        let out = DecodeOut {
            logits: TensorF::new(vec![n, vocab], logits).unwrap(),
            feats: TensorF::zeros(&[n, 4]),
        };
        (plan, out)
    }

    #[test]
    fn greedy_walk_follows_matching_path() {
        let (plan, out) = plan_and_logits(16);
        let mut m = Metrics::default();
        let mut rng = Rng::new(0);
        let params = SampleParams { temperature: 0.0, ..Default::default() };
        let w = accept_walk(&plan, &out, &params, &mut rng, &mut m);
        // path: root -> 7 -> 9, then bonus 0 from node 9's row
        assert_eq!(w.new_tokens, vec![7, 9, 0]);
        assert_eq!(w.accepted_rows.len(), 3);
        assert_eq!(m.cycles, 1);
        assert_eq!(m.new_tokens, 3);
        assert!((m.alpha(0) - 1.0).abs() < 1e-9);
        assert!((m.alpha(1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn walk_rejects_when_target_prefers_other_token() {
        let mut t = Tree::new(5);
        t.add_child(0, 7, -0.1);
        let plan = t.flatten_all();
        let vocab = 16;
        let mut logits = vec![-10.0f32; plan.len() * vocab];
        logits[3] = 10.0; // root row prefers token 3, child is 7 -> reject
        logits[vocab + 1] = 10.0;
        let out = DecodeOut {
            logits: TensorF::new(vec![plan.len(), vocab], logits).unwrap(),
            feats: TensorF::zeros(&[plan.len(), 4]),
        };
        let mut m = Metrics::default();
        let mut rng = Rng::new(0);
        let params = SampleParams { temperature: 0.0, ..Default::default() };
        let w = accept_walk(&plan, &out, &params, &mut rng, &mut m);
        assert_eq!(w.new_tokens, vec![3]);
        assert_eq!(w.accepted_rows, vec![0]);
        assert_eq!(w.bonus_parent_row, 0);
        assert_eq!(m.alpha(0), 0.0);
    }

    #[test]
    fn walk_stops_at_eos() {
        let mut t = Tree::new(5);
        let e = t.add_child(0, EOS, -0.1);
        t.add_child(e, 7, -0.1);
        let plan = t.flatten_all();
        let vocab = 16;
        let mut logits = vec![-10.0f32; plan.len() * vocab];
        for row in 0..plan.len() {
            logits[row * vocab + EOS as usize] = 10.0;
        }
        let out = DecodeOut {
            logits: TensorF::new(vec![plan.len(), vocab], logits).unwrap(),
            feats: TensorF::zeros(&[plan.len(), 4]),
        };
        let mut m = Metrics::default();
        let mut rng = Rng::new(0);
        let params = SampleParams { temperature: 0.0, ..Default::default() };
        let w = accept_walk(&plan, &out, &params, &mut rng, &mut m);
        assert_eq!(w.new_tokens, vec![EOS]);
    }

    #[test]
    fn genstate_clamp_limits_max_new() {
        let req = GenRequest {
            prompt_tokens: vec![1],
            max_new: 4,
            params: SampleParams::default(),
        };
        let mut st = GenState::new(&req, ());
        st.tokens.extend([10, 11]);
        assert!(!st.clamp());
        assert!(!st.done);
        st.tokens.extend([12, 13, 14]);
        assert!(st.clamp());
        assert_eq!(st.tokens, vec![10, 11, 12, 13]);
        assert!(st.done);
    }

    #[test]
    fn genstate_clamp_cuts_at_eos_incrementally() {
        let req = GenRequest {
            prompt_tokens: vec![1],
            max_new: 100,
            params: SampleParams::default(),
        };
        let mut st = GenState::new(&req, ());
        st.tokens.extend([10, 11]);
        assert!(!st.clamp());
        st.tokens.extend([12, EOS, 13]);
        assert!(st.clamp());
        assert_eq!(st.tokens, vec![10, 11, 12, EOS]);
        let out = st.into_output();
        assert_eq!(out.tokens.last(), Some(&EOS));
    }

    #[test]
    fn truncate_at_eos() {
        let mut v = vec![10, 11, EOS, 40];
        assert!(truncate_eos(&mut v));
        assert_eq!(v, vec![10, 11, EOS]);
        let mut w = vec![10, 11];
        assert!(!truncate_eos(&mut w));
    }
}
