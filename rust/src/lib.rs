//! # HASS — Harmonized Speculative Sampling (ICLR 2025), full-system repro
//!
//! A three-layer speculative-decoding serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request scheduler, TCP
//!   server, draft-tree construction (EAGLE-2 dynamic / EAGLE static /
//!   Medusa), lossless verification, KV-cache management, metrics, and the
//!   paper's full table/figure harness.
//! * **L2/L1 (python/, build-time only)** — JAX models + Pallas kernels,
//!   AOT-lowered to HLO-text artifacts that this crate loads through the
//!   PJRT CPU client (`xla` crate).  Python never runs on the request path.
//!
//! Quickstart: see `examples/quickstart.rs`; paper tables: `hass table N`.

pub mod bench;
pub mod engine;
pub mod kvcache;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod spec;
pub mod tables;
pub mod tokenizer;
pub mod tree;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Default artifact directory: `$HASS_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> PathBuf {
    std::env::var("HASS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
