//! # HASS — Harmonized Speculative Sampling (ICLR 2025), full-system repro
//!
//! A three-layer speculative-decoding serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request scheduler, TCP
//!   server, draft-tree construction (EAGLE-2 dynamic / EAGLE static /
//!   Medusa), lossless verification, KV-cache management, metrics, and the
//!   paper's full table/figure harness.
//! * **L2/L1 (python/, build-time only)** — JAX models + Pallas kernels,
//!   AOT-lowered to HLO-text artifacts that this crate loads through the
//!   PJRT CPU client (`xla` crate).  Python never runs on the request path.
//!
//! Quickstart: see `examples/quickstart.rs`; paper tables: `hass table N`.

// CI runs `cargo clippy -p hass -- -D warnings`.  Index-heavy tensor code
// is written in explicit loop style on purpose (mirrors the python/JAX
// reference layer), so the pedantic loop/arg-count style lints are opted
// out crate-wide; everything else denies.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain
)]

pub mod bench;
pub mod engine;
pub mod kvcache;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod spec;
pub mod tables;
pub mod tokenizer;
pub mod tree;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Default artifact directory: `$HASS_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> PathBuf {
    std::env::var("HASS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
