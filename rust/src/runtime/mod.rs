//! L3 runtime: PJRT CPU client wrapping the `xla` crate.
//!
//! Loads HLO-text artifacts produced by `python/compile/aot.py`, compiles
//! them once (lazily, per graph), and executes them from the serving hot
//! path with weights + per-call inputs as literals.  Follows the pattern of
//! /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.

pub mod artifacts;
pub mod tensor;
pub mod weights;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

pub use artifacts::{GraphMeta, Golden, Meta};
pub use tensor::{scalar_i32, TensorF, TensorI};
pub use weights::Checkpoint;

/// Cumulative per-graph call accounting (perf pass instrumentation).
#[derive(Clone, Debug, Default)]
pub struct CallStats {
    pub calls: u64,
    pub secs: f64,
    /// real (un-padded) rows covered by decode-block calls — callers
    /// report them via [`Runtime::record_rows`]; `rows / calls` is the
    /// graph's batch occupancy (fused cross-session verification packs
    /// many sessions' rows into one call, so occupancy rises while
    /// `calls` falls)
    pub rows: u64,
}

impl CallStats {
    /// Mean real rows per call (0 when the graph reports no rows).
    pub fn rows_per_call(&self) -> f64 {
        if self.calls == 0 {
            return 0.0;
        }
        self.rows as f64 / self.calls as f64
    }
}

pub struct Runtime {
    client: PjRtClient,
    meta: Meta,
    weights_dir: PathBuf,
    exes: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    ckpts: RefCell<HashMap<String, std::rc::Rc<Checkpoint>>>,
    stats: RefCell<HashMap<String, CallStats>>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let meta = Meta::load(artifact_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            meta,
            weights_dir: artifact_dir.join("weights"),
            exes: RefCell::new(HashMap::new()),
            ckpts: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn meta(&self) -> &Meta {
        &self.meta
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and cache) a weight checkpoint by name.
    pub fn checkpoint(&self, name: &str) -> Result<std::rc::Rc<Checkpoint>> {
        if let Some(c) = self.ckpts.borrow().get(name) {
            return Ok(c.clone());
        }
        let c = std::rc::Rc::new(Checkpoint::load(&self.weights_dir, name)?);
        self.ckpts.borrow_mut().insert(name.to_string(), c.clone());
        Ok(c)
    }

    pub fn has_checkpoint(&self, name: &str) -> bool {
        self.weights_dir.join(format!("{name}.json")).exists()
    }

    fn ensure_compiled(&self, graph: &str) -> Result<()> {
        if self.exes.borrow().contains_key(graph) {
            return Ok(());
        }
        let gm = self.meta.graph(graph)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&gm.file)
            .with_context(|| format!("parsing {}", gm.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling graph {graph}"))?;
        eprintln!(
            "[runtime] compiled {graph} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        self.exes.borrow_mut().insert(graph.to_string(), exe);
        Ok(())
    }

    /// Execute `graph` with the given argument literals (weights first, in
    /// manifest order, then per-call inputs).  Returns the decomposed
    /// output tuple as literals.
    pub fn call(&self, graph: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        self.ensure_compiled(graph)?;
        let gm = self.meta.graph(graph)?;
        let expected = gm.params.len() + gm.inputs.len();
        if args.len() != expected {
            bail!(
                "graph {graph}: got {} args, expected {} ({} weights + {} inputs)",
                args.len(),
                expected,
                gm.params.len(),
                gm.inputs.len()
            );
        }
        let t0 = Instant::now();
        let exes = self.exes.borrow();
        let exe = exes.get(graph).unwrap();
        let mut out = exe.execute::<&Literal>(args)?;
        let lit = out
            .pop()
            .and_then(|mut v| v.pop())
            .context("empty execution result")?
            .to_literal_sync()?;
        let parts = lit.to_tuple()?;
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(graph.to_string()).or_default();
        e.calls += 1;
        e.secs += t0.elapsed().as_secs_f64();
        Ok(parts)
    }

    /// Sanity-check that a checkpoint's manifest matches a graph's weight
    /// parameter list (names + count), catching stale artifacts early.
    pub fn validate_bundle(&self, graph: &str, ckpt: &Checkpoint, extra: usize) -> Result<()> {
        let gm = self.meta.graph(graph)?;
        if gm.params.len() != ckpt.tensor_names.len() + extra {
            bail!(
                "graph {graph} expects {} weight params, checkpoint '{}' has {} (+{extra} extra)",
                gm.params.len(),
                ckpt.name,
                ckpt.tensor_names.len()
            );
        }
        for (g, c) in gm.params.iter().zip(ckpt.tensor_names.iter()) {
            if g != c {
                bail!("graph {graph} param '{g}' != checkpoint tensor '{c}'");
            }
        }
        Ok(())
    }

    /// Attribute `rows` real (un-padded) block rows to `graph`'s stats —
    /// decode callers report how much useful work each call carried.
    pub fn record_rows(&self, graph: &str, rows: usize) {
        self.stats.borrow_mut().entry(graph.to_string()).or_default().rows += rows as u64;
    }

    pub fn call_stats(&self) -> Vec<(String, CallStats)> {
        let mut v: Vec<_> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.secs.partial_cmp(&a.1.secs).unwrap());
        v
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }
}
