//! Checkpoint loader: flat f32 binary + JSON manifest written by
//! `python/compile/ckpt.py`.  Manifest tensor order == jax pytree flatten
//! order == the weight-argument order every AOT graph expects, so a
//! checkpoint zips 1:1 with a graph's parameter list.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::util::json::{self, Json};

use super::tensor::TensorF;

pub struct Checkpoint {
    pub name: String,
    pub tensor_names: Vec<String>,
    pub tensors: Vec<TensorF>,
    pub literals: Vec<Literal>,
    pub meta: Json,
}

impl Checkpoint {
    pub fn load(dir: &Path, name: &str) -> Result<Checkpoint> {
        let man_path = dir.join(format!("{name}.json"));
        let bin_path = dir.join(format!("{name}.bin"));
        let manifest = json::parse(
            &std::fs::read_to_string(&man_path)
                .with_context(|| format!("reading {}", man_path.display()))?,
        )?;
        let raw = std::fs::read(&bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        if raw.len() % 4 != 0 {
            bail!("{}: bin size not a multiple of 4", bin_path.display());
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let specs = manifest
            .get("tensors")
            .and_then(|t| t.as_arr())
            .context("manifest missing 'tensors'")?;
        let mut tensor_names = Vec::new();
        let mut tensors = Vec::new();
        let mut literals = Vec::new();
        for spec in specs {
            let tname = spec.str_at("name").context("tensor missing name")?.to_string();
            let dims: Vec<usize> = spec
                .get("shape")
                .and_then(|s| s.as_arr())
                .context("tensor missing shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let offset = spec.usize_at("offset").context("tensor missing offset")? / 4;
            let n: usize = dims.iter().product::<usize>().max(1);
            if offset + n > floats.len() {
                bail!("{name}: tensor {tname} overruns bin file");
            }
            let t = TensorF::new(dims, floats[offset..offset + n].to_vec())?;
            literals.push(t.to_literal()?);
            tensor_names.push(tname);
            tensors.push(t);
        }
        let meta = manifest.get("meta").cloned().unwrap_or(Json::Obj(vec![]));
        Ok(Checkpoint { name: name.to_string(), tensor_names, tensors, literals, meta })
    }

    pub fn tensor(&self, name: &str) -> Option<&TensorF> {
        self.tensor_names
            .iter()
            .position(|n| n == name)
            .map(|i| &self.tensors[i])
    }

    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_ckpt(dir: &Path) {
        let data: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("t.bin"), &bytes).unwrap();
        let mut f = std::fs::File::create(dir.join("t.json")).unwrap();
        write!(
            f,
            r#"{{"tensors":[{{"name":"['a']","shape":[2,3],"offset":0}},{{"name":"['b']","shape":[4],"offset":24}}],"meta":{{"kind":"test"}}}}"#
        )
        .unwrap();
    }

    #[test]
    fn load_checkpoint() {
        let dir = std::env::temp_dir().join("hass_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_test_ckpt(&dir);
        let c = Checkpoint::load(&dir, "t").unwrap();
        assert_eq!(c.tensor_names, vec!["['a']", "['b']"]);
        assert_eq!(c.tensors[0].dims, vec![2, 3]);
        assert_eq!(c.tensors[1].data, vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(c.param_count(), 10);
        assert_eq!(c.meta.str_at("kind"), Some("test"));
        assert_eq!(c.tensor("['b']").unwrap().data[0], 6.0);
        assert!(c.tensor("missing").is_none());
    }

    #[test]
    fn overrun_detected() {
        let dir = std::env::temp_dir().join("hass_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let bytes: Vec<u8> = (0..8u8).collect();
        std::fs::write(dir.join("bad.bin"), &bytes).unwrap();
        std::fs::write(
            dir.join("bad.json"),
            r#"{"tensors":[{"name":"x","shape":[100],"offset":0}]}"#,
        )
        .unwrap();
        assert!(Checkpoint::load(&dir, "bad").is_err());
    }
}
