//! Host tensors ⇄ XLA literals.
//!
//! The engine keeps KV caches and weights host-side as flat `Vec`s and
//! materializes `xla::Literal`s at call boundaries (CPU PJRT: literal
//! creation is a memcpy; see DESIGN.md §7 for the perf accounting).

use anyhow::{bail, Result};
use xla::Literal;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorF {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorI {
    pub dims: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(dims: &[usize]) -> usize {
    dims.iter().product::<usize>().max(1)
}

impl TensorF {
    pub fn zeros(dims: &[usize]) -> Self {
        TensorF { dims: dims.to_vec(), data: vec![0.0; numel(dims)] }
    }

    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        if numel(&dims) != data.len() {
            bail!("shape {:?} != data len {}", dims, data.len());
        }
        Ok(TensorF { dims, data })
    }

    pub fn to_literal(&self) -> Result<Literal> {
        // single-copy construction (perf §Perf: vec1+reshape costs two
        // copies; create_from_shape_and_untyped_data costs one)
        f32_literal(&self.dims, &self.data)
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        TensorF::new(dims, data)
    }

    /// Row-major index helper for 2-D tensors.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }

    /// Borrow row i of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.dims[self.dims.len() - 1];
        &self.data[i * w..(i + 1) * w]
    }
}

impl TensorI {
    pub fn zeros(dims: &[usize]) -> Self {
        TensorI { dims: dims.to_vec(), data: vec![0; numel(dims)] }
    }

    pub fn new(dims: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        if numel(&dims) != data.len() {
            bail!("shape {:?} != data len {}", dims, data.len());
        }
        Ok(TensorI { dims, data })
    }

    pub fn to_literal(&self) -> Result<Literal> {
        // single-copy construction, same rationale as TensorF::to_literal
        debug_assert_eq!(self.data.len(), numel(&self.dims), "dims/data desync");
        // SAFETY: an initialized `[i32]` viewed as bytes — 4 bytes per
        // element, no padding or invalid bit patterns, the length covers
        // exactly the slice, and u8's alignment of 1 is always satisfied.
        let bytes = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &self.dims,
            bytes,
        )?)
    }
}

pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Build an f32 literal directly from a host slice (one copy).
pub fn f32_literal(dims: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(data.len(), numel(dims), "dims/data desync");
    // SAFETY: an initialized `[f32]` viewed as bytes — 4 bytes per
    // element, no padding or invalid bit patterns, the length covers
    // exactly the slice, and u8's alignment of 1 is always satisfied.
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(TensorF::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorF::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn indexing() {
        let t = TensorF::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn literal_roundtrip() {
        let t = TensorF::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = TensorF::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn int_literal() {
        let t = TensorI::new(vec![3], vec![7, 8, 9]).unwrap();
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }
}
