//! Artifact registry: `artifacts/meta.json` + `*.hlo.txt` graph inventory.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct GraphMeta {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<String>,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct Golden {
    pub prompt_tokens: Vec<i32>,
    pub greedy_tokens: Vec<i32>,
    pub prefill_logits8: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Meta {
    pub dir: PathBuf,
    pub graphs: BTreeMap<String, GraphMeta>,
    pub goldens: Vec<Golden>,
    pub config: Json,
}

impl Meta {
    pub fn load(dir: &Path) -> Result<Meta> {
        let path = dir.join("meta.json");
        let j = json::parse(
            &std::fs::read_to_string(&path)
                .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?,
        )?;
        let mut graphs = BTreeMap::new();
        for (name, g) in j.get("graphs").and_then(|g| g.as_obj()).unwrap_or(&[]) {
            let inputs = g
                .get("inputs")
                .and_then(|i| i.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|i| InputSpec {
                    name: i.str_at("name").unwrap_or("").to_string(),
                    shape: i
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect(),
                    dtype: i.str_at("dtype").unwrap_or("f32").to_string(),
                })
                .collect();
            graphs.insert(
                name.clone(),
                GraphMeta {
                    name: name.clone(),
                    file: dir.join(g.str_at("file").unwrap_or("")),
                    params: g
                        .get("params")
                        .and_then(|p| p.as_arr())
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|p| p.as_str().map(String::from))
                        .collect(),
                    inputs,
                    outputs: g
                        .get("outputs")
                        .and_then(|o| o.as_arr())
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|o| o.as_str().map(String::from))
                        .collect(),
                },
            );
        }
        let goldens = j
            .get("goldens")
            .and_then(|g| g.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|g| Golden {
                prompt_tokens: ints(g.get("prompt_tokens")),
                greedy_tokens: ints(g.get("greedy_tokens")),
                prefill_logits8: floats(g.get("prefill_logits8")),
            })
            .collect();
        Ok(Meta {
            dir: dir.to_path_buf(),
            graphs,
            goldens,
            config: j.get("config").cloned().unwrap_or(Json::Obj(vec![])),
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphMeta> {
        self.graphs
            .get(name)
            .with_context(|| format!("graph '{name}' not in artifacts (re-run `make artifacts`)"))
    }

    /// Model dimensions from the config block.
    pub fn dim(&self, model: &str, key: &str) -> usize {
        self.config
            .get(model)
            .and_then(|m| m.usize_at(key))
            .unwrap_or(0)
    }

    pub fn cache_slots(&self) -> usize {
        self.config.usize_at("S").unwrap_or(512)
    }
}

fn ints(j: Option<&Json>) -> Vec<i32> {
    j.and_then(|a| a.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_i64().map(|x| x as i32))
        .collect()
}

fn floats(j: Option<&Json>) -> Vec<f32> {
    j.and_then(|a| a.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_f64().map(|x| x as f32))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_minimal_meta() {
        let dir = std::env::temp_dir().join("hass_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"config":{"S":512,"target":{"d_model":128}},
                "graphs":{"g1":{"file":"g1.hlo.txt","params":["['wte']"],
                 "inputs":[{"name":"tokens","shape":[512],"dtype":"i32"}],
                 "outputs":["logits"]}},
                "goldens":[{"prompt_tokens":[1,2],"greedy_tokens":[3],"prefill_logits8":[0.5]}]}"#,
        )
        .unwrap();
        let m = Meta::load(&dir).unwrap();
        let g = m.graph("g1").unwrap();
        assert_eq!(g.params, vec!["['wte']"]);
        assert_eq!(g.inputs[0].shape, vec![512]);
        assert_eq!(g.inputs[0].dtype, "i32");
        assert_eq!(m.goldens.len(), 1);
        assert_eq!(m.goldens[0].greedy_tokens, vec![3]);
        assert_eq!(m.dim("target", "d_model"), 128);
        assert_eq!(m.cache_slots(), 512);
        assert!(m.graph("nope").is_err());
    }
}
