//! Request scheduler: prefix-affinity per-worker queues + a shared
//! overflow queue feeding a pool of engine workers, each running
//! cycle-granular continuous batching with FUSED cross-session
//! verification.
//!
//! The PJRT client (and thus every session) is thread-pinned, so each of
//! the N engine worker threads constructs its own `Runtime` and per-method
//! instance pool locally.  **Dispatch is prefix-affine over least-loaded**:
//! `submit` fingerprints the prompt prefix and routes a known prefix to
//! the worker that last served it — that worker already holds the
//! prompt's pages hot in its fused-pack staging caches, and (pages being
//! pool-wide `Arc`s, see `kvcache`) its sessions share them physically.
//! Unknown prefixes fall back to the worker with the fewest (live
//! sessions + queued jobs), and a load-imbalance **escape hatch** remaps
//! a prefix whose worker is more than [`AFFINITY_MAX_IMBALANCE`] load
//! units above the least-loaded one, so a single hot prefix cannot
//! starve the pool (the pool-wide registry still dedups its pages across
//! workers).  The fingerprint map is bounded ([`AFFINITY_MAP_CAP`]) and
//! per-worker `affinity_hits`/`affinity_misses` land on the stats wire.
//! When the pool-wide backlog reaches `queue_cap`, submissions
//! spill to the shared bounded channel, whose blocking `send` provides
//! the backpressure (workers steal from it between cycles — the
//! steal-from-shared fallback; at most ~2×`queue_cap` jobs sit unserved).
//!
//! **Continuous batching + fused verification.**  `Method` is a resumable
//! state machine split into a two-phase protocol (`plan`/`absorb`, see
//! `spec`): each cycle the worker plans EVERY live session (drafting, tree
//! expansion), packs all batchable sessions' candidate rows into as few
//! compiled decode-block calls as capacity allows
//! (`engine::sessions::fused_decode` — ONE target forward per cycle per
//! worker in the common case), scatters the outputs, and absorbs each
//! session independently.  Grouping capacity is **page-granular** over
//! the paged KV cache: a group must satisfy `(unique pages)·page_size +
//! padded block <= slots`, where pages shared by several sessions
//! (identical prompt prefixes, see `kvcache` COW/dedup) count once — so
//! a shared-prefix fleet fuses past the old `Σ prefixes + block <=
//! slots` ceiling, and the per-cycle host pack cost is bounded by the
//! pages that actually changed (per-worker staging cache in
//! `kvcache::FusedScratch`).  Methods that cannot batch
//! (`StepPlan::Unbatchable`: pld/lookahead) fall back to their solo
//! `step` within the same cycle.
//!
//! **Fused draft expansion** (PR 5).  Before planning, each cycle runs a
//! DRAFT phase: EAGLE-family sessions build their draft trees
//! level-synchronously (`Method::draft_next`/`draft_feed`), and the
//! worker fuses the same round's levels across sessions into one
//! `draft_decode` graph call (`engine::sessions::fused_draft_decode`,
//! grouped by the same page-granular capacity machinery over the draft
//! width ladder; host-drafted `mock` sessions batch through their shared
//! `host_drafter`) — per-group draft calls per cycle drop from `N·depth`
//! to `~depth`.  Sessions left unfused (lone planner, failed fused call)
//! keep their pending level and their own `plan` drives the walk solo.
//!
//! A short job submitted behind a long one
//! still starts immediately and finishes first (cycle granularity), and
//! each live session owns its `Method` instance (own KV caches) checked
//! out of a per-name free list, returned at completion.  Sessions without
//! a compiled target (`mock`) batch through their method's
//! `HostVerifier`: rows from all such sessions go through one host batch
//! call, exercising the identical pack/scatter choreography without
//! artifacts.
//!
//! **Streaming / cancellation / deadlines.**  Results travel as
//! [`JobEvent`]s on an *unbounded* channel (a worker must never block
//! handing a result to a slow consumer): jobs with `stream: true` get a
//! [`JobEvent::Delta`] per cycle, every job ends with exactly one
//! [`JobEvent::Done`].  [`Scheduler::cancel`] marks a job id; the owning
//! worker aborts it between cycles (or at admission while still queued)
//! with a "cancelled" error result.  A job's `deadline_ms` is checked
//! between cycles against its submission clock.  Callers must only
//! cancel ids they actually submitted (the TCP server enforces this per
//! connection): a marker for a never-submitted id would linger and
//! cancel whatever job is eventually assigned that id.  Markers for
//! already-finished jobs are cleared lazily when the id is next seen.
//!
//! Observability: every worker maintains a [`WorkerStats`] slot (jobs
//! served, tokens, busy/idle seconds, acceptance [`Metrics`] merged over
//! its jobs — busy counts in-step CPU time, not interleaved wall time —
//! plus batch occupancy: fused vs. solo verify call counts and the rows
//! fused calls carried); [`Scheduler::stats`] snapshots them as a
//! [`PoolStats`] aggregate, which the server exposes through the
//! `{"stats": true}` JSON-lines request.
//! [`Scheduler::shutdown`] is graceful: queued jobs drain (FIFO) before
//! the per-worker stop markers are consumed — a worker that sees its
//! marker finishes its live sessions, then exits.  `HASS_TEST_JOB_DELAY_MS`
//! injects an artificial delay at job admission *and* after every step
//! (test-only throttle for pool scheduling tests and queueing demos).
//!
//! Under the `HASS_CHECK=1` shadow sanitizer every mutex acquisition in
//! this module is traced through [`crate::util::lockorder`]; an order
//! inversion across the worker-queue / shared-channel / stats / cancels /
//! affinity classes (or against the kvcache's page-shard leaf class)
//! panics immediately instead of deadlocking some future run.  Each lock
//! here is held alone — the affinity map in particular is released
//! before the queue push and the stats update it decides.
//! Worker threads are panic-isolated AND supervised: the spawn wraps the
//! worker loop in `catch_unwind` inside a respawn loop, so a bug in one
//! engine thread surfaces as a counted death + recovery, never a
//! silently stranded queue (see "Failure semantics" below).
//!
//! # Failure semantics
//!
//! Faults are injectable at named [`crate::util::failpoint`] sites
//! (`HASS_FAULTS="<point>:<err|panic|delay:N>:<rate>"` with
//! `HASS_FAULTS_SEED` for reproducible chaos; scoped installs via
//! `failpoint::install` + [`Scheduler::fault_scope`]), and the pool is
//! built to survive them:
//!
//! * **Flight board.**  From the moment a worker dequeues a job until
//!   its terminal `Done` event is sent, a [`FlightRec`] journal entry
//!   (job, result channel, delivered-delta prefix, attempt count) lives
//!   on the pool's flight board.  The entry is removed immediately
//!   before the `Done` send with no fault site in between, so an
//!   injected fault can never strike inside the at-most-once window:
//!   every job produces exactly one `Done`.
//! * **Supervision.**  A worker thread that dies on an unexpected panic
//!   (engine panics inside a cycle are already caught per-call) is
//!   respawned onto the SAME [`WorkerQueue`] by its supervisor loop
//!   after a short backoff; `worker_deaths` and the death-to-respawn
//!   latency land on the stats wire (`recovery_ms_sum`).
//! * **Requeue / replay.**  In-flight jobs of a dead worker — and live
//!   sessions that hit a chaos-injected error (`failpoint::is_injected`)
//!   in `start`/`plan`/`step`/`verify`/`absorb` — are redelivered: a job
//!   with NO delivered stream deltas is transparently requeued
//!   (`requeues`); a streamed job with delivered deltas is replayed from
//!   its seeded `GenRequest` with the already-delivered token prefix
//!   suppressed and byte-verified (`replays`) — generation is seeded and
//!   deterministic, so the replay is token-identical.  Redelivery is
//!   bounded by `max_requeues` (`HASS_MAX_REQUEUES`, default 8); past
//!   the bound — or on a replay prefix mismatch — the client gets the
//!   structured [`WORKER_LOST_MSG`] error, which the server renders as
//!   the `{"error":"worker_lost","retryable":true}` wire line.
//! * **Poisoned locks.**  Every mutex in this module (and the kvcache
//!   registry shards) is taken through `unwrap_or_else(|p|
//!   p.into_inner())`: a panic injected while a lock is held poisons it
//!   without disabling the pool — stats snapshots and submissions keep
//!   working, which the `chaos_poisoned_*` tests pin.
//!
//! Genuine (non-injected) errors keep their pre-existing semantics: they
//! complete the job with an error result immediately, with no retry.
//!
//! # Overload policy
//!
//! Past capacity the pool degrades *gracefully* instead of queueing
//! unboundedly, OOMing the page pool, or hanging callers.  All knobs
//! live in [`OverloadPolicy`] (set via [`Scheduler::start_with_policy`];
//! `HASS_PAGE_BUDGET` / `HASS_BREAKER_MAX_CYCLES` / `HASS_BREAKER_MAX_MS`
//! seed the defaults for env-configured pools):
//!
//! * **Admission watermarks.**  `submit` reads the pool-wide live-page
//!   gauge (`kvcache::live_pages`, every physical page on every worker)
//!   before routing: above `admission_hwm · page_budget` the job is
//!   rejected up front with an explicit [`Overloaded`] error carrying a
//!   `retry_after_ms` hint (the server turns it into the
//!   `{"error":"overloaded","retry_after_ms":..}` wire response), and
//!   `admission_rejects` counts it.  The spill-to-shared-channel path is
//!   bounded too: a full shared channel is retried only for
//!   `spill_timeout_ms` before shedding the same way, so a stalled pool
//!   can never hang callers silently.
//! * **Preemption ordering.**  Between cycles a worker over
//!   `preempt_hwm · page_budget` parks sessions — lowest [`Job::priority`]
//!   first, youngest (latest-admitted) within a priority — until the
//!   gauge recovers or one session remains (forward progress).  Parking
//!   releases what a resumed session can rebuild (the staging image and
//!   every KV page wholly past the committed prefix, via
//!   `KvCache::release_staging`; the worker's `FusedScratch` staging is
//!   dropped too) while committed pages stay live and still dedup
//!   through the registry.  The `GenState` is kept verbatim, so a
//!   resumed run is token-identical to an uninterrupted one (the
//!   solo == preempted-and-resumed invariant).  Parked sessions still
//!   count toward `max_active` and the load gauge, are swept for
//!   cancel/deadline every iteration, and resume — highest priority,
//!   oldest first — once the gauge drops to `resume_lwm · page_budget`
//!   (or unconditionally at shutdown so draining cannot strand them).
//! * **Circuit breakers.**  A session that runs more than
//!   `breaker_max_cycles` cycles or longer than `breaker_max_ms` is
//!   aborted between cycles with a distinct `aborted:"breaker"` status
//!   on its error result (`breaker_trips` counts them), so a runaway
//!   session cannot pin its pages until `max_new`.
//!
//! `preemptions`/`resumes`/`breaker_trips` land per worker on the stats
//! wire next to `admission_rejects`/`live_pages`/`free_pages`/
//! `page_budget` pool-wide, and per-job `queue_wait_ms` + TTFT sums make
//! client-side SLO numbers cross-checkable server-side.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::engine::build_method;
use crate::engine::metrics::Metrics;
use crate::engine::sessions::{
    fused_decode, fused_draft_decode, pick_block, pick_width, DraftSession, TargetSession,
    MAX_BLOCK,
};
use crate::kvcache::FusedScratch;
use crate::runtime::Runtime;
use crate::sampling::SampleParams;
use crate::spec::{
    DraftPhase, DraftRows, GenRequest, GenState, HostVerifier, Method, MethodCfg, StepPlan,
    VerifyOut, VerifyRows,
};
use crate::tokenizer;
use crate::util::failpoint;
use crate::util::lockorder;
use crate::util::stats::Stopwatch;

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub method: String,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
    /// emit a [`JobEvent::Delta`] per drafting-verification cycle
    pub stream: bool,
    /// abort with an error result once this many ms have passed since
    /// submission (checked between cycles, and at admission while queued)
    pub deadline_ms: Option<u64>,
    /// overload class (higher = more important): under page pressure a
    /// worker parks its lowest-priority sessions first (module docs,
    /// "Overload policy")
    pub priority: u8,
}

#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub tau: f64,
    /// wall time from admission to completion (includes cycles of other
    /// interleaved jobs on the same worker)
    pub latency_s: f64,
    pub queue_s: f64,
    /// engine worker that served the job
    pub worker: usize,
    /// the request asked for streaming (final wire line carries "done")
    pub stream: bool,
    pub error: Option<String>,
    /// which policy fence aborted the job (`"breaker"`), distinct from
    /// ordinary errors so clients can tell a policy kill from a failure
    pub aborted: Option<&'static str>,
}

/// One message on a job's result channel.  Non-streamed jobs produce a
/// single `Done`; streamed jobs produce one `Delta` per cycle first.
#[derive(Clone, Debug)]
pub enum JobEvent {
    Delta {
        id: u64,
        /// decoded text of the tokens emitted this cycle
        text: String,
        /// total tokens emitted so far
        tokens: usize,
    },
    Done(JobResult),
}

impl JobEvent {
    pub fn id(&self) -> u64 {
        match self {
            JobEvent::Delta { id, .. } => *id,
            JobEvent::Done(r) => r.id,
        }
    }

    /// The terminal result, if this is the `Done` event.
    pub fn into_result(self) -> Option<JobResult> {
        match self {
            JobEvent::Done(r) => Some(r),
            JobEvent::Delta { .. } => None,
        }
    }
}

/// Redelivery context for a job re-enqueued after a worker death or a
/// chaos-injected fault (module docs, "Failure semantics").
#[derive(Clone, Debug)]
struct Redo {
    /// redeliveries so far, bounded by the pool's `max_requeues`
    attempts: u32,
    /// stream tokens already delivered to the client before the fault
    skip_tokens: usize,
    /// exact delta text already delivered (replay prefix verification)
    prefix_text: String,
}

enum Msg {
    Run(Job, Stopwatch, Sender<JobEvent>),
    /// Redelivered job: re-run from its seeded request, suppressing (and
    /// byte-verifying) the already-streamed token prefix
    Redo(Job, Redo, Sender<JobEvent>),
    Shutdown,
}

/// Live counters for one engine worker (updated by the worker thread).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub worker: usize,
    pub jobs_ok: u64,
    pub jobs_err: u64,
    /// tokens emitted across successful jobs
    pub tokens: u64,
    /// seconds spent doing per-job work — method build/checkout, start,
    /// and step calls (CPU occupancy, not interleaved wall time)
    pub busy_s: f64,
    /// seconds spent waiting for work
    pub idle_s: f64,
    /// verify executions that fused >= 2 sessions into one call
    pub fused_calls: u64,
    /// single-session verify executions (lone planner, or fused fallback)
    pub solo_calls: u64,
    /// candidate rows covered by fused calls (occupancy numerator)
    pub fused_rows: u64,
    /// draft executions that fused >= 2 sessions' levels into one call
    pub draft_fused_calls: u64,
    /// single-session draft executions (a lone session's walk driven
    /// inside its own `plan`, or the fused-draft fallback)
    pub draft_solo_calls: u64,
    /// draft rows covered by fused draft calls (occupancy numerator)
    pub draft_fused_rows: u64,
    /// draft KV pages memcpy'd into the fused draft image across packs
    pub draft_pack_pages_copied: u64,
    /// draft KV pages skipped because their `(id, stamp)` was staged
    pub draft_pack_pages_reused: u64,
    /// KV pages memcpy'd into the fused image across all packs (paged KV:
    /// steady-state cycles copy only changed tail pages)
    pub pack_pages_copied: u64,
    /// KV pages skipped because their `(id, stamp)` was already staged
    pub pack_pages_reused: u64,
    /// cross-session shared pages seen by this worker's most recent fused
    /// pack (gauge; > 0 means co-active sessions share a prompt prefix)
    pub shared_pages: u64,
    /// submits routed here because this worker already served the
    /// prompt's prefix fingerprint (its pages are hot here)
    pub affinity_hits: u64,
    /// affinity-routed submits that landed here via least-loaded
    /// fallback instead — unknown prefix, or the escape hatch rebalanced
    /// a hot one
    pub affinity_misses: u64,
    /// dedup hits this worker's thread took on pages first registered by
    /// ANOTHER worker — physical prompt pages shared across the pool
    pub cross_worker_shared_pages: u64,
    /// sessions parked under page pressure (overload policy, module docs)
    pub preemptions: u64,
    /// parked sessions moved back to active once pages freed
    pub resumes: u64,
    /// sessions aborted by the cycle/time circuit breaker
    pub breaker_trips: u64,
    /// jobs transparently requeued after a worker death or injected
    /// fault (no stream deltas had been delivered yet)
    pub requeues: u64,
    /// streamed jobs deterministically replayed with their delivered
    /// delta prefix suppressed (module docs, "Failure semantics")
    pub replays: u64,
    /// times this worker's engine thread died and was respawned
    pub worker_deaths: u64,
    /// Σ death-to-respawn latency (ms) over `worker_deaths`
    pub recovery_ms_sum: f64,
    /// Σ queue wait (ms) over every finished job (SLO cross-check)
    pub queue_wait_ms_sum: f64,
    /// Σ time-to-first-token (ms) over jobs that produced tokens
    pub ttft_ms_sum: f64,
    /// jobs counted in `ttft_ms_sum`
    pub ttft_count: u64,
    /// acceptance metrics merged over every successful job
    pub metrics: Metrics,
}

impl WorkerStats {
    pub fn jobs(&self) -> u64 {
        self.jobs_ok + self.jobs_err
    }

    /// Mean sessions' rows per fused verify call.
    pub fn mean_fused_rows(&self) -> f64 {
        if self.fused_calls == 0 {
            return 0.0;
        }
        self.fused_rows as f64 / self.fused_calls as f64
    }

    /// Mean rows per fused draft call.
    pub fn mean_draft_fused_rows(&self) -> f64 {
        if self.draft_fused_calls == 0 {
            return 0.0;
        }
        self.draft_fused_rows as f64 / self.draft_fused_calls as f64
    }

    /// Mean per-job queue wait in ms.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        if self.jobs() == 0 {
            return 0.0;
        }
        self.queue_wait_ms_sum / self.jobs() as f64
    }

    /// Mean time-to-first-token in ms over jobs that produced tokens.
    pub fn mean_ttft_ms(&self) -> f64 {
        if self.ttft_count == 0 {
            return 0.0;
        }
        self.ttft_ms_sum / self.ttft_count as f64
    }

    /// Mean death-to-respawn recovery latency in ms.
    pub fn mean_recovery_ms(&self) -> f64 {
        if self.worker_deaths == 0 {
            return 0.0;
        }
        self.recovery_ms_sum / self.worker_deaths as f64
    }
}

/// Snapshot of the whole pool: per-worker counters + queue depth +
/// pool-wide page-registry gauges.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub workers: Vec<WorkerStats>,
    /// jobs submitted but not yet picked up by a worker
    pub queue_depth: usize,
    /// live pages in the pool-wide dedup registry (gauge)
    pub registry_entries: u64,
    /// cumulative registry entries dropped (dead-prefix sweeps + cap
    /// evictions)
    pub registry_evictions: u64,
    /// submissions shed by admission control / spill timeout (overload)
    pub admission_rejects: u64,
    /// physical pages alive pool-wide right now (gauge)
    pub live_pages: u64,
    /// configured page budget (0 = unbounded)
    pub page_budget: u64,
    /// pages left under the budget (0 when unbounded or exhausted)
    pub free_pages: u64,
}

impl PoolStats {
    pub fn jobs(&self) -> u64 {
        self.workers.iter().map(WorkerStats::jobs).sum()
    }

    pub fn jobs_ok(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs_ok).sum()
    }

    pub fn jobs_err(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs_err).sum()
    }

    pub fn tokens(&self) -> u64 {
        self.workers.iter().map(|w| w.tokens).sum()
    }

    pub fn busy_s(&self) -> f64 {
        self.workers.iter().map(|w| w.busy_s).sum()
    }

    pub fn idle_s(&self) -> f64 {
        self.workers.iter().map(|w| w.idle_s).sum()
    }

    /// Acceptance metrics merged across every worker.
    pub fn metrics(&self) -> Metrics {
        Metrics::merged(self.workers.iter().map(|w| &w.metrics))
    }

    /// Pool-wide acceptance length τ.
    pub fn tau(&self) -> f64 {
        self.metrics().tau()
    }

    pub fn fused_calls(&self) -> u64 {
        self.workers.iter().map(|w| w.fused_calls).sum()
    }

    pub fn solo_calls(&self) -> u64 {
        self.workers.iter().map(|w| w.solo_calls).sum()
    }

    pub fn fused_rows(&self) -> u64 {
        self.workers.iter().map(|w| w.fused_rows).sum()
    }

    pub fn pack_pages_copied(&self) -> u64 {
        self.workers.iter().map(|w| w.pack_pages_copied).sum()
    }

    pub fn pack_pages_reused(&self) -> u64 {
        self.workers.iter().map(|w| w.pack_pages_reused).sum()
    }

    /// Cross-session shared pages over the workers' latest fused packs.
    pub fn shared_pages(&self) -> u64 {
        self.workers.iter().map(|w| w.shared_pages).sum()
    }

    pub fn affinity_hits(&self) -> u64 {
        self.workers.iter().map(|w| w.affinity_hits).sum()
    }

    pub fn affinity_misses(&self) -> u64 {
        self.workers.iter().map(|w| w.affinity_misses).sum()
    }

    /// Pool-wide dedup hits on pages first registered by another worker.
    pub fn cross_worker_shared_pages(&self) -> u64 {
        self.workers.iter().map(|w| w.cross_worker_shared_pages).sum()
    }

    /// Pool-wide verify executions (each serves >= 1 session's cycle).
    pub fn verify_calls(&self) -> u64 {
        self.fused_calls() + self.solo_calls()
    }

    /// Pool-wide mean rows per fused verify call.
    pub fn mean_fused_rows(&self) -> f64 {
        let calls = self.fused_calls();
        if calls == 0 {
            return 0.0;
        }
        self.fused_rows() as f64 / calls as f64
    }

    pub fn draft_fused_calls(&self) -> u64 {
        self.workers.iter().map(|w| w.draft_fused_calls).sum()
    }

    pub fn draft_solo_calls(&self) -> u64 {
        self.workers.iter().map(|w| w.draft_solo_calls).sum()
    }

    pub fn draft_fused_rows(&self) -> u64 {
        self.workers.iter().map(|w| w.draft_fused_rows).sum()
    }

    pub fn draft_pack_pages_copied(&self) -> u64 {
        self.workers.iter().map(|w| w.draft_pack_pages_copied).sum()
    }

    pub fn draft_pack_pages_reused(&self) -> u64 {
        self.workers.iter().map(|w| w.draft_pack_pages_reused).sum()
    }

    /// Pool-wide draft executions (each serves >= 1 session's level).
    pub fn draft_execs(&self) -> u64 {
        self.draft_fused_calls() + self.draft_solo_calls()
    }

    /// Pool-wide mean rows per fused draft call.
    pub fn mean_draft_fused_rows(&self) -> f64 {
        let calls = self.draft_fused_calls();
        if calls == 0 {
            return 0.0;
        }
        self.draft_fused_rows() as f64 / calls as f64
    }

    pub fn preemptions(&self) -> u64 {
        self.workers.iter().map(|w| w.preemptions).sum()
    }

    pub fn resumes(&self) -> u64 {
        self.workers.iter().map(|w| w.resumes).sum()
    }

    pub fn breaker_trips(&self) -> u64 {
        self.workers.iter().map(|w| w.breaker_trips).sum()
    }

    /// Pool-wide transparent requeues after worker deaths / injected faults.
    pub fn requeues(&self) -> u64 {
        self.workers.iter().map(|w| w.requeues).sum()
    }

    /// Pool-wide streamed-job replays with prefix suppression.
    pub fn replays(&self) -> u64 {
        self.workers.iter().map(|w| w.replays).sum()
    }

    /// Pool-wide engine-thread deaths survived by supervision.
    pub fn worker_deaths(&self) -> u64 {
        self.workers.iter().map(|w| w.worker_deaths).sum()
    }

    /// Pool-wide mean death-to-respawn recovery latency in ms.
    pub fn mean_recovery_ms(&self) -> f64 {
        let deaths = self.worker_deaths();
        if deaths == 0 {
            return 0.0;
        }
        self.workers.iter().map(|w| w.recovery_ms_sum).sum::<f64>() / deaths as f64
    }

    /// Pool-wide mean per-job queue wait in ms.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        let jobs = self.jobs();
        if jobs == 0 {
            return 0.0;
        }
        self.workers.iter().map(|w| w.queue_wait_ms_sum).sum::<f64>() / jobs as f64
    }

    /// Pool-wide mean time-to-first-token in ms.
    pub fn mean_ttft_ms(&self) -> f64 {
        let n: u64 = self.workers.iter().map(|w| w.ttft_count).sum();
        if n == 0 {
            return 0.0;
        }
        self.workers.iter().map(|w| w.ttft_ms_sum).sum::<f64>() / n as f64
    }
}

/// One worker's direct-dispatch queue + its load gauge (queued jobs +
/// live sessions), the least-loaded selection key.
struct WorkerQueue {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
    load: AtomicUsize,
}

impl WorkerQueue {
    fn new() -> WorkerQueue {
        WorkerQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            load: AtomicUsize::new(0),
        }
    }

    /// Enqueue a job for this worker (load counts it until admission).
    fn push(&self, msg: Msg) {
        let _t = lockorder::trace(lockorder::WORKER_QUEUE);
        self.q.lock().unwrap_or_else(|p| p.into_inner()).push_back(msg);
        self.load.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<Msg> {
        let _t = lockorder::trace(lockorder::WORKER_QUEUE);
        let m = self.q.lock().unwrap_or_else(|p| p.into_inner()).pop_front();
        if m.is_some() {
            self.load.fetch_sub(1, Ordering::Relaxed);
        }
        m
    }

    fn is_empty(&self) -> bool {
        // hass-lint: allow(lock-order) — the `.is_empty()` below is VecDeque's, called on the held guard; name-based call resolution reads it as WorkerQueue::is_empty and infers same-class re-entry
        let _t = lockorder::trace(lockorder::WORKER_QUEUE);
        self.q.lock().unwrap_or_else(|p| p.into_inner()).is_empty()
    }

    /// Park until (maybe) more work exists.  Re-checks the private queue
    /// under the same lock a `push` holds, so wakeups cannot be lost; the
    /// timeout is a safety net for shared-queue traffic.
    fn park(&self) {
        let _t = lockorder::trace(lockorder::WORKER_QUEUE);
        let g = self.q.lock().unwrap_or_else(|p| p.into_inner());
        if g.is_empty() {
            let _ = self
                .cv
                .wait_timeout(g, std::time::Duration::from_millis(25))
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    fn notify(&self) {
        self.cv.notify_all();
    }
}

/// Bound on the prefix-affinity map (fingerprint -> worker).  A full map
/// is simply cleared: affinity is a routing hint, and losing it costs
/// one least-loaded fallback per prefix, not correctness.
const AFFINITY_MAP_CAP: usize = 4096;

/// Escape-hatch threshold: an affinity worker more than this many load
/// units (queued jobs + live sessions) above the least-loaded worker
/// loses the prefix — one hot prefix must not starve the pool.
const AFFINITY_MAX_IMBALANCE: usize = 4;

/// FNV-1a over the first 64 prompt bytes — sessions sharing a system
/// prompt / template prefix collide on purpose (their prompt pages
/// dedup), while the tail of a long prompt cannot split an otherwise
/// identical prefix across workers.
fn prompt_fingerprint(prompt: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in prompt.as_bytes().iter().take(64) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Graceful-overload knobs: admission watermarks over the pool-wide
/// live-page gauge, preemption/resume thresholds, spill-path timeout and
/// runaway-session circuit breakers (module docs, "Overload policy").
#[derive(Clone, Debug)]
pub struct OverloadPolicy {
    /// pool-wide physical page budget; `None` disables admission control
    /// and preemption (breakers still apply)
    pub page_budget: Option<u64>,
    /// budget fraction past which NEW submissions are shed (overloaded)
    pub admission_hwm: f64,
    /// budget fraction past which a worker parks sessions between cycles
    pub preempt_hwm: f64,
    /// budget fraction at or under which parked sessions resume
    pub resume_lwm: f64,
    /// bound on the spill path's wait for shared-channel space: past it
    /// the submission sheds (overloaded) instead of hanging the caller
    pub spill_timeout_ms: u64,
    /// retry hint carried by overloaded rejections
    pub retry_after_ms: u64,
    /// abort a session after this many verify cycles
    pub breaker_max_cycles: Option<u64>,
    /// abort a session running (admission to now) longer than this
    pub breaker_max_ms: Option<u64>,
    /// test override for the live-page gauge (`None` reads
    /// `kvcache::live_pages`): pool-level tests inject page pressure
    /// without racing other tests' real page traffic
    pub gauge: Option<Arc<AtomicU64>>,
}

impl Default for OverloadPolicy {
    fn default() -> OverloadPolicy {
        OverloadPolicy {
            page_budget: None,
            admission_hwm: 0.9,
            preempt_hwm: 1.0,
            resume_lwm: 0.85,
            spill_timeout_ms: 2000,
            retry_after_ms: 250,
            breaker_max_cycles: None,
            breaker_max_ms: None,
            gauge: None,
        }
    }
}

impl OverloadPolicy {
    /// Current live-page gauge reading (pool-wide, or the test override).
    pub fn live(&self) -> u64 {
        match &self.gauge {
            Some(g) => g.load(Ordering::Relaxed),
            None => crate::kvcache::live_pages(),
        }
    }

    /// True once the gauge is past the admission high-water mark.
    fn admission_overloaded(&self) -> bool {
        match self.page_budget {
            Some(b) => self.live() as f64 > self.admission_hwm * b as f64,
            None => false,
        }
    }
}

/// Explicit overload rejection (admission control or spill timeout): the
/// caller should retry after `retry_after_ms`.  The vendored `anyhow`
/// stand-in has no downcast, so the rejection travels as the
/// machine-parseable message `overloaded retry_after_ms=<N>`;
/// [`Overloaded::parse`] recovers it (the server turns it into the
/// `{"error":"overloaded","retry_after_ms":N}` wire response).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Overloaded {
    pub retry_after_ms: u64,
}

impl Overloaded {
    pub fn to_error(self) -> anyhow::Error {
        anyhow::anyhow!("overloaded retry_after_ms={}", self.retry_after_ms)
    }

    /// Recover an overload rejection from an error's rendered message.
    pub fn parse(msg: &str) -> Option<Overloaded> {
        let rest = msg.strip_prefix("overloaded retry_after_ms=")?;
        rest.trim().parse().ok().map(|retry_after_ms| Overloaded { retry_after_ms })
    }
}

/// A job's worker died (or kept faulting) before the job could complete,
/// and redelivery was exhausted (`max_requeues`) or impossible (replay
/// prefix mismatch).  Like [`Overloaded`], the vendored `anyhow` stand-in
/// has no downcast, so the rejection travels as this machine-parseable
/// message; [`is_worker_lost`] recovers it (the server turns it into the
/// `{"error":"worker_lost","retryable":true}` wire line).
pub const WORKER_LOST_MSG: &str = "worker_lost retryable=true";

/// True if an error's rendered message is the `worker_lost` rejection.
pub fn is_worker_lost(msg: &str) -> bool {
    msg.starts_with("worker_lost")
}

/// One in-flight job on the flight board: everything needed to redeliver
/// it if its worker dies before the terminal `Done` send (module docs,
/// "Failure semantics").
struct FlightRec {
    job: Job,
    rtx: Sender<JobEvent>,
    /// stream tokens already delivered as deltas (0 ⇒ transparent requeue)
    sent_tokens: usize,
    /// exact delta text already on the wire (replay prefix verification)
    sent_text: String,
    /// redeliveries so far (bounded by the pool's `max_requeues`)
    attempts: u32,
}

/// Crash-redelivery journal: one [`FlightRec`] per job from the moment a
/// worker dequeues it until its terminal `Done` event is sent.  Sharded
/// per worker; every critical section is a leaf ([`lockorder::FLIGHT`])
/// — records are moved out before any queue or stats lock is touched.
struct FlightBoard {
    by_worker: Vec<Mutex<HashMap<u64, FlightRec>>>,
}

impl FlightBoard {
    fn new(workers: usize) -> FlightBoard {
        FlightBoard { by_worker: (0..workers).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    /// Journal a dequeued job before any fault site can strike it.
    fn check_in(&self, w: usize, rec: FlightRec) {
        let _t = lockorder::trace(lockorder::FLIGHT);
        self.by_worker[w].lock().unwrap_or_else(|p| p.into_inner()).insert(rec.job.id, rec);
    }

    /// Record a delivered stream delta (redelivery must suppress it).
    fn note_delta(&self, w: usize, id: u64, sent_tokens: usize, text: &str) {
        let _t = lockorder::trace(lockorder::FLIGHT);
        if let Some(r) = self.by_worker[w].lock().unwrap_or_else(|p| p.into_inner()).get_mut(&id)
        {
            r.sent_tokens = sent_tokens;
            r.sent_text.push_str(text);
        }
    }

    /// Retire a job from the journal; the caller sends `Done` immediately
    /// after, with no fault site in between (the at-most-once window).
    fn checkout(&self, w: usize, id: u64) -> Option<FlightRec> {
        let _t = lockorder::trace(lockorder::FLIGHT);
        self.by_worker[w].lock().unwrap_or_else(|p| p.into_inner()).remove(&id)
    }

    /// Pop one in-flight record of a dead worker.  Incremental on
    /// purpose: redelivery runs record-at-a-time with no fault site
    /// between the take and the requeue push, so recovery itself cannot
    /// be made to drop jobs by injected chaos.
    fn take_any(&self, w: usize) -> Option<FlightRec> {
        let _t = lockorder::trace(lockorder::FLIGHT);
        let mut g = self.by_worker[w].lock().unwrap_or_else(|p| p.into_inner());
        let id = g.keys().next().copied()?;
        g.remove(&id)
    }
}

pub struct Scheduler {
    /// `None` once shutdown has begun: closing submissions *before* the
    /// stop markers are enqueued guarantees no job can land behind them
    /// (it would be dropped unserved and hang its client).
    tx: RwLock<Option<SyncSender<Msg>>>,
    /// per-worker direct-dispatch queues (affinity/least-loaded routing)
    queues: Vec<Arc<WorkerQueue>>,
    /// pool-wide backlog bound before submissions spill to the shared
    /// channel (whose own bound provides the blocking backpressure)
    queue_cap: usize,
    workers: usize,
    max_active: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
    stats: Arc<Mutex<Vec<WorkerStats>>>,
    queue_depth: Arc<AtomicUsize>,
    cancels: Arc<Mutex<HashSet<u64>>>,
    /// prompt-prefix fingerprint -> last worker routed (prefix-affinity
    /// dispatch); bounded by [`AFFINITY_MAP_CAP`], held only inside
    /// [`Scheduler::route`]
    affinity: Mutex<HashMap<u64, usize>>,
    affinity_on: bool,
    /// overload policy shared with every worker (module docs)
    policy: Arc<OverloadPolicy>,
    /// submissions shed by admission control or the spill timeout
    admission_rejects: AtomicU64,
    /// in-flight job journal for crash redelivery ("Failure semantics")
    board: Arc<FlightBoard>,
    /// thread-name tag of this pool's workers (`engine-p{pool}-`), the
    /// scope chaos tests install their faults under
    pool_tag: String,
}

/// Monotonic pool ordinal: worker threads are named
/// `engine-p{pool}-{worker}` so a chaos test can scope its installed
/// faults to its own pool's threads ([`Scheduler::fault_scope`]) without
/// perturbing pools owned by tests running in parallel.
static POOL_SEQ: AtomicU64 = AtomicU64::new(0);

impl Scheduler {
    /// Spawn `workers` engine threads sharing one bounded work queue.
    /// `queue_cap` bounds submitted-but-unserved requests; `max_active`
    /// bounds the sessions one worker interleaves (1 = run-to-completion).
    /// Prefix-affinity routing is on (see [`Scheduler::start_with_affinity`]).
    pub fn start(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        max_active: usize,
    ) -> Scheduler {
        Scheduler::start_with_affinity(artifact_dir, cfg, queue_cap, workers, max_active, true)
    }

    /// [`Scheduler::start`] with prefix-affinity routing explicitly on or
    /// off (off = pure least-loaded dispatch; the page-pool bench
    /// measures both sides).
    pub fn start_with_affinity(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        max_active: usize,
        affinity_on: bool,
    ) -> Scheduler {
        // the env knobs are read once per pool (demo/test throttle +
        // overload policy for env-configured pools)
        let env_u64 = |k: &str| std::env::var(k).ok().and_then(|v| v.parse::<u64>().ok());
        let test_delay_ms = env_u64("HASS_TEST_JOB_DELAY_MS");
        let policy = OverloadPolicy {
            page_budget: env_u64("HASS_PAGE_BUDGET"),
            breaker_max_cycles: env_u64("HASS_BREAKER_MAX_CYCLES"),
            breaker_max_ms: env_u64("HASS_BREAKER_MAX_MS"),
            ..OverloadPolicy::default()
        };
        Scheduler::start_inner_policy(
            artifact_dir,
            cfg,
            queue_cap,
            workers,
            max_active,
            test_delay_ms,
            affinity_on,
            policy,
        )
    }

    /// [`Scheduler::start_with_affinity`] with an explicit
    /// [`OverloadPolicy`] (admission control, preemption, breakers) —
    /// the load harness and overload tests construct their pools here.
    pub fn start_with_policy(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        max_active: usize,
        affinity_on: bool,
        policy: OverloadPolicy,
    ) -> Scheduler {
        let test_delay_ms: Option<u64> = std::env::var("HASS_TEST_JOB_DELAY_MS")
            .ok()
            .and_then(|v| v.parse().ok());
        Scheduler::start_inner_policy(
            artifact_dir,
            cfg,
            queue_cap,
            workers,
            max_active,
            test_delay_ms,
            affinity_on,
            policy,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        max_active: usize,
        test_delay_ms: Option<u64>,
        affinity_on: bool,
    ) -> Scheduler {
        Scheduler::start_inner_policy(
            artifact_dir,
            cfg,
            queue_cap,
            workers,
            max_active,
            test_delay_ms,
            affinity_on,
            OverloadPolicy::default(),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner_policy(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        max_active: usize,
        test_delay_ms: Option<u64>,
        affinity_on: bool,
        policy: OverloadPolicy,
    ) -> Scheduler {
        let workers = workers.max(1);
        let max_active = max_active.max(1);
        let queue_cap = queue_cap.max(1);
        let (tx, rx) = sync_channel::<Msg>(queue_cap);
        let rx = Arc::new(Mutex::new(rx));
        let stats: Arc<Mutex<Vec<WorkerStats>>> = Arc::new(Mutex::new(
            (0..workers).map(|w| WorkerStats { worker: w, ..WorkerStats::default() }).collect(),
        ));
        let queues: Vec<Arc<WorkerQueue>> =
            (0..workers).map(|_| Arc::new(WorkerQueue::new())).collect();
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let cancels: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
        let policy = Arc::new(policy);
        let board = Arc::new(FlightBoard::new(workers));
        let max_requeues: u32 = std::env::var("HASS_MAX_REQUEUES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(8);
        // the trailing dash keeps tags prefix-free across pools (the tag
        // `engine-p3-` never substring-matches a thread of pool 31)
        let pool_tag = format!("engine-p{}-", POOL_SEQ.fetch_add(1, Ordering::Relaxed));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let ctx = WorkerCtx {
                id: w,
                stats: stats.clone(),
                queue: queues[w].clone(),
                queue_depth: queue_depth.clone(),
                cancels: cancels.clone(),
                max_active,
                test_delay_ms,
                policy: policy.clone(),
                board: board.clone(),
                max_requeues,
            };
            let rx = rx.clone();
            let dir = artifact_dir.clone();
            let cfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("{pool_tag}{w}"))
                    // supervision: a worker that dies on an unexpected
                    // panic (engine panics inside a cycle are already
                    // caught per-call) is respawned onto the SAME queue
                    // after its in-flight jobs are redelivered — it must
                    // not take the process down or vanish silently with
                    // its queue ("Failure semantics")
                    .spawn(move || loop {
                        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker(ctx.clone(), dir.clone(), cfg.clone(), rx.clone())
                        }));
                        match run {
                            Ok(()) => break,
                            Err(_) => {
                                let sw = Stopwatch::start();
                                eprintln!(
                                    "[scheduler] engine worker {w} died on an unexpected \
                                     panic; redelivering in-flight jobs and respawning"
                                );
                                recover_in_flight(&ctx);
                                // brief backoff: a deterministic rate-1.0
                                // fault must not respawn-spin the CPU
                                std::thread::sleep(std::time::Duration::from_millis(25));
                                ctx.with_stats_quiet(|s| {
                                    s.worker_deaths += 1;
                                    s.recovery_ms_sum += sw.secs() * 1000.0;
                                });
                            }
                        }
                    })
                    // hass-lint: allow(no-unwrap) — pool startup; OS thread spawn has no fallback
                    .expect("spawn engine worker"),
            );
        }
        Scheduler {
            tx: RwLock::new(Some(tx)),
            queues,
            queue_cap,
            workers,
            max_active,
            handles: Mutex::new(handles),
            stats,
            queue_depth,
            cancels,
            affinity: Mutex::new(HashMap::new()),
            affinity_on,
            policy,
            admission_rejects: AtomicU64::new(0),
            board,
            pool_tag,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Thread-name tag shared by this pool's engine workers (e.g.
    /// `engine-p3-`): pass it as the `scope` of a
    /// [`crate::util::failpoint::install`] to chaos exactly this pool
    /// without perturbing pools owned by parallel tests.
    pub fn fault_scope(&self) -> &str {
        &self.pool_tag
    }

    pub fn max_active(&self) -> usize {
        self.max_active
    }

    pub fn policy(&self) -> &OverloadPolicy {
        &self.policy
    }

    /// Submit a job; `blocking` waits for queue space, otherwise a full
    /// queue is an error (backpressure surfaced to the caller).
    pub fn submit(&self, job: Job, blocking: bool) -> Result<Receiver<JobEvent>> {
        let (rtx, rrx) = channel();
        self.submit_to(job, blocking, rtx)?;
        Ok(rrx)
    }

    /// Submit with a caller-supplied event channel.  One channel can
    /// collect many jobs (events carry the job id), which lets a server
    /// connection drain all its responses with a single pump thread.
    ///
    /// Dispatch is prefix-affine (module docs): while the pool-wide
    /// backlog is under `queue_cap`, the job goes straight onto the
    /// queue of the worker holding its prompt prefix hot — least-loaded
    /// on an unknown prefix or when the escape hatch rebalances.  Beyond
    /// that the job spills to the shared bounded channel — `blocking`
    /// waits for space there (backpressure), otherwise a full queue is
    /// an error.
    pub fn submit_to(&self, job: Job, blocking: bool, rtx: Sender<JobEvent>) -> Result<()> {
        // admission control: past the high-water mark of the page budget
        // NEW work is shed with an explicit retry hint instead of queued
        // against a pool that cannot serve it (module docs)
        if self.policy.admission_overloaded() {
            self.admission_rejects.fetch_add(1, Ordering::Relaxed);
            return Err(Overloaded { retry_after_ms: self.policy.retry_after_ms }.to_error());
        }
        // holding the read lock across the send excludes shutdown()'s
        // write-locked sender teardown, so an accepted job always sits
        // ahead of the stop markers and is guaranteed to be served
        let guard = self.tx.read().unwrap_or_else(|p| p.into_inner());
        let tx = match guard.as_ref() {
            Some(tx) => tx,
            None => return Err(anyhow::anyhow!("scheduler down")),
        };
        let (worker, affinity_hit) = self.route(&job);
        let msg = Msg::Run(job, Stopwatch::start(), rtx);
        // count before sending so the gauge never underflows when a worker
        // dequeues between the send and the increment
        let backlog = self.queue_depth.fetch_add(1, Ordering::Relaxed);
        if backlog < self.queue_cap {
            if let Some(hit) = affinity_hit {
                self.note_affinity(worker, hit);
            }
            self.queues[worker].push(msg);
            return Ok(());
        }
        // chaos: an injected spill fault sheds the submission the way a
        // wedged shared channel would — callers see a transient error
        if let Err(e) = failpoint::fire(failpoint::SPILL_SEND) {
            self.queue_depth.fetch_sub(1, Ordering::Relaxed);
            return Err(e);
        }
        let sent = if blocking {
            // bounded backpressure (std's SyncSender has no send_timeout,
            // so this is a try_send/park loop): a pool whose workers are
            // all wedged sheds after `spill_timeout_ms` instead of
            // hanging the caller on the bounded channel forever
            let deadline = std::time::Instant::now()
                + std::time::Duration::from_millis(self.policy.spill_timeout_ms);
            let mut msg = msg;
            loop {
                match tx.try_send(msg) {
                    Ok(()) => break Ok(()),
                    Err(TrySendError::Disconnected(_)) => {
                        break Err(anyhow::anyhow!("scheduler down"))
                    }
                    Err(TrySendError::Full(m)) => {
                        if std::time::Instant::now() >= deadline {
                            self.admission_rejects.fetch_add(1, Ordering::Relaxed);
                            break Err(
                                Overloaded { retry_after_ms: self.policy.retry_after_ms }.to_error()
                            );
                        }
                        msg = m;
                        // wake parked workers so one can steal and free a slot
                        for q in &self.queues {
                            q.notify();
                        }
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
            }
        } else {
            match tx.try_send(msg) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(_)) => Err(anyhow::anyhow!("queue full (backpressure)")),
                Err(TrySendError::Disconnected(_)) => Err(anyhow::anyhow!("scheduler down")),
            }
        };
        if let Err(e) = sent {
            self.queue_depth.fetch_sub(1, Ordering::Relaxed);
            return Err(e);
        }
        // shared-queue work: wake any parked worker to steal it
        for q in &self.queues {
            q.notify();
        }
        Ok(())
    }

    /// Worker with the fewest queued jobs + live sessions (ties -> lowest
    /// index).  The gauges are racy by design; dispatch just needs to
    /// spread load, not be exact.
    fn least_loaded(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (w, q) in self.queues.iter().enumerate() {
            let load = q.load.load(Ordering::Relaxed);
            if load < best_load {
                best = w;
                best_load = load;
            }
        }
        best
    }

    /// Pick the worker for `job`.  With affinity routing on (and > 1
    /// worker), a prompt prefix seen before goes back to the worker that
    /// last served it — unless that worker is more than
    /// [`AFFINITY_MAX_IMBALANCE`] load units above the least-loaded one,
    /// in which case the prefix is remapped there (the escape hatch).
    /// Returns `(worker, Some(hit))` when affinity routing decided, or
    /// `(worker, None)` for pure least-loaded dispatch.  The affinity
    /// lock is released before the caller touches any queue or stats
    /// lock (only atomics are read inside).
    fn route(&self, job: &Job) -> (usize, Option<bool>) {
        if !self.affinity_on || self.workers < 2 {
            return (self.least_loaded(), None);
        }
        let fp = prompt_fingerprint(&job.prompt);
        let ll = self.least_loaded();
        let _t = lockorder::trace(lockorder::AFFINITY);
        let mut map = self.affinity.lock().unwrap_or_else(|p| p.into_inner());
        // chaos: a panic here poisons the affinity lock with the map
        // still consistent — the `into_inner` above is the recovery path
        // the poison tests pin
        failpoint::fire_unit(failpoint::AFFINITY_ROUTE);
        if map.len() >= AFFINITY_MAP_CAP {
            map.clear();
        }
        match map.get(&fp).copied() {
            Some(w) => {
                let wl = self.queues[w].load.load(Ordering::Relaxed);
                let lll = self.queues[ll].load.load(Ordering::Relaxed);
                if wl <= lll + AFFINITY_MAX_IMBALANCE {
                    (w, Some(true))
                } else {
                    map.insert(fp, ll);
                    (ll, Some(false))
                }
            }
            None => {
                map.insert(fp, ll);
                (ll, Some(false))
            }
        }
    }

    /// Count an affinity routing outcome on the routed worker's stats row.
    fn note_affinity(&self, worker: usize, hit: bool) {
        let _t = lockorder::trace(lockorder::STATS);
        let mut stats = self.stats.lock().unwrap_or_else(|p| p.into_inner());
        if hit {
            stats[worker].affinity_hits += 1;
        } else {
            stats[worker].affinity_misses += 1;
        }
    }

    /// Request cancellation of a job by id.  The job — queued or live —
    /// reports a "cancelled" error result through its own event channel;
    /// cancelling an unknown or already-finished id is a no-op.
    pub fn cancel(&self, id: u64) {
        let _t = lockorder::trace(lockorder::CANCELS);
        self.cancels.lock().unwrap_or_else(|p| p.into_inner()).insert(id);
    }

    /// Snapshot per-worker counters + queue depth + pool-wide registry
    /// gauges.  The registry walk finishes before the stats lock is
    /// taken — no lock is ever held across another class here.
    pub fn stats(&self) -> PoolStats {
        let reg = crate::kvcache::registry_stats();
        let live = self.policy.live();
        let budget = self.policy.page_budget.unwrap_or(0);
        let _t = lockorder::trace(lockorder::STATS);
        PoolStats {
            workers: self.stats.lock().unwrap_or_else(|p| p.into_inner()).clone(),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            registry_entries: reg.entries,
            registry_evictions: reg.evictions,
            admission_rejects: self.admission_rejects.load(Ordering::Relaxed),
            live_pages: live,
            page_budget: budget,
            free_pages: budget.saturating_sub(live),
        }
    }

    /// Graceful shutdown: submissions close first (the write lock waits
    /// out in-flight submits), then the per-worker stop markers go onto
    /// the SHARED queue — it is FIFO and jobs only ever precede markers
    /// there, so every spilled job drains before a worker stops, and a
    /// worker that takes its marker keeps serving its own direct queue
    /// until empty.  All engine threads are then joined.  Idempotent.
    pub fn shutdown(&self) {
        let tx = self.tx.write().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(tx) = tx {
            for _ in 0..self.workers {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        // wake parked workers so they steal their markers
        for q in &self.queues {
            q.notify();
        }
        let mut handles = self.handles.lock().unwrap_or_else(|p| p.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Cloneable (every field is shared or plain data) so the supervisor
/// loop can hand a fresh copy to each respawned worker incarnation.
#[derive(Clone)]
struct WorkerCtx {
    id: usize,
    stats: Arc<Mutex<Vec<WorkerStats>>>,
    /// this worker's direct-dispatch queue (+ load gauge)
    queue: Arc<WorkerQueue>,
    queue_depth: Arc<AtomicUsize>,
    cancels: Arc<Mutex<HashSet<u64>>>,
    /// sessions this worker interleaves per fused cycle
    max_active: usize,
    /// artificial admission + per-step delay (test throttle; module docs)
    test_delay_ms: Option<u64>,
    /// overload policy (preemption watermarks + breaker fences)
    policy: Arc<OverloadPolicy>,
    /// in-flight job journal (crash redelivery; "Failure semantics")
    board: Arc<FlightBoard>,
    /// redelivery bound before a job fails with [`WORKER_LOST_MSG`]
    max_requeues: u32,
}

impl WorkerCtx {
    /// Run `f` on this worker's stats row — the single traced
    /// acquisition point for the pool stats lock, so every counter
    /// update participates in lock-order auditing.
    fn with_stats<R>(&self, f: impl FnOnce(&mut WorkerStats) -> R) -> R {
        let _t = lockorder::trace(lockorder::STATS);
        let mut stats = self.stats.lock().unwrap_or_else(|p| p.into_inner());
        // chaos: a panic here poisons the stats lock with the row still
        // consistent (`f` has not run, so a redelivered job cannot
        // double-count) — `unwrap_or_else(|p| p.into_inner())` at every
        // acquisition is the recovery path the poison tests pin
        failpoint::fire_unit(failpoint::STATS_UPDATE);
        f(&mut stats[self.id])
    }

    /// [`WorkerCtx::with_stats`] with NO failpoint: used where an
    /// injected panic would break delivery guarantees — between a queue
    /// pop and the flight-board check-in (the popped message would be
    /// lost), and in the supervisor/redelivery path (recovery must make
    /// progress under the very faults it recovers from).
    fn with_stats_quiet<R>(&self, f: impl FnOnce(&mut WorkerStats) -> R) -> R {
        let _t = lockorder::trace(lockorder::STATS);
        let mut stats = self.stats.lock().unwrap_or_else(|p| p.into_inner());
        f(&mut stats[self.id])
    }

    fn add_idle(&self, idle_s: f64) {
        // quiet: add_idle runs between a queue pop and the flight-board
        // check-in, where a fault must not be able to strike
        self.with_stats_quiet(|s| s.idle_s += idle_s);
    }

    fn note_fused(&self, rows: usize) {
        self.with_stats(|s| {
            s.fused_calls += 1;
            s.fused_rows += rows as u64;
        });
    }

    /// Record one fused pack's page traffic (copied/reused deltas).
    fn note_pack(&self, copied: u64, reused: u64) {
        self.with_stats(|s| {
            s.pack_pages_copied += copied;
            s.pack_pages_reused += reused;
        });
    }

    /// Update the shared-page gauge with a full cycle's total (summed
    /// over every fused pack the cycle ran, so multi-group cycles don't
    /// clobber one group's sharing with another's zero).
    fn note_shared(&self, shared: u64) {
        self.with_stats(|s| s.shared_pages = shared);
    }

    fn note_solo(&self) {
        self.with_stats(|s| s.solo_calls += 1);
    }

    /// Record one fused draft execution covering `rows` rows.
    fn note_draft_fused(&self, rows: usize) {
        self.with_stats(|s| {
            s.draft_fused_calls += 1;
            s.draft_fused_rows += rows as u64;
        });
    }

    /// Record `calls` single-session draft executions (levels a session's
    /// own `plan` drove solo).
    fn note_draft_solo(&self, calls: u64) {
        self.with_stats(|s| s.draft_solo_calls += calls);
    }

    /// Record one fused DRAFT pack's page traffic.
    fn note_draft_pack(&self, copied: u64, reused: u64) {
        self.with_stats(|s| {
            s.draft_pack_pages_copied += copied;
            s.draft_pack_pages_reused += reused;
        });
    }

    /// Consume a pending cancel marker for `id`.
    fn take_cancel(&self, id: u64) -> bool {
        let _t = lockorder::trace(lockorder::CANCELS);
        self.cancels.lock().unwrap_or_else(|p| p.into_inner()).remove(&id)
    }

    fn sleep_throttle(&self) {
        if let Some(ms) = self.test_delay_ms {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// Per-name free list of method instances.  Each live session owns one
/// instance (sessions hold per-instance KV caches); at completion the
/// instance returns here so checkpoint/compile costs are paid at most
/// `max_active` times per name per worker.
type MethodPool = HashMap<String, Vec<Box<dyn Method>>>;

/// Admission order, pool-wide: preemption parks the youngest (highest
/// seq) session of the lowest priority first, and resume brings back the
/// oldest of the highest priority.
static ADMIT_SEQ: AtomicU64 = AtomicU64::new(0);

/// One live generation session on a worker.
struct ActiveJob {
    job: Job,
    rtx: Sender<JobEvent>,
    /// clock since submission (deadline base; keeps ticking while running)
    submit_sw: Stopwatch,
    queue_s: f64,
    /// clock since admission (reported latency)
    run_sw: Stopwatch,
    /// seconds spent inside start/plan/verify/absorb for this job
    cpu_s: f64,
    /// tokens already delivered as stream deltas
    sent: usize,
    /// replay (redelivered streamed job): tokens the PREVIOUS attempt
    /// already delivered — suppressed, then byte-verified, before any
    /// new delta goes out ("Failure semantics")
    skip: usize,
    /// exact delta text the previous attempt delivered (verification)
    skip_text: String,
    /// admission order (preemption victim / resume ordering)
    seq: u64,
    /// verify cycles run (the breaker's cycle fence)
    cycles: u64,
    /// submit-to-first-token, set once tokens exist (SLO counter)
    ttft_s: Option<f64>,
    /// policy fence that aborted the session (copied onto the result)
    aborted: Option<&'static str>,
    state: GenState,
    method: Box<dyn Method>,
    /// set once the session finished this cycle: Some(reuse) — `reuse`
    /// returns the method instance to the pool (false after a panic left
    /// its sessions mid-mutation).  Swept between cycles.
    ended: Option<bool>,
}

impl ActiveJob {
    /// Record the first moment generated tokens exist (cycle-granular).
    fn note_ttft(&mut self) {
        if self.ttft_s.is_none() && !self.state.tokens.is_empty() {
            self.ttft_s = Some(self.submit_sw.secs());
        }
    }
}

/// What a worker decided about dequeuing more work.
enum Polled {
    Msg(Msg),
    Empty,
    Disconnected,
}

/// Non-blocking steal off the shared overflow queue.
fn try_steal(rx: &Arc<Mutex<Receiver<Msg>>>) -> Polled {
    // chaos: fires before the channel is touched, so a panic action
    // kills the worker with nothing popped and nothing to lose
    failpoint::fire_unit(failpoint::STEAL);
    let recv = |g: &Receiver<Msg>| match g.try_recv() {
        Ok(m) => Polled::Msg(m),
        Err(TryRecvError::Empty) => Polled::Empty,
        Err(TryRecvError::Disconnected) => Polled::Disconnected,
    };
    match rx.try_lock() {
        Ok(guard) => {
            // traced after the fact: a try-lock that would have inverted
            // an order records the same edge without ever blocking
            let _t = lockorder::trace(lockorder::SHARED_RX);
            recv(&guard)
        }
        Err(std::sync::TryLockError::WouldBlock) => Polled::Empty,
        Err(std::sync::TryLockError::Poisoned(p)) => {
            let _t = lockorder::trace(lockorder::SHARED_RX);
            recv(&p.into_inner())
        }
    }
}

fn worker(ctx: WorkerCtx, artifact_dir: PathBuf, cfg: MethodCfg, rx: Arc<Mutex<Receiver<Msg>>>) {
    // The runtime is thread-pinned, so each worker owns one.  If init
    // fails (missing artifacts), keep serving: runtime-backed jobs get an
    // error result instead of a hang (runtime-free methods still run),
    // and the pool stays observable.
    let (rt, init_err): (Option<Rc<Runtime>>, Option<String>) = match Runtime::new(&artifact_dir) {
        Ok(rt) => (Some(Rc::new(rt)), None),
        Err(e) => {
            eprintln!("[scheduler] worker {}: runtime init failed: {e:#}", ctx.id);
            (None, Some(format!("runtime init failed: {e:#}")))
        }
    };
    let mut pool: MethodPool = HashMap::new();
    let mut active: Vec<ActiveJob> = Vec::new();
    // persistent fused-pack images + page staging caches, one per fused
    // group ordinal: pages staged in one cycle are reused by the next
    // (same (id, stamp) at the same fused offset), which is what makes
    // packing O(changed pages) — and a cycle that splits into several
    // capacity groups must not let group B's pack evict group A's staging
    let mut scratches: Vec<FusedScratch> = Vec::new();
    // fused DRAFT packs stage into their own per-group scratches: the
    // draft cache's single-layer geometry differs from the target's, and
    // FusedScratch staging is keyed by geometry (sharing one vec would
    // thrash both staging caches every cycle)
    let mut draft_scratches: Vec<FusedScratch> = Vec::new();
    // sessions paused under page pressure (overload policy): they keep
    // their GenState + committed pages, count toward max_active and the
    // load gauge, and resume once the gauge recovers
    let mut parked: Vec<ActiveJob> = Vec::new();
    let mut draining = false;
    loop {
        // ---- admit new jobs up to max_active (parked ones count) ----
        while active.len() + parked.len() < ctx.max_active {
            let msg = if draining {
                // stop pulling shared work (other workers' markers), but
                // keep serving jobs routed directly to this worker
                match ctx.queue.pop() {
                    Some(m) => m,
                    None => break,
                }
            } else if active.is_empty() && parked.is_empty() {
                // nothing to step: park for work (counted as idle)
                let idle_sw = Stopwatch::start();
                let m = loop {
                    if let Some(m) = ctx.queue.pop() {
                        break Some(m);
                    }
                    match try_steal(&rx) {
                        Polled::Msg(m) => break Some(m),
                        Polled::Disconnected => break None,
                        Polled::Empty => ctx.queue.park(),
                    }
                };
                ctx.add_idle(idle_sw.secs());
                match m {
                    Some(m) => m,
                    None => {
                        // shared channel gone: drain our own queue and exit
                        draining = true;
                        continue;
                    }
                }
            } else {
                // live sessions waiting: poll both sources without blocking
                match ctx.queue.pop() {
                    Some(m) => m,
                    None => match try_steal(&rx) {
                        Polled::Msg(m) => m,
                        Polled::Empty => break,
                        Polled::Disconnected => {
                            draining = true;
                            continue;
                        }
                    },
                }
            };
            match msg {
                Msg::Shutdown => {
                    // finish live sessions + our own queued jobs, stop
                    // stealing shared work
                    draining = true;
                }
                Msg::Run(job, submit_sw, rtx) => {
                    ctx.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    // journal the job FIRST: from here to its `Done` send
                    // the flight board guarantees redelivery if this
                    // thread dies ("Failure semantics")
                    ctx.board.check_in(
                        ctx.id,
                        FlightRec {
                            job: job.clone(),
                            rtx: rtx.clone(),
                            sent_tokens: 0,
                            sent_text: String::new(),
                            attempts: 0,
                        },
                    );
                    // reserve the session slot in the load gauge BEFORE the
                    // (possibly throttled) admission work, so least-loaded
                    // dispatch never sees this worker as idle mid-admit
                    ctx.queue.load.fetch_add(1, Ordering::Relaxed);
                    match admit(
                        &ctx,
                        rt.as_ref(),
                        &init_err,
                        &mut pool,
                        &cfg,
                        job,
                        submit_sw,
                        rtx,
                        None,
                    ) {
                        Some(a) => active.push(a),
                        None => {
                            ctx.queue.load.fetch_sub(1, Ordering::Relaxed);
                        }
                    }
                }
                Msg::Redo(job, redo, rtx) => {
                    // redelivered work: not a client submission, so the
                    // pool-wide queue_depth gauge is untouched
                    ctx.board.check_in(
                        ctx.id,
                        FlightRec {
                            job: job.clone(),
                            rtx: rtx.clone(),
                            sent_tokens: redo.skip_tokens,
                            sent_text: redo.prefix_text.clone(),
                            attempts: redo.attempts,
                        },
                    );
                    ctx.queue.load.fetch_add(1, Ordering::Relaxed);
                    match admit(
                        &ctx,
                        rt.as_ref(),
                        &init_err,
                        &mut pool,
                        &cfg,
                        job,
                        Stopwatch::start(),
                        rtx,
                        Some(redo),
                    ) {
                        Some(a) => active.push(a),
                        None => {
                            ctx.queue.load.fetch_sub(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }
        // fold this thread's cross-worker dedup hits (admission prefills
        // and cycle absorbs since the last drain) into the stats row
        let cross = crate::kvcache::take_cross_worker_hits();
        if cross > 0 {
            ctx.with_stats(|s| s.cross_worker_shared_pages += cross);
        }
        // parked sessions: honor cancels/deadlines, then let the page
        // gauge decide who resumes or who else parks (overload policy)
        sweep_parked(&ctx, &mut pool, &mut parked);
        manage_pressure(
            &ctx,
            &mut active,
            &mut parked,
            &mut scratches,
            &mut draft_scratches,
            draining,
        );
        if active.is_empty() {
            if parked.is_empty() {
                if draining && ctx.queue.is_empty() {
                    return;
                }
                continue;
            }
            // every session is parked: wait for pages to free (resume is
            // re-evaluated at the top of each iteration)
            std::thread::sleep(std::time::Duration::from_millis(1));
            continue;
        }
        // ---- one fused cycle over every live session: level-synchronous
        // draft expansion first, then fused verification ----
        run_draft_phase(&ctx, &mut active, &mut draft_scratches);
        run_cycle(&ctx, &mut active, &mut scratches);
        sweep_ended(&ctx, &mut pool, &mut active);
        // chaos: a panic action here kills the thread BETWEEN cycles —
        // every live session has run at least one cycle per incarnation
        // (streamed jobs therefore have deltas on the board and exercise
        // the replay path), finished ones are already checked out, and
        // idle workers never reach this line (the admit loop parks them)
        failpoint::fire_unit(failpoint::WORKER_TICK);
    }
}

/// Complete parked sessions whose cancel marker or deadline fired while
/// they were paused — a parked session must stay responsive to both.
fn sweep_parked(ctx: &WorkerCtx, pool: &mut MethodPool, parked: &mut Vec<ActiveJob>) {
    let mut i = 0;
    while i < parked.len() {
        let a = &mut parked[i];
        let msg = if ctx.take_cancel(a.job.id) {
            Some("cancelled".to_string())
        } else if past_deadline(&a.job, &a.submit_sw) {
            let ms = a.job.deadline_ms.unwrap_or(0);
            Some(format!("deadline_ms exceeded ({ms} ms)"))
        } else {
            None
        };
        match msg {
            Some(m) => {
                complete(ctx, a, Some(m));
                let a = parked.swap_remove(i);
                ctx.queue.load.fetch_sub(1, Ordering::Relaxed);
                let name = a.job.method.clone();
                checkin(pool, &name, a.method);
            }
            None => i += 1,
        }
    }
}

/// The preemption state machine, run between cycles (module docs,
/// "Overload policy"): resume parked sessions — highest priority, oldest
/// first — while the gauge sits at or under the resume low-water mark
/// (or unconditionally when draining, so shutdown cannot strand them);
/// park active sessions — lowest priority, youngest first — while the
/// gauge is past the preempt high-water mark, always keeping one active
/// for forward progress.  Parking drops rebuildable state only
/// (`KvCache::release_staging` + the worker's fused-pack staging), so a
/// resumed run stays token-identical to an uninterrupted one.
fn manage_pressure(
    ctx: &WorkerCtx,
    active: &mut Vec<ActiveJob>,
    parked: &mut Vec<ActiveJob>,
    scratches: &mut Vec<FusedScratch>,
    draft_scratches: &mut Vec<FusedScratch>,
    draining: bool,
) {
    while !parked.is_empty() && active.len() < ctx.max_active {
        let under = match ctx.policy.page_budget {
            Some(b) => ctx.policy.live() as f64 <= ctx.policy.resume_lwm * b as f64,
            None => true,
        };
        if !under && !draining {
            break;
        }
        let mut best = 0;
        for i in 1..parked.len() {
            let (bp, bs) = (parked[best].job.priority, parked[best].seq);
            let (ip, is) = (parked[i].job.priority, parked[i].seq);
            if ip > bp || (ip == bp && is < bs) {
                best = i;
            }
        }
        ctx.with_stats(|s| s.resumes += 1);
        active.push(parked.swap_remove(best));
    }
    let Some(budget) = ctx.policy.page_budget else { return };
    let hwm = ctx.policy.preempt_hwm * budget as f64;
    let mut parked_any = false;
    while active.len() > 1 && ctx.policy.live() as f64 > hwm {
        let mut victim = 0;
        for i in 1..active.len() {
            let (vp, vs) = (active[victim].job.priority, active[victim].seq);
            let (ip, is) = (active[i].job.priority, active[i].seq);
            if ip < vp || (ip == vp && is > vs) {
                victim = i;
            }
        }
        let mut a = active.swap_remove(victim);
        if let Some(t) = a.method.fused_handle() {
            t.cache.release_staging();
        }
        if let Some(d) = a.method.draft_handle() {
            d.cache.release_staging();
        }
        ctx.with_stats(|s| s.preemptions += 1);
        parked.push(a);
        parked_any = true;
    }
    if parked_any {
        // the parked sessions' pages may die: drop the fused-pack staging
        // images so the worker's scratch does not pin their memory
        scratches.clear();
        draft_scratches.clear();
    }
}

/// Remove sessions that finished during the last cycle, returning
/// reusable method instances to the per-name free list.
fn sweep_ended(ctx: &WorkerCtx, pool: &mut MethodPool, active: &mut Vec<ActiveJob>) {
    let mut i = 0;
    while i < active.len() {
        let ended = active[i].ended;
        match ended {
            Some(reuse) => {
                let a = active.swap_remove(i);
                ctx.queue.load.fetch_sub(1, Ordering::Relaxed);
                if reuse {
                    let name = a.job.method.clone();
                    checkin(pool, &name, a.method);
                }
            }
            None => i += 1,
        }
    }
}

fn checkout(
    pool: &mut MethodPool,
    rt: Option<&Rc<Runtime>>,
    init_err: &Option<String>,
    cfg: &MethodCfg,
    name: &str,
) -> std::result::Result<Box<dyn Method>, String> {
    if let Some(m) = pool.get_mut(name).and_then(|v| v.pop()) {
        return Ok(m);
    }
    if let Some(m) = crate::engine::build_free_method(name) {
        return Ok(m);
    }
    match rt {
        Some(rt) => build_method(rt, name, cfg).map_err(|e| format!("{e:#}")),
        None => Err(init_err.clone().unwrap_or_else(|| "runtime init failed".to_string())),
    }
}

fn checkin(pool: &mut MethodPool, name: &str, m: Box<dyn Method>) {
    pool.entry(name.to_string()).or_default().push(m);
}

fn past_deadline(job: &Job, since_submit: &Stopwatch) -> bool {
    match job.deadline_ms {
        Some(ms) => since_submit.secs() * 1000.0 > ms as f64,
        None => false,
    }
}

/// Why the circuit breaker aborts this session now, if it does: the
/// cycle fence trips first, then the wall-clock fence (admission-based,
/// unlike `deadline_ms` which the *client* anchors at submission).
fn breaker_trip(policy: &OverloadPolicy, a: &ActiveJob) -> Option<String> {
    if let Some(max_cycles) = policy.breaker_max_cycles {
        if a.cycles > max_cycles {
            return Some(format!("breaker: session exceeded {max_cycles} cycles"));
        }
    }
    if let Some(max_ms) = policy.breaker_max_ms {
        if a.run_sw.secs() * 1000.0 > max_ms as f64 {
            return Some(format!("breaker: session ran past {max_ms} ms"));
        }
    }
    None
}

/// Start a session for a dequeued job.  Returns the live session, or
/// `None` if the job already completed (rejected, or done at start).
#[allow(clippy::too_many_arguments)]
fn admit(
    ctx: &WorkerCtx,
    rt: Option<&Rc<Runtime>>,
    init_err: &Option<String>,
    pool: &mut MethodPool,
    cfg: &MethodCfg,
    job: Job,
    submit_sw: Stopwatch,
    rtx: Sender<JobEvent>,
    redo: Option<Redo>,
) -> Option<ActiveJob> {
    let queue_s = submit_sw.secs();
    if ctx.take_cancel(job.id) {
        reject(ctx, &job, queue_s, 0.0, 0.0, "cancelled", &rtx);
        return None;
    }
    if past_deadline(&job, &submit_sw) {
        reject(ctx, &job, queue_s, 0.0, 0.0, "deadline_ms exceeded while queued", &rtx);
        return None;
    }
    // work clock: the test throttle, method build/compile, and start()
    // are all real worker occupancy and count toward busy_s
    let work_sw = Stopwatch::start();
    ctx.sleep_throttle();
    let mut method = match checkout(pool, rt, init_err, cfg, &job.method) {
        Ok(m) => m,
        Err(msg) => {
            reject(ctx, &job, queue_s, 0.0, work_sw.secs(), &msg, &rtx);
            return None;
        }
    };
    let req = GenRequest {
        prompt_tokens: tokenizer::encode(&job.prompt, true),
        max_new: job.max_new,
        params: SampleParams {
            temperature: job.temperature,
            seed: job.seed,
            ..Default::default()
        },
    };
    let run_sw = Stopwatch::start();
    // a panicking method (bad logits, artifact mismatch...) must cost one
    // error response, not the engine thread — and certainly not a client
    // hung waiting for a reply
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        let r = method.start(&req);
        (method, r)
    }));
    let cpu_s = work_sw.secs();
    match caught {
        Err(p) => {
            // instance sessions are mid-mutation: drop the instance
            let msg = format!("engine panic: {}", panic_text(p.as_ref()));
            if failpoint::is_injected(&msg) && redeliver_job(ctx, job.id) {
                return None;
            }
            reject(ctx, &job, queue_s, run_sw.secs(), cpu_s, &msg, &rtx);
            None
        }
        Ok((method, Err(e))) => {
            checkin(pool, &job.method, method);
            let msg = format!("{e:#}");
            if failpoint::is_injected(&msg) && redeliver_job(ctx, job.id) {
                return None;
            }
            reject(ctx, &job, queue_s, run_sw.secs(), cpu_s, &msg, &rtx);
            None
        }
        Ok((method, Ok(state))) => {
            let (skip, skip_text) =
                redo.map_or((0, String::new()), |r| (r.skip_tokens, r.prefix_text));
            let mut a = ActiveJob {
                job,
                rtx,
                submit_sw,
                queue_s,
                run_sw,
                cpu_s,
                sent: 0,
                skip,
                skip_text,
                seq: ADMIT_SEQ.fetch_add(1, Ordering::Relaxed),
                cycles: 0,
                ttft_s: None,
                aborted: None,
                state,
                method,
                ended: None,
            };
            if !flush_delta(ctx, &mut a) {
                // replay prefix mismatch: already completed as worker_lost
                let name = a.job.method.clone();
                checkin(pool, &name, a.method);
                return None;
            }
            if a.state.done {
                complete(ctx, &mut a, None);
                let name = a.job.method.clone();
                checkin(pool, &name, a.method);
                None
            } else {
                Some(a)
            }
        }
    }
}

/// A compiled-target session's fuse-relevant shape, probed without
/// holding any session borrow.  Occupancy is page-granular: what a member
/// adds to a group is its *distinct* page ids, so co-active sessions
/// sharing a prompt prefix cost their shared pages only once.
#[derive(Clone, Debug)]
pub(crate) struct FuseCand {
    /// target checkpoint identity (fused members must share weights)
    pub wptr: usize,
    pub slots: usize,
    pub page_size: usize,
    /// ids of the pages backing the committed prefix
    pub pages: Vec<u64>,
    /// candidate verification rows this cycle
    pub rows: usize,
}

/// How a planned session's verification will be executed.
enum VerKind {
    /// compiled target graph; fused by (weights ptr, page capacity)
    Target(FuseCand),
    /// runtime-free host verifier; fused by method name
    Host,
    /// no executor handle — verify through the method's own `verify`
    Solo,
}

/// Greedily group compiled-target candidates while one decode-block call
/// can hold every member: rows fit the widest artifact, and the group's
/// *unique* pages plus the padded block fit the cache —
/// `(unique pages)·page_size + pick_block(rows) <= slots`, the paged
/// replacement for the old `Σ prefixes + block <= slots` ceiling (a
/// shared-prefix fleet can therefore fuse past the old session bound).
pub(crate) fn plan_fuse_groups(cands: &[Option<&FuseCand>]) -> Vec<Vec<usize>> {
    plan_fuse_groups_by(cands, MAX_BLOCK, pick_block)
}

/// [`plan_fuse_groups`] with a pluggable compiled-width ladder: `max_rows`
/// is the widest artifact and `pick(n)` the padded width for `n` rows —
/// the draft phase reuses the grouping machinery over the
/// `draft_decode_b{N}` inventory instead of the target ladder.
pub(crate) fn plan_fuse_groups_by(
    cands: &[Option<&FuseCand>],
    max_rows: usize,
    pick: impl Fn(usize) -> usize,
) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut cur_pages: HashSet<u64> = HashSet::new();
    // fused segments the group occupies — distinct ids once, plus one per
    // intra-member duplicate occurrence, exactly mirroring
    // `PackedLayout::plan` so an admitted group can never fail to pack
    let mut cur_segments = 0usize;
    let mut cur_rows = 0usize;
    let (mut cur_wptr, mut cur_slots, mut cur_ps) = (0usize, 0usize, 0usize);
    for (i, cand) in cands.iter().enumerate() {
        let Some(c) = cand else { continue };
        // segments this candidate would add to the current group
        let mut seen: HashSet<u64> = HashSet::new();
        let mut add = 0usize;
        for &id in &c.pages {
            if !seen.insert(id) || !cur_pages.contains(&id) {
                add += 1;
            }
        }
        let fits = !cur.is_empty()
            && c.wptr == cur_wptr
            && c.slots == cur_slots
            && c.page_size == cur_ps
            && cur_rows + c.rows <= max_rows
            && (cur_segments + add) * c.page_size + pick(cur_rows + c.rows) <= c.slots;
        if fits {
            cur.push(i);
            cur_rows += c.rows;
            cur_segments += add;
            cur_pages.extend(c.pages.iter().copied());
        } else {
            if !cur.is_empty() {
                groups.push(std::mem::take(&mut cur));
            }
            cur.push(i);
            cur_pages.clear();
            cur_pages.extend(c.pages.iter().copied());
            // alone in a fresh group, every page occurrence is a segment
            cur_segments = c.pages.len();
            cur_rows = c.rows;
            cur_wptr = c.wptr;
            cur_slots = c.slots;
            cur_ps = c.page_size;
        }
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    groups
}

/// Phase 0 of a cycle: level-synchronous fused draft expansion (PR 5).
///
/// Each round polls every live session for its next draft-tree level
/// (`Method::draft_next` — idempotent until fed) and fuses the rows of
/// >= 2 compatible sessions into ONE draft execution: compiled
/// EAGLE-family sessions through `engine::sessions::fused_draft_decode`
/// (draft pages packed page-granular like verify packing, grouped by the
/// same capacity machinery over the `draft_decode_b{N}` width ladder),
/// host-drafted sessions (mock) through one batched call of their shared
/// drafter.  Rounds repeat until no fused execution makes progress —
/// sessions left ungrouped (lone planner, failed fused call, method
/// without a draft phase) keep their pending level and `plan` drives the
/// remainder of their walk solo, which is why fused-draft failure needs
/// no cleanup: packing copies pages OUT of the sessions and mutates only
/// the worker's scratch image.
fn run_draft_phase(ctx: &WorkerCtx, active: &mut [ActiveJob], scratches: &mut Vec<FusedScratch>) {
    let n = active.len();
    loop {
        // ---- poll each live session for its next level ----
        let mut pend: Vec<Option<DraftRows>> = (0..n).map(|_| None).collect();
        for i in 0..n {
            let a = &mut active[i];
            if a.ended.is_some() || a.state.done {
                continue;
            }
            // cancel/deadline before spending draft calls on the session
            // (run_cycle re-checks, but a job cancelled mid-phase must
            // not burn a whole tree build first — and must report
            // "cancelled", not whatever error the doomed drafting hits)
            if ctx.take_cancel(a.job.id) {
                complete(ctx, a, Some("cancelled".to_string()));
                a.ended = Some(true);
                continue;
            }
            if past_deadline(&a.job, &a.submit_sw) {
                let ms = a.job.deadline_ms.unwrap_or(0);
                complete(ctx, a, Some(format!("deadline_ms exceeded ({ms} ms)")));
                a.ended = Some(true);
                continue;
            }
            let cpu_sw = Stopwatch::start();
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                a.method.draft_next(&mut a.state)
            }));
            a.cpu_s += cpu_sw.secs();
            match caught {
                Err(p) => {
                    fail_session(ctx, a, format!("engine panic: {}", panic_text(p.as_ref())));
                    a.ended = Some(false);
                }
                Ok(Err(e)) => {
                    fail_session(ctx, a, format!("{e:#}"));
                    a.ended = Some(true);
                }
                Ok(Ok(DraftPhase::Rows(r))) => pend[i] = Some(r),
                // Ready / Finished / no draft phase: nothing to fuse —
                // `plan` (the verify cycle's phase 1) takes it from here
                Ok(Ok(_)) => {}
            }
        }

        let mut progressed = false;

        // ---- compiled draft groups (page-granular capacity over the
        // draft width ladder) ----
        let mut widths: Vec<usize> = Vec::new();
        let cands: Vec<Option<FuseCand>> = (0..n)
            .map(|i| {
                let rows = pend[i].as_ref()?;
                let a = &mut active[i];
                let d = a.method.draft_handle()?;
                if widths.is_empty() {
                    widths = d.widths().to_vec();
                }
                Some(FuseCand {
                    wptr: Rc::as_ptr(&d.weights) as usize,
                    slots: d.slots,
                    page_size: d.cache.page_size(),
                    pages: d.cache.page_ids_covering(rows.write_start),
                    rows: rows.len(),
                })
            })
            .collect();
        let groups = match widths.last().copied() {
            None => Vec::new(),
            Some(max_w) => {
                let refs: Vec<Option<&FuseCand>> = cands.iter().map(|c| c.as_ref()).collect();
                plan_fuse_groups_by(&refs, max_w, |r| pick_width(&widths, r).unwrap_or(max_w))
            }
        };
        for (gi, g) in groups.iter().enumerate() {
            if g.len() < 2 {
                // a lone session's walk is cheaper inside its own plan
                continue;
            }
            while scratches.len() <= gi {
                scratches.push(FusedScratch::new());
            }
            let scratch = &mut scratches[gi];
            let total_rows: usize =
                g.iter().map(|&i| pend[i].as_ref().map_or(0, |r| r.len())).sum();
            let pack_before = (scratch.pages_copied, scratch.pages_reused);
            let sw = Stopwatch::start();
            let outs = {
                let mut batch: Vec<(&mut DraftSession, &DraftRows)> = Vec::with_capacity(g.len());
                for (i, a) in active.iter_mut().enumerate() {
                    if !g.contains(&i) {
                        continue;
                    }
                    if let (Some(d), Some(rows)) = (a.method.draft_handle(), pend[i].as_ref()) {
                        batch.push((d, rows));
                    }
                }
                if batch.len() == g.len() {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        fused_draft_decode(scratch, &mut batch)
                    }))
                    .unwrap_or_else(|p| {
                        Err(anyhow::anyhow!("engine panic: {}", panic_text(p.as_ref())))
                    })
                } else {
                    Err(anyhow::anyhow!("draft handle disappeared between probe and pack"))
                }
            };
            let draft_s = sw.secs();
            ctx.note_draft_pack(
                scratch.pages_copied - pack_before.0,
                scratch.pages_reused - pack_before.1,
            );
            match outs {
                Ok(outs) => {
                    ctx.note_draft_fused(total_rows);
                    progressed = true;
                    let share = draft_s / g.len() as f64;
                    let mut oi = 0usize;
                    for (i, a) in active.iter_mut().enumerate() {
                        if !g.contains(&i) {
                            continue;
                        }
                        pend[i] = None;
                        a.state.metrics.phases.draft_s += share;
                        a.cpu_s += share;
                        feed_one(ctx, a, &outs[oi]);
                        oi += 1;
                    }
                }
                Err(e) => {
                    // execute each member's level solo NOW (packing
                    // copies pages OUT of the sessions, so nothing needs
                    // undoing) — leaving the levels pending would retry
                    // the same failing fused call every round
                    eprintln!(
                        "[scheduler] worker {}: fused draft failed ({e:#}); \
                         falling back to solo expansion",
                        ctx.id
                    );
                    progressed = true;
                    for (i, a) in active.iter_mut().enumerate() {
                        if !g.contains(&i) {
                            continue;
                        }
                        let Some(rows) = pend[i].take() else { continue };
                        solo_draft_exec(ctx, a, &rows);
                    }
                }
            }
        }

        // ---- host draft groups: every host-drafted session of the same
        // method shares one batched drafter call ----
        let mut host_groups: Vec<(String, Vec<usize>)> = Vec::new();
        for i in 0..n {
            if pend[i].is_none() || active[i].ended.is_some() {
                continue;
            }
            if active[i].method.host_drafter().is_none() {
                continue;
            }
            let name = active[i].job.method.clone();
            match host_groups.iter().position(|(k, _)| *k == name) {
                Some(p) => host_groups[p].1.push(i),
                None => host_groups.push((name, vec![i])),
            }
        }
        for (_, g) in &host_groups {
            if g.len() < 2 {
                continue;
            }
            let Some(hd) = active[g[0]].method.host_drafter() else { continue };
            let mut tokens: Vec<i32> = Vec::new();
            let mut positions: Vec<usize> = Vec::new();
            for &i in g {
                let Some(rows) = pend[i].as_ref() else {
                    // unreachable (host groups are built from pending
                    // members) — but a lost member must not kill the
                    // worker; the scatter below skips it the same way
                    eprintln!("[scheduler] worker {}: host draft member lost its rows", ctx.id);
                    continue;
                };
                tokens.extend_from_slice(&rows.tokens);
                positions.extend_from_slice(&rows.positions);
            }
            let sw = Stopwatch::start();
            let caught =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| hd(&tokens, &positions)));
            let draft_s = sw.secs();
            let out = match caught {
                Ok(out) => out,
                Err(p) => {
                    let msg = panic_text(p.as_ref());
                    for (i, a) in active.iter_mut().enumerate() {
                        if !g.contains(&i) {
                            continue;
                        }
                        pend[i] = None;
                        complete(ctx, a, Some(format!("engine panic: {msg}")));
                        a.ended = Some(true);
                    }
                    continue;
                }
            };
            ctx.note_draft_fused(tokens.len());
            progressed = true;
            let vocab = out.logits.dims[1];
            let fdim = out.feats.dims[1];
            let share = draft_s / g.len() as f64;
            let mut off = 0usize;
            for (i, a) in active.iter_mut().enumerate() {
                if !g.contains(&i) {
                    continue;
                }
                let n_i = pend[i].take().map_or(0, |r| r.len());
                let mut lj = Vec::with_capacity(n_i * vocab);
                let mut fj = Vec::with_capacity(n_i * fdim);
                for r in off..off + n_i {
                    lj.extend_from_slice(out.logits.row(r));
                    fj.extend_from_slice(out.feats.row(r));
                }
                off += n_i;
                let member_out = VerifyOut {
                    logits: crate::runtime::TensorF { dims: vec![n_i, vocab], data: lj },
                    feats: crate::runtime::TensorF { dims: vec![n_i, fdim], data: fj },
                };
                a.state.metrics.phases.draft_s += share;
                a.cpu_s += share;
                feed_one(ctx, a, &member_out);
            }
        }

        if !progressed {
            break;
        }
    }
}

/// Execute one session's pending draft level through its own compiled
/// draft session (the fused-failure fallback), then feed it.
fn solo_draft_exec(ctx: &WorkerCtx, a: &mut ActiveJob, rows: &DraftRows) {
    let cpu_sw = Stopwatch::start();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        match a.method.draft_handle() {
            Some(d) => d.decode_rows(rows),
            None => Err(anyhow::anyhow!("draft handle disappeared between probe and fallback")),
        }
    }));
    let spent = cpu_sw.secs();
    a.cpu_s += spent;
    match caught {
        Err(p) => {
            complete(ctx, a, Some(format!("engine panic: {}", panic_text(p.as_ref()))));
            a.ended = Some(false);
        }
        Ok(Err(e)) => {
            complete(ctx, a, Some(format!("{e:#}")));
            a.ended = Some(true);
        }
        Ok(Ok(out)) => {
            ctx.note_draft_solo(1);
            a.state.metrics.phases.draft_s += spent;
            feed_one(ctx, a, &out);
        }
    }
}

/// Feed one fused draft level's outputs into a session, with the same
/// completion/panic discipline as a solo step.
fn feed_one(ctx: &WorkerCtx, a: &mut ActiveJob, out: &VerifyOut) {
    let cpu_sw = Stopwatch::start();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        a.method.draft_feed(&mut a.state, out)
    }));
    a.cpu_s += cpu_sw.secs();
    match caught {
        Err(p) => {
            complete(ctx, a, Some(format!("engine panic: {}", panic_text(p.as_ref()))));
            a.ended = Some(false);
        }
        Ok(Err(e)) => {
            complete(ctx, a, Some(format!("{e:#}")));
            a.ended = Some(true);
        }
        Ok(Ok(())) => {}
    }
}

/// One fused verification cycle over every live session:
///
/// 1. check cancel/deadline, then `plan` each session (drafting);
/// 2. pack batchable sessions' rows into as few verify executions as
///    capacity allows — compiled sessions through `fused_decode` (one
///    graph call per group), host sessions through one batch call of
///    their shared `HostVerifier`;
/// 3. scatter the outputs and `absorb` each session;
/// 4. run `Unbatchable` sessions through their solo `step`.
///
/// Sessions that finish (or fail) anywhere in the cycle are completed
/// inline and marked `ended` for the caller's sweep.  A failed fused
/// call falls back to per-session solo verifies — packing copies pages
/// *out* of the sessions and mutates only the worker's scratch image, so
/// the retry is safe.
fn run_cycle(ctx: &WorkerCtx, active: &mut [ActiveJob], scratches: &mut Vec<FusedScratch>) {
    let n = active.len();
    // ---- phase 1: checks + plan ----
    let mut rows_of: Vec<Option<VerifyRows>> = (0..n).map(|_| None).collect();
    let mut solo: Vec<bool> = vec![false; n];
    for i in 0..n {
        let a = &mut active[i];
        if a.ended.is_some() {
            continue;
        }
        if ctx.take_cancel(a.job.id) {
            complete(ctx, a, Some("cancelled".to_string()));
            a.ended = Some(true);
            continue;
        }
        if past_deadline(&a.job, &a.submit_sw) {
            let ms = a.job.deadline_ms.unwrap_or(0);
            complete(ctx, a, Some(format!("deadline_ms exceeded ({ms} ms)")));
            a.ended = Some(true);
            continue;
        }
        a.note_ttft();
        // circuit breakers: a runaway session is aborted between cycles
        // with a distinct status, so it cannot pin pages until max_new
        a.cycles += 1;
        if let Some(reason) = breaker_trip(&ctx.policy, a) {
            ctx.with_stats(|s| s.breaker_trips += 1);
            a.aborted = Some("breaker");
            complete(ctx, a, Some(reason));
            a.ended = Some(true);
            continue;
        }
        let cpu_sw = Stopwatch::start();
        let draft_before = a.state.metrics.draft_calls;
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| a.method.plan(&mut a.state)));
        a.cpu_s += cpu_sw.secs();
        // draft executions plan ran itself (walk levels the draft phase
        // left unfused, or a method that drafts entirely inside plan) are
        // the solo side of the draft-batching ledger
        let solo_drafts = a.state.metrics.draft_calls.saturating_sub(draft_before);
        if solo_drafts > 0 {
            ctx.note_draft_solo(solo_drafts as u64);
        }
        match caught {
            Err(p) => {
                fail_session(ctx, a, format!("engine panic: {}", panic_text(p.as_ref())));
                a.ended = Some(false);
            }
            Ok(Err(e)) => {
                fail_session(ctx, a, format!("{e:#}"));
                a.ended = Some(true);
            }
            Ok(Ok(StepPlan::Finished(_))) => {
                if flush_delta(ctx, a) {
                    complete(ctx, a, None);
                    a.ended = Some(true);
                }
                ctx.sleep_throttle();
            }
            Ok(Ok(StepPlan::Unbatchable)) => solo[i] = true,
            Ok(Ok(StepPlan::Verify(rows))) => rows_of[i] = Some(rows),
        }
    }

    // ---- phase 2: probe executors + group by page-granular capacity ----
    let mut kinds: Vec<Option<VerKind>> = (0..n).map(|_| None).collect();
    for i in 0..n {
        let Some(rows) = rows_of[i].as_ref() else { continue };
        let r = rows.len();
        let a = &mut active[i];
        kinds[i] = Some(if a.method.host_verifier().is_some() {
            VerKind::Host
        } else if let Some(t) = a.method.fused_handle() {
            VerKind::Target(FuseCand {
                wptr: Rc::as_ptr(&t.weights) as usize,
                slots: t.cache.slots,
                page_size: t.cache.page_size(),
                pages: t.cache.committed_page_ids(),
                rows: r,
            })
        } else {
            VerKind::Solo
        });
    }
    // compiled-target groups: greedy while one decode-block call can hold
    // every member's distinct pages + padded rows (shared prompt pages
    // count once — the lifted fusion ceiling)
    let groups = {
        let cands: Vec<Option<&FuseCand>> = kinds
            .iter()
            .map(|k| match k {
                Some(VerKind::Target(c)) => Some(c),
                _ => None,
            })
            .collect();
        plan_fuse_groups(&cands)
    };
    // host groups: every host-verified session of the same method shares
    // one batch call (the verifier is a pure per-row function)
    let mut host_groups: Vec<(String, Vec<usize>)> = Vec::new();
    for i in 0..n {
        if !matches!(kinds[i], Some(VerKind::Host)) {
            continue;
        }
        let name = active[i].job.method.clone();
        match host_groups.iter().position(|(k, _)| *k == name) {
            Some(p) => host_groups[p].1.push(i),
            None => host_groups.push((name, vec![i])),
        }
    }
    // sessions with no executor handle verify solo
    for i in 0..n {
        if !matches!(kinds[i], Some(VerKind::Solo)) {
            continue;
        }
        let Some(rows) = rows_of[i].take() else { continue };
        solo_verify_absorb(ctx, &mut active[i], &rows);
        ctx.sleep_throttle();
    }

    // ---- phase 3a: fused compiled groups ----
    let mut cycle_shared: Option<u64> = None;
    for (gi, g) in groups.iter().enumerate() {
        if g.len() == 1 {
            let i = g[0];
            let Some(rows) = rows_of[i].take() else { continue };
            solo_verify_absorb(ctx, &mut active[i], &rows);
            ctx.sleep_throttle();
            continue;
        }
        // one scratch per group ordinal: with stable membership, group gi
        // hits the same staging cache it filled last cycle
        while scratches.len() <= gi {
            scratches.push(FusedScratch::new());
        }
        let scratch = &mut scratches[gi];
        let total_rows: usize =
            g.iter().map(|&i| rows_of[i].as_ref().map_or(0, |r| r.len())).sum();
        let pack_before = (scratch.pages_copied, scratch.pages_reused, scratch.packs);
        let sw = Stopwatch::start();
        let outs = {
            let mut batch: Vec<(&mut TargetSession, &VerifyRows)> = Vec::with_capacity(g.len());
            for (i, a) in active.iter_mut().enumerate() {
                if !g.contains(&i) {
                    continue;
                }
                if let (Some(t), Some(rows)) = (a.method.fused_handle(), rows_of[i].as_ref()) {
                    batch.push((t, rows));
                }
            }
            if batch.len() == g.len() {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    fused_decode(scratch, &mut batch)
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow::anyhow!("engine panic: {}", panic_text(p.as_ref())))
                })
            } else {
                Err(anyhow::anyhow!("fused handle disappeared between probe and pack"))
            }
        };
        let verify_s = sw.secs();
        // pack traffic happened whether or not the graph call succeeded —
        // but only read the shared-page gauge if a pack actually ran this
        // group (a call that bailed before packing would replay a stale
        // value)
        ctx.note_pack(
            scratch.pages_copied - pack_before.0,
            scratch.pages_reused - pack_before.1,
        );
        if scratch.packs != pack_before.2 {
            *cycle_shared.get_or_insert(0) += scratch.shared_pages;
        }
        match outs {
            Ok(outs) => {
                ctx.note_fused(total_rows);
                let share = verify_s / g.len() as f64;
                let mut oi = 0usize;
                for (i, a) in active.iter_mut().enumerate() {
                    if !g.contains(&i) {
                        continue;
                    }
                    rows_of[i] = None;
                    a.state.metrics.phases.verify_s += share;
                    a.state.metrics.target_calls += 1;
                    a.cpu_s += share;
                    absorb_one(ctx, a, &outs[oi]);
                    oi += 1;
                    ctx.sleep_throttle();
                }
            }
            Err(e) => {
                // packing only copies pages OUT of the sessions (into the
                // worker scratch), so every member can retry solo
                eprintln!(
                    "[scheduler] worker {}: fused verify failed ({e:#}); retrying solo",
                    ctx.id
                );
                for &i in g {
                    let Some(rows) = rows_of[i].take() else { continue };
                    solo_verify_absorb(ctx, &mut active[i], &rows);
                    ctx.sleep_throttle();
                }
            }
        }
    }
    // gauge: this cycle's cross-session shared pages, summed over every
    // fused pack (left untouched on cycles with no fused call, so a brief
    // solo cycle doesn't zero an otherwise-sharing worker)
    if let Some(shared) = cycle_shared {
        ctx.note_shared(shared);
    }

    // ---- phase 3b: fused host groups ----
    for (_, g) in &host_groups {
        if g.len() == 1 {
            let i = g[0];
            let Some(rows) = rows_of[i].take() else { continue };
            solo_verify_absorb(ctx, &mut active[i], &rows);
            ctx.sleep_throttle();
            continue;
        }
        // pack every member's rows into one host batch call
        let hv: Option<HostVerifier> = active[g[0]].method.host_verifier();
        let Some(hv) = hv else {
            // probe went stale (cannot happen for stateless verifiers):
            // degrade to per-member solo verifies instead of stalling
            for &i in g {
                let Some(rows) = rows_of[i].take() else { continue };
                solo_verify_absorb(ctx, &mut active[i], &rows);
                ctx.sleep_throttle();
            }
            continue;
        };
        let mut tokens: Vec<i32> = Vec::new();
        let mut positions: Vec<usize> = Vec::new();
        for &i in g {
            let Some(rows) = rows_of[i].as_ref() else {
                // unreachable (host groups are built from planned members)
                // — but a lost member must not kill the worker; the
                // scatter below skips it the same way
                eprintln!("[scheduler] worker {}: host verify member lost its rows", ctx.id);
                continue;
            };
            tokens.extend_from_slice(&rows.tokens);
            positions.extend_from_slice(&rows.positions);
        }
        let sw = Stopwatch::start();
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| hv(&tokens, &positions)));
        let verify_s = sw.secs();
        let out = match caught {
            Ok(out) => out,
            Err(p) => {
                // a panicking verifier costs this cycle's members one
                // error response each, not the engine thread
                let msg = panic_text(p.as_ref());
                for (i, a) in active.iter_mut().enumerate() {
                    if !g.contains(&i) {
                        continue;
                    }
                    rows_of[i] = None;
                    complete(ctx, a, Some(format!("engine panic: {msg}")));
                    a.ended = Some(true);
                }
                continue;
            }
        };
        ctx.note_fused(tokens.len());
        // scatter rows back per member
        let vocab = out.logits.dims[1];
        let fdim = out.feats.dims[1];
        let share = verify_s / g.len() as f64;
        let mut off = 0usize;
        for (i, a) in active.iter_mut().enumerate() {
            if !g.contains(&i) {
                continue;
            }
            let n_i = rows_of[i].take().map_or(0, |r| r.len());
            let mut lj = Vec::with_capacity(n_i * vocab);
            let mut fj = Vec::with_capacity(n_i * fdim);
            for r in off..off + n_i {
                lj.extend_from_slice(out.logits.row(r));
                fj.extend_from_slice(out.feats.row(r));
            }
            off += n_i;
            let member_out = VerifyOut {
                logits: crate::runtime::TensorF { dims: vec![n_i, vocab], data: lj },
                feats: crate::runtime::TensorF { dims: vec![n_i, fdim], data: fj },
            };
            a.state.metrics.phases.verify_s += share;
            a.state.metrics.target_calls += 1;
            absorb_one(ctx, a, &member_out);
            ctx.sleep_throttle();
        }
    }

    // ---- phase 4: unbatchable sessions run their opaque solo step ----
    for i in 0..n {
        if !solo[i] {
            continue;
        }
        let a = &mut active[i];
        let cpu_sw = Stopwatch::start();
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| a.method.step(&mut a.state)));
        a.cpu_s += cpu_sw.secs();
        ctx.sleep_throttle();
        match caught {
            Err(p) => {
                fail_session(ctx, a, format!("engine panic: {}", panic_text(p.as_ref())));
                a.ended = Some(false);
            }
            Ok(Err(e)) => {
                fail_session(ctx, a, format!("{e:#}"));
                a.ended = Some(true);
            }
            Ok(Ok(_outcome)) => {
                if flush_delta(ctx, a) && a.state.done {
                    complete(ctx, a, None);
                    a.ended = Some(true);
                }
            }
        }
    }
}

/// Verify one session through its own solo executor, then absorb.
fn solo_verify_absorb(ctx: &WorkerCtx, a: &mut ActiveJob, rows: &VerifyRows) {
    let cpu_sw = Stopwatch::start();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        a.method.verify(&mut a.state, rows)
    }));
    a.cpu_s += cpu_sw.secs();
    match caught {
        Err(p) => {
            fail_session(ctx, a, format!("engine panic: {}", panic_text(p.as_ref())));
            a.ended = Some(false);
        }
        Ok(Err(e)) => {
            fail_session(ctx, a, format!("{e:#}"));
            a.ended = Some(true);
        }
        Ok(Ok(out)) => {
            ctx.note_solo();
            absorb_one(ctx, a, &out);
        }
    }
}

/// Absorb externally produced verify outputs into one session, with the
/// same completion/panic discipline as a solo step.
fn absorb_one(ctx: &WorkerCtx, a: &mut ActiveJob, out: &VerifyOut) {
    let cpu_sw = Stopwatch::start();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        a.method.absorb(&mut a.state, out)
    }));
    a.cpu_s += cpu_sw.secs();
    match caught {
        Err(p) => {
            fail_session(ctx, a, format!("engine panic: {}", panic_text(p.as_ref())));
            a.ended = Some(false);
        }
        Ok(Err(e)) => {
            fail_session(ctx, a, format!("{e:#}"));
            a.ended = Some(true);
        }
        Ok(Ok(_outcome)) => {
            if flush_delta(ctx, a) && a.state.done {
                complete(ctx, a, None);
                a.ended = Some(true);
            }
        }
    }
}

/// Redeliver the flight record of a live session (or mid-admission job)
/// that hit a chaos-injected fault.  Returns `false` if the record is
/// gone (already checked out — caller falls back to a normal completion).
fn redeliver_job(ctx: &WorkerCtx, id: u64) -> bool {
    match ctx.board.checkout(ctx.id, id) {
        Some(rec) => {
            redeliver(ctx, rec);
            true
        }
        None => false,
    }
}

/// Re-enqueue a checked-out flight record: jobs with no delivered deltas
/// requeue transparently, streamed jobs with delivered deltas replay
/// with the prefix suppressed.  Past `max_requeues` the client gets the
/// structured [`WORKER_LOST_MSG`] error instead ("Failure semantics").
/// Stats go through the quiet path — redelivery must make progress under
/// the very faults it recovers from.
fn redeliver(ctx: &WorkerCtx, rec: FlightRec) {
    let attempts = rec.attempts + 1;
    if attempts > ctx.max_requeues {
        ctx.with_stats_quiet(|s| s.jobs_err += 1);
        let r = err_result(&rec.job, 0.0, 0.0, WORKER_LOST_MSG, ctx.id);
        let _ = rec.rtx.send(JobEvent::Done(r));
        return;
    }
    let replay = rec.sent_tokens > 0;
    ctx.with_stats_quiet(|s| if replay { s.replays += 1 } else { s.requeues += 1 });
    let redo =
        Redo { attempts, skip_tokens: rec.sent_tokens, prefix_text: rec.sent_text.clone() };
    ctx.queue.push(Msg::Redo(rec.job, redo, rec.rtx));
}

/// Supervisor-side recovery after a worker death: every in-flight record
/// of the dead incarnation is redelivered onto the same queue, one at a
/// time, releasing each dead session's load-gauge unit (the queue push
/// inside [`redeliver`] re-counts surviving jobs as queued work).
fn recover_in_flight(ctx: &WorkerCtx) {
    while let Some(rec) = ctx.board.take_any(ctx.id) {
        ctx.queue.load.fetch_sub(1, Ordering::Relaxed);
        redeliver(ctx, rec);
    }
}

/// Finish a live session that returned an error: chaos-injected failures
/// are redelivered through the requeue/replay machinery (bounded by
/// `max_requeues`); genuine errors complete immediately, exactly as
/// before fault injection existed.
fn fail_session(ctx: &WorkerCtx, a: &mut ActiveJob, msg: String) {
    if failpoint::is_injected(&msg) && redeliver_job(ctx, a.job.id) {
        return;
    }
    complete(ctx, a, Some(msg));
}

/// Send any not-yet-delivered tokens as a stream delta.  On a replayed
/// session the regenerated stream is first suppressed up to, then
/// byte-verified against, the prefix the previous attempt delivered;
/// a mismatch completes the job with [`WORKER_LOST_MSG`] and returns
/// `false` (the session is already ended — callers must not complete it
/// again).
fn flush_delta(ctx: &WorkerCtx, a: &mut ActiveJob) -> bool {
    a.note_ttft();
    if !a.job.stream || a.state.tokens.len() <= a.sent {
        return true;
    }
    if a.sent < a.skip {
        if a.state.tokens.len() < a.skip {
            // still inside the already-delivered prefix: emit nothing
            return true;
        }
        let prefix = tokenizer::decode(&a.state.tokens[..a.skip]);
        if prefix != a.skip_text {
            // the replay diverged from what the client already saw —
            // deterministic methods cannot hit this, but a divergent one
            // must fail loudly rather than corrupt the stream
            complete(ctx, a, Some(WORKER_LOST_MSG.to_string()));
            a.ended = Some(true);
            return false;
        }
        a.sent = a.skip;
        if a.state.tokens.len() == a.sent {
            return true;
        }
    }
    let text = tokenizer::decode(&a.state.tokens[a.sent..]);
    a.sent = a.state.tokens.len();
    if !text.is_empty() {
        let _ = a.rtx.send(JobEvent::Delta { id: a.job.id, text, tokens: a.sent });
        // journal the delivery so a later redelivery suppresses it
        ctx.board.note_delta(ctx.id, a.job.id, a.sent, &text);
    }
    true
}

/// Finish a live session: record stats, send the terminal event.
fn complete(ctx: &WorkerCtx, a: &mut ActiveJob, error: Option<String>) {
    // clear any cancel marker that raced in after the last check
    ctx.take_cancel(a.job.id);
    a.note_ttft();
    let result = match error {
        Some(msg) => {
            let mut r = err_result(&a.job, a.queue_s, a.run_sw.secs(), &msg, ctx.id);
            r.aborted = a.aborted;
            r
        }
        None => JobResult {
            id: a.job.id,
            text: tokenizer::decode(&a.state.tokens),
            tokens: a.state.tokens.len(),
            tau: a.state.metrics.tau(),
            latency_s: a.run_sw.secs(),
            queue_s: a.queue_s,
            worker: ctx.id,
            stream: a.job.stream,
            error: None,
            aborted: None,
        },
    };
    ctx.with_stats(|w| {
        w.busy_s += a.cpu_s;
        a.cpu_s = 0.0;
        w.tokens += result.tokens as u64;
        w.queue_wait_ms_sum += a.queue_s * 1000.0;
        if let Some(t) = a.ttft_s {
            w.ttft_ms_sum += t * 1000.0;
            w.ttft_count += 1;
        }
        match &result.error {
            Some(_) => w.jobs_err += 1,
            None => {
                w.jobs_ok += 1;
                w.metrics.merge(&a.state.metrics);
            }
        }
    });
    // the at-most-once window: checkout immediately precedes the Done
    // send with no fault site in between, so a job can never be both
    // redelivered and completed ("Failure semantics")
    ctx.board.checkout(ctx.id, a.job.id);
    let _ = a.rtx.send(JobEvent::Done(result));
}

/// Fail a job that never became a live session.  `busy_s` is whatever
/// admission work (throttle, method build, start) was already spent.
fn reject(
    ctx: &WorkerCtx,
    job: &Job,
    queue_s: f64,
    latency_s: f64,
    busy_s: f64,
    msg: &str,
    rtx: &Sender<JobEvent>,
) {
    ctx.take_cancel(job.id);
    ctx.with_stats(|w| {
        w.jobs_err += 1;
        w.busy_s += busy_s;
        w.queue_wait_ms_sum += queue_s * 1000.0;
    });
    // see `complete`: checkout → send is the at-most-once window
    ctx.board.checkout(ctx.id, job.id);
    let _ = rtx.send(JobEvent::Done(err_result(job, queue_s, latency_s, msg, ctx.id)));
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

fn err_result(job: &Job, queue_s: f64, latency_s: f64, err: &str, worker: usize) -> JobResult {
    JobResult {
        id: job.id,
        text: String::new(),
        tokens: 0,
        tau: 0.0,
        latency_s,
        queue_s,
        worker,
        stream: job.stream,
        error: Some(err.to_string()),
        aborted: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64) -> Job {
        Job {
            id,
            method: "hass".into(),
            prompt: "hi".into(),
            max_new: 4,
            temperature: 0.0,
            seed: 0,
            stream: false,
            deadline_ms: None,
            priority: 0,
        }
    }

    fn mock_job(id: u64, max_new: usize, stream: bool) -> Job {
        Job {
            id,
            method: "mock".into(),
            prompt: "hi".into(),
            max_new,
            temperature: 0.0,
            seed: 1,
            stream,
            deadline_ms: None,
            priority: 0,
        }
    }

    /// Block until the job's terminal event arrives (skipping deltas).
    fn recv_done(rx: &Receiver<JobEvent>) -> JobResult {
        loop {
            match rx.recv().expect("scheduler dropped a job") {
                JobEvent::Done(r) => return r,
                JobEvent::Delta { .. } => {}
            }
        }
    }

    /// Nonexistent artifact dir: runtime init fails fast, so the pool's
    /// error path exercises the full dispatch machinery without weights.
    fn bad_dir() -> PathBuf {
        PathBuf::from("/nonexistent/hass-artifacts")
    }

    #[test]
    fn pool_serves_error_results_without_artifacts() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 2, 1);
        let rxs: Vec<_> = (0..8).map(|i| sched.submit(job(i), true).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = recv_done(&rx);
            assert_eq!(r.id, i as u64);
            assert!(r.worker < 2);
            let err = r.error.expect("no artifacts must surface an error result");
            assert!(err.contains("runtime init failed"), "unexpected error: {err}");
        }
        let stats = sched.stats();
        assert_eq!(stats.workers.len(), 2);
        assert_eq!(stats.jobs(), 8);
        assert_eq!(stats.jobs_err(), 8);
        assert_eq!(stats.jobs_ok(), 0);
        assert!(stats.tau().is_finite());
        sched.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 4, 1, 1);
        sched.shutdown();
        assert!(sched.submit(job(1), true).is_err());
        assert!(sched.submit(job(2), false).is_err());
        assert_eq!(sched.stats().queue_depth, 0);
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 32, 2, 1);
        let rxs: Vec<_> = (0..12).map(|i| sched.submit(job(i), true).unwrap()).collect();
        sched.shutdown();
        for rx in rxs {
            assert!(rx.recv().is_ok(), "job dropped during graceful shutdown");
        }
        let stats = sched.stats();
        assert_eq!(stats.jobs(), 12);
        assert_eq!(stats.queue_depth, 0);
    }

    #[test]
    fn pool_distributes_across_workers_under_load() {
        // inject the per-job delay directly (mutating the process env from
        // a parallel test races other threads reading it) so one worker
        // can't drain the queue alone
        let sched =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 2, 1, Some(20), true);
        let rxs: Vec<_> = (0..8).map(|i| sched.submit(job(i), true).unwrap()).collect();
        let served: std::collections::HashSet<usize> =
            rxs.into_iter().map(|rx| recv_done(&rx).worker).collect();
        assert_eq!(served.len(), 2, "both engine threads must serve jobs");
        let stats = sched.stats();
        assert!(stats.workers.iter().all(|w| w.jobs() > 0));
        // admission work (throttle + failed checkout) counts as busy
        assert!(stats.busy_s() > 0.0);
        sched.shutdown();
    }

    #[test]
    fn submit_to_collects_many_jobs_on_one_channel() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 2, 1);
        let (rtx, rrx) = std::sync::mpsc::channel();
        for i in 0..6 {
            sched.submit_to(job(i), true, rtx.clone()).unwrap();
        }
        drop(rtx);
        let mut ids: Vec<u64> =
            rrx.iter().filter_map(JobEvent::into_result).map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        sched.shutdown();
    }

    /// Runtime-free `mock` jobs succeed even where every real method
    /// errors at init — the serving path is testable without artifacts.
    #[test]
    fn mock_jobs_run_without_artifacts() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 8, 1, 1);
        let r = recv_done(&sched.submit(mock_job(1, 8, false), true).unwrap());
        assert!(r.error.is_none(), "mock job failed: {:?}", r.error);
        assert_eq!(r.tokens, 8);
        assert_eq!(r.text.len(), 8);
        let stats = sched.stats();
        assert_eq!(stats.jobs_ok(), 1);
        assert_eq!(stats.tokens(), 8);
        sched.shutdown();
    }

    /// Equivalence under the shadow sanitizer: with audits force-enabled
    /// on the submitting thread (lock-order tracing through submit /
    /// stats / cancel) the pool must behave identically and the audits
    /// must stay silent.  The `HASS_CHECK=1` CI matrix entry additionally
    /// enables the worker-side audits for the whole suite.
    #[test]
    fn audited_pool_is_equivalent_and_silent() {
        crate::kvcache::audit::force_enable_for_tests(true);
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 8, 2, 2);
        let rxs: Vec<_> =
            (0..6u64).map(|i| sched.submit(mock_job(i, 6, false), true).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = recv_done(&rx);
            assert_eq!(r.id, i as u64);
            assert!(r.error.is_none(), "audited mock job failed: {:?}", r.error);
            assert_eq!(r.tokens, 6);
        }
        sched.cancel(999); // unknown id: traced, then lazily cleared
        let stats = sched.stats();
        assert_eq!(stats.jobs_ok(), 6);
        sched.shutdown();
        crate::kvcache::audit::force_enable_for_tests(false);
    }

    /// THE continuous-batching acceptance test: one worker interleaving
    /// two sessions must finish a short job submitted *behind* a long one
    /// first (cycle-granular scheduling beats head-of-line blocking).
    #[test]
    fn short_job_overtakes_long_job_when_interleaving() {
        let sched =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 1, 2, Some(3), true);
        let (rtx, rrx) = std::sync::mpsc::channel();
        sched.submit_to(mock_job(1, 64, false), true, rtx.clone()).unwrap();
        sched.submit_to(mock_job(2, 4, false), true, rtx).unwrap();
        let first = recv_done(&rrx);
        assert_eq!(first.id, 2, "4-token job must return before the 64-token job");
        assert!(first.error.is_none());
        assert_eq!(first.tokens, 4);
        let second = recv_done(&rrx);
        assert_eq!(second.id, 1);
        assert!(second.error.is_none());
        assert_eq!(second.tokens, 64);
        sched.shutdown();
    }

    /// A cancelled job returns an error result and does not block the
    /// queue behind it.
    #[test]
    fn cancelled_job_errors_without_blocking_queue() {
        let sched =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 1, 1, Some(3), true);
        let rx1 = sched.submit(mock_job(1, 100_000, false), true).unwrap();
        sched.cancel(1);
        let rx2 = sched.submit(mock_job(2, 4, false), true).unwrap();
        let r1 = recv_done(&rx1);
        let err = r1.error.expect("cancelled job must error");
        assert!(err.contains("cancel"), "unexpected error: {err}");
        let r2 = recv_done(&rx2);
        assert!(r2.error.is_none(), "queue blocked behind cancelled job: {:?}", r2.error);
        assert_eq!(r2.tokens, 4);
        sched.shutdown();
    }

    #[test]
    fn deadline_exceeded_job_errors() {
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, Some(5), true);
        let mut j = mock_job(1, 100_000, false);
        j.deadline_ms = Some(20);
        let r = recv_done(&sched.submit(j, true).unwrap());
        let err = r.error.expect("deadline must abort the job");
        assert!(err.contains("deadline"), "unexpected error: {err}");
        sched.shutdown();
    }

    /// THE batched-verification acceptance test: one worker fusing 4
    /// co-active sessions must produce token-for-token the outputs (and
    /// acceptance metrics) of 4 sequential solo runs with the same seeds,
    /// while issuing at least 2x fewer verify executions.
    #[test]
    fn fused_verify_matches_sequential_solo_runs() {
        let jobs = |offset: u64| -> Vec<Job> {
            (0..4u64)
                .map(|i| {
                    let mut j = mock_job(offset + i, 24 + 7 * i as usize, false);
                    j.seed = 100 + i;
                    j
                })
                .collect()
        };
        // sequential baseline: one worker, one session at a time
        let solo = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 1, 1);
        let mut want = Vec::new();
        for j in jobs(1) {
            let r = recv_done(&solo.submit(j, true).unwrap());
            assert!(r.error.is_none(), "solo run failed: {:?}", r.error);
            want.push((r.text, r.tokens, r.tau));
        }
        let solo_stats = solo.stats();
        assert!(solo_stats.solo_calls() > 0, "sequential runs must verify solo");
        assert_eq!(solo_stats.fused_calls(), 0, "nothing to fuse at max_active 1");
        solo.shutdown();

        // fused: one worker interleaving all four (admission throttled so
        // every session is co-active before the first cycle)
        let fused =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 16, 1, 4, Some(2), true);
        let rxs: Vec<_> =
            jobs(1).into_iter().map(|j| fused.submit(j, true).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = recv_done(&rx);
            assert!(r.error.is_none(), "fused run failed: {:?}", r.error);
            let (text, tokens, tau) = &want[i];
            assert_eq!(&r.text, text, "job {i}: fused text diverged from solo");
            assert_eq!(r.tokens, *tokens, "job {i}: token count diverged");
            assert!((r.tau - tau).abs() < 1e-9, "job {i}: tau diverged ({} vs {tau})", r.tau);
        }
        let fused_stats = fused.stats();
        assert!(fused_stats.fused_calls() > 0, "fused path must be exercised");
        assert!(
            fused_stats.mean_fused_rows() > 5.0,
            "fused calls must carry multiple sessions' rows (mean {})",
            fused_stats.mean_fused_rows()
        );
        // the scaling lever: >= 2x fewer verify executions for the same jobs
        assert!(
            fused_stats.verify_calls() * 2 <= solo_stats.verify_calls(),
            "fused {} vs solo {} verify calls",
            fused_stats.verify_calls(),
            solo_stats.verify_calls()
        );
        fused.shutdown();
    }

    /// THE draft-batching acceptance test (tentpole): one worker fusing 4
    /// co-active mock sessions must produce token-for-token the outputs
    /// (and acceptance metrics) of 4 sequential solo runs with the same
    /// seeds, while issuing >= 2x fewer draft executions — each fused
    /// draft call carries one level of EVERY co-active session instead of
    /// `N·depth` solo calls per cycle.
    #[test]
    fn fused_draft_matches_sequential_solo_runs() {
        let jobs = || -> Vec<Job> {
            (0..4u64)
                .map(|i| {
                    let mut j = mock_job(1 + i, 20 + 5 * i as usize, false);
                    j.seed = 300 + i;
                    j
                })
                .collect()
        };
        // sequential baseline: every draft level runs solo inside plan
        let solo = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 1, 1);
        let mut want = Vec::new();
        for j in jobs() {
            let r = recv_done(&solo.submit(j, true).unwrap());
            assert!(r.error.is_none(), "solo run failed: {:?}", r.error);
            want.push((r.text, r.tokens, r.tau));
        }
        let solo_stats = solo.stats();
        assert!(solo_stats.draft_solo_calls() > 0, "sequential runs must draft solo");
        assert_eq!(solo_stats.draft_fused_calls(), 0, "nothing to fuse at max_active 1");
        solo.shutdown();

        // fused: one worker interleaving all four (admission throttled so
        // every session is co-active before the first cycle)
        let fused =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 16, 1, 4, Some(2), true);
        let rxs: Vec<_> = jobs().into_iter().map(|j| fused.submit(j, true).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = recv_done(&rx);
            assert!(r.error.is_none(), "fused run failed: {:?}", r.error);
            let (text, tokens, tau) = &want[i];
            assert_eq!(&r.text, text, "job {i}: fused-draft text diverged from solo");
            assert_eq!(r.tokens, *tokens, "job {i}: token count diverged");
            assert!((r.tau - tau).abs() < 1e-9, "job {i}: tau diverged ({} vs {tau})", r.tau);
        }
        let fused_stats = fused.stats();
        assert!(fused_stats.draft_fused_calls() > 0, "fused draft path must be exercised");
        assert!(
            fused_stats.mean_draft_fused_rows() > 1.5,
            "fused draft calls must carry multiple sessions' rows (mean {})",
            fused_stats.mean_draft_fused_rows()
        );
        // the scaling lever: >= 2x fewer draft executions for the same jobs
        assert!(
            fused_stats.draft_execs() * 2 <= solo_stats.draft_execs(),
            "fused {} vs solo {} draft executions",
            fused_stats.draft_execs(),
            solo_stats.draft_execs()
        );
        fused.shutdown();
    }

    fn cand(wptr: usize, pages: Vec<u64>, rows: usize) -> Option<FuseCand> {
        Some(FuseCand { wptr, slots: 128, page_size: 8, pages, rows })
    }

    /// Call `plan_fuse_groups` over owned candidates (it takes borrows,
    /// matching the probe loop's zero-copy path).
    fn groups_of(cands: &[Option<FuseCand>]) -> Vec<Vec<usize>> {
        let refs: Vec<Option<&FuseCand>> = cands.iter().map(|c| c.as_ref()).collect();
        plan_fuse_groups(&refs)
    }

    /// Page-granular grouping: distinct-page fleets still respect the
    /// slot budget, row counts respect the widest artifact, and weights
    /// identity splits groups.
    #[test]
    fn fuse_groups_respect_page_capacity_and_rows() {
        // 3 members, disjoint 4-page prefixes (32 slots each at page 8):
        // 2 fit (8 pages * 8 + block), a 3rd overflows 128 slots
        let cands = vec![
            cand(1, vec![1, 2, 3, 4], 30),
            cand(1, vec![5, 6, 7, 8], 30),
            cand(1, vec![9, 10, 11, 12], 30),
        ];
        let groups = groups_of(&cands);
        assert_eq!(groups, vec![vec![0, 1], vec![2]]);
        // row overflow splits even when pages fit
        let cands = vec![cand(1, vec![1], 100), cand(1, vec![2], 100)];
        assert_eq!(groups_of(&cands), vec![vec![0], vec![1]]);
        // different checkpoints never fuse
        let cands = vec![cand(1, vec![1], 4), cand(2, vec![2], 4)];
        assert_eq!(groups_of(&cands), vec![vec![0], vec![1]]);
        // non-candidates are skipped without breaking a group
        let cands = vec![cand(1, vec![1], 4), None, cand(1, vec![2], 4)];
        assert_eq!(groups_of(&cands), vec![vec![0, 2]]);
        // intra-member duplicate ids occupy one segment EACH (mirroring
        // PackedLayout::plan's forced distinct segments): 7 + 9 segments
        // at page 8 overflow 128 slots even though only 8 ids are distinct
        let cands = vec![
            cand(1, (1..=7).collect(), 4),
            cand(1, vec![9; 9], 4),
        ];
        assert_eq!(groups_of(&cands), vec![vec![0], vec![1]]);
    }

    /// The draft grouping reuses the capacity machinery over the draft
    /// width ladder: rows respect the widest draft artifact instead of
    /// the target's, padded by the smallest fitting draft width.
    #[test]
    fn fuse_groups_by_respects_draft_width_ladder() {
        let widths = [10usize, 40];
        let pick = |r: usize| pick_width(&widths, r).unwrap_or(40);
        // 5 + 5 rows pad to 10 -> one group; a third member of 35 rows
        // would blow the 40-row ladder and splits
        let cands = vec![cand(1, vec![1], 5), cand(1, vec![2], 5), cand(1, vec![3], 35)];
        let refs: Vec<Option<&FuseCand>> = cands.iter().map(|c| c.as_ref()).collect();
        assert_eq!(plan_fuse_groups_by(&refs, 40, pick), vec![vec![0, 1], vec![2]]);
        // the same members under the target ladder would all fuse
        assert_eq!(plan_fuse_groups(&refs), vec![vec![0, 1, 2]]);
        // page capacity still binds: two 8-page members at page 8 leave
        // no room for a 40-wide block in 128 slots
        let cands = vec![
            cand(1, (1..=8).collect(), 20),
            cand(1, (11..=18).collect(), 20),
        ];
        let refs: Vec<Option<&FuseCand>> = cands.iter().map(|c| c.as_ref()).collect();
        assert_eq!(plan_fuse_groups_by(&refs, 40, pick), vec![vec![0], vec![1]]);
    }

    /// THE lifted-ceiling test: a shared-prefix fleet whose summed
    /// prefixes blow the old `Σ prefixes + block <= slots` bound still
    /// forms ONE fused group, because its shared pages count once.
    #[test]
    fn fuse_groups_share_prompt_pages_past_old_ceiling() {
        // 7 members, each 20 committed slots over the SAME 3 pages:
        // old bound 7*20 + 8 = 148 > 128; new bound 3*8 + 8 = 32
        let cands: Vec<Option<FuseCand>> = (0..7).map(|_| cand(7, vec![1, 2, 3], 1)).collect();
        let groups = groups_of(&cands);
        assert_eq!(groups.len(), 1, "shared-prefix fleet must fuse into one group: {groups:?}");
        assert_eq!(groups[0], (0..7).collect::<Vec<usize>>());
        // sanity: the same fleet with disjoint pages cannot all fuse
        let cands: Vec<Option<FuseCand>> = (0..7)
            .map(|j| cand(7, vec![10 * j as u64, 10 * j as u64 + 1, 10 * j as u64 + 2], 1))
            .collect();
        let groups = groups_of(&cands);
        assert!(groups.len() > 1, "disjoint prefixes must still hit the slot budget");
    }

    /// Least-loaded dispatch (affinity off): with every worker idle,
    /// consecutive submits spread round-robin-ish instead of piling onto
    /// worker 0.  (With affinity on, same-prompt jobs deliberately pile
    /// onto one worker — the test below.)
    #[test]
    fn least_loaded_dispatch_spreads_queued_jobs() {
        // throttled so queued jobs stay queued while we submit
        let sched =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 64, 3, 1, Some(10), false);
        let rxs: Vec<_> =
            (0..9).map(|i| sched.submit(mock_job(i, 4, false), true).unwrap()).collect();
        let mut served = std::collections::HashMap::new();
        for rx in rxs {
            let r = recv_done(&rx);
            assert!(r.error.is_none());
            *served.entry(r.worker).or_insert(0usize) += 1;
        }
        assert_eq!(served.len(), 3, "all three workers must serve: {served:?}");
        // round-robin-ish: allow a couple of racy misroutes, but nothing
        // resembling a pile-up on one worker
        assert!(
            served.values().all(|&c| (1..=5).contains(&c)),
            "least-loaded dispatch must spread 9 jobs over 3 workers: {served:?}"
        );
        sched.shutdown();
    }

    /// Prefix-affinity dispatch: same-prompt jobs land on ONE worker
    /// (whose staging caches hold their pages hot) while the load stays
    /// within the imbalance budget, and the hit/miss counters say so.
    #[test]
    fn prefix_affinity_routes_same_prompt_jobs_together() {
        // throttled so the affinity worker's load (4 < 1 + imbalance 4)
        // never trips the escape hatch while we submit
        let sched =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 64, 3, 1, Some(10), true);
        let rxs: Vec<_> =
            (0..4).map(|i| sched.submit(mock_job(i, 4, false), true).unwrap()).collect();
        let served: std::collections::HashSet<usize> =
            rxs.into_iter().map(|rx| recv_done(&rx).worker).collect();
        assert_eq!(served.len(), 1, "same-prefix jobs must share a worker: {served:?}");
        let stats = sched.stats();
        assert_eq!(stats.affinity_misses(), 1, "first sighting of the prefix is the miss");
        assert_eq!(stats.affinity_hits(), 3, "every later submit must hit the mapping");
        sched.shutdown();
    }

    /// The escape hatch: a hot prefix whose worker runs more than
    /// AFFINITY_MAX_IMBALANCE load units ahead of the least-loaded one
    /// is remapped there instead of starving the pool.
    #[test]
    fn affinity_escape_hatch_rebalances_hot_prefix() {
        let sched =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 64, 2, 1, Some(15), true);
        let rxs: Vec<_> =
            (0..8).map(|i| sched.submit(mock_job(i, 4, false), true).unwrap()).collect();
        let served: std::collections::HashSet<usize> =
            rxs.into_iter().map(|rx| recv_done(&rx).worker).collect();
        assert_eq!(
            served.len(),
            2,
            "8 same-prefix jobs must overflow one worker's imbalance budget: {served:?}"
        );
        let stats = sched.stats();
        // initial sighting + at least one escape-hatch remap
        assert!(stats.affinity_misses() >= 2, "stats: {:?}", stats.affinity_misses());
        assert!(stats.affinity_hits() >= 1);
        sched.shutdown();
    }

    /// Cross-worker COW isolation over the pool-wide page pool: a
    /// 2-worker fleet serving the SAME prompt with different seeds must
    /// produce exactly the outputs of sequential solo runs — sessions
    /// diverging after a shared prefix never leak writes across workers.
    /// Audits are force-enabled on the submitting thread; the
    /// `shared-pool` CI matrix entry re-runs this whole suite with
    /// `HASS_CHECK=1`, which also audits every worker thread.
    #[test]
    fn two_worker_shared_prompt_fleet_matches_solo_runs() {
        crate::kvcache::audit::force_enable_for_tests(true);
        let jobs = || -> Vec<Job> {
            (0..6u64)
                .map(|i| {
                    let mut j = mock_job(1 + i, 16, false);
                    j.seed = 700 + i; // same prompt, divergent continuations
                    j
                })
                .collect()
        };
        // sequential baseline: one worker, one session at a time
        let solo = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 1, 1);
        let mut want = Vec::new();
        for j in jobs() {
            let r = recv_done(&solo.submit(j, true).unwrap());
            assert!(r.error.is_none(), "solo run failed: {:?}", r.error);
            want.push((r.text, r.tokens, r.tau));
        }
        solo.shutdown();

        // fleet: 2 workers, affinity OFF so the fleet actually spreads
        // over both workers (affinity would co-locate the shared prefix);
        // throttled so all six submits land before any job completes
        let fleet =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 16, 2, 3, Some(5), false);
        let rxs: Vec<_> = jobs().into_iter().map(|j| fleet.submit(j, true).unwrap()).collect();
        let mut served = std::collections::HashSet::new();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = recv_done(&rx);
            assert!(r.error.is_none(), "fleet run failed: {:?}", r.error);
            served.insert(r.worker);
            let (text, tokens, tau) = &want[i];
            assert_eq!(&r.text, text, "job {i}: fleet text diverged from solo");
            assert_eq!(r.tokens, *tokens, "job {i}: token count diverged");
            assert!((r.tau - tau).abs() < 1e-9, "job {i}: tau diverged");
        }
        assert_eq!(served.len(), 2, "fleet must actually spread over both workers");
        // divergent seeds must actually diverge — otherwise the leak
        // assertion above would be vacuous
        assert!(want.iter().map(|(t, _, _)| t).collect::<HashSet<_>>().len() > 1);
        fleet.shutdown();
        crate::kvcache::audit::force_enable_for_tests(false);
    }

    /// Streamed deltas concatenate to exactly the non-streamed text for a
    /// fixed seed, with at least two delta events before the terminal one.
    #[test]
    fn streamed_deltas_concatenate_to_final_text() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 8, 1, 2);
        let mut j = mock_job(7, 12, true);
        j.seed = 42;
        let rx = sched.submit(j, true).unwrap();
        let mut concat = String::new();
        let mut n_deltas = 0usize;
        let fin = loop {
            match rx.recv().unwrap() {
                JobEvent::Delta { id, text, tokens } => {
                    assert_eq!(id, 7);
                    concat.push_str(&text);
                    assert_eq!(tokens, concat.len(), "delta token counter out of sync");
                    n_deltas += 1;
                }
                JobEvent::Done(r) => break r,
            }
        };
        assert!(n_deltas >= 2, "want >= 2 deltas, got {n_deltas}");
        assert!(fin.error.is_none());
        assert!(fin.stream);
        assert_eq!(concat, fin.text, "deltas must concatenate to the final text");
        // same seed, non-streamed: identical text
        let mut j2 = mock_job(8, 12, false);
        j2.seed = 42;
        let r2 = recv_done(&sched.submit(j2, true).unwrap());
        assert_eq!(r2.text, fin.text);
        sched.shutdown();
    }

    // ---- overload policy (admission, preemption, breakers) ----
    //
    // Every test here is named `overload_*` so the `overload` CI matrix
    // entry can run exactly this family (plus the kvcache/server/
    // integration `overload_*` tests) under HASS_CHECK=1 with a tiny
    // page size and a real HASS_PAGE_BUDGET.  None of them read env
    // knobs themselves — pools come from `start_inner_policy` — except
    // the explicitly env-gated one at the end.

    /// Poll `cond` until it holds, failing the test after ~5 s.
    fn wait_for(desc: &str, mut cond: impl FnMut() -> bool) {
        let sw = std::time::Instant::now();
        while !cond() {
            assert!(
                sw.elapsed() < std::time::Duration::from_secs(5),
                "timed out waiting for {desc}"
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    /// A pool whose lone worker is wedged and whose spill channel is
    /// full must shed a blocking submission after `spill_timeout_ms`
    /// with a parseable overload rejection instead of hanging the caller
    /// on the bounded channel forever (regression: the spill path used
    /// to block without any bound on the wait).
    #[test]
    fn overload_spill_timeout_sheds_instead_of_hanging() {
        let policy =
            OverloadPolicy { spill_timeout_ms: 50, retry_after_ms: 75, ..OverloadPolicy::default() };
        let sched = Scheduler::start_inner_policy(
            bad_dir(),
            MethodCfg::default(),
            1,
            1,
            1,
            Some(300),
            true,
            policy,
        );
        // job 1 wedges the worker in its admission throttle...
        let rx1 = sched.submit(job(1), true).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(30));
        // ...job 2 takes the freed backlog slot, job 3 fills the 1-slot
        // spill channel, so job 4 has nowhere to go but the timeout
        let rx2 = sched.submit(job(2), true).unwrap();
        let rx3 = sched.submit(job(3), true).unwrap();
        let sw = std::time::Instant::now();
        let err = sched.submit(job(4), true).expect_err("4th submit must shed");
        let waited = sw.elapsed();
        let o = Overloaded::parse(&format!("{err:#}")).expect("shed must parse as overloaded");
        assert_eq!(o.retry_after_ms, 75);
        assert!(waited < std::time::Duration::from_secs(2), "shed took {waited:?}");
        assert!(sched.stats().admission_rejects >= 1);
        // the shed didn't corrupt the queue: every accepted job drains
        for rx in [rx1, rx2, rx3] {
            assert!(recv_done(&rx).error.is_some());
        }
        assert_eq!(sched.stats().queue_depth, 0);
        sched.shutdown();
    }

    /// Admission control over an injected page gauge: past the
    /// high-water mark NEW submissions shed with the policy's retry hint
    /// and the stats snapshot shows the exhausted budget; once pressure
    /// clears the same traffic is admitted and served.
    #[test]
    fn overload_admission_gate_rejects_then_admits() {
        let gauge = Arc::new(AtomicU64::new(100));
        let policy = OverloadPolicy {
            page_budget: Some(100),
            retry_after_ms: 30,
            gauge: Some(gauge.clone()),
            ..OverloadPolicy::default()
        };
        let sched = Scheduler::start_inner_policy(
            bad_dir(),
            MethodCfg::default(),
            8,
            1,
            1,
            None,
            true,
            policy,
        );
        // 100 live > 0.9 * 100: shed at the submission boundary
        let err = sched.submit(mock_job(1, 4, false), true).expect_err("past hwm must shed");
        let o = Overloaded::parse(&format!("{err:#}")).expect("parseable overload rejection");
        assert_eq!(o.retry_after_ms, 30);
        let stats = sched.stats();
        assert_eq!(stats.admission_rejects, 1);
        assert_eq!((stats.live_pages, stats.page_budget, stats.free_pages), (100, 100, 0));
        // pressure clears: the same job shape is admitted and served
        gauge.store(0, Ordering::Relaxed);
        let r = recv_done(&sched.submit(mock_job(2, 4, false), true).unwrap());
        assert!(r.error.is_none(), "post-recovery submit failed: {:?}", r.error);
        assert_eq!(sched.stats().free_pages, 100);
        sched.shutdown();
    }

    /// Tentpole invariant: a session parked mid-generation under page
    /// pressure and resumed once pages free produces byte-identical
    /// output to an uninterrupted solo run — parking drops rebuildable
    /// state only.  Audits are force-enabled; the `overload` CI matrix
    /// entry re-runs this under `HASS_CHECK=1` with a tiny page size.
    #[test]
    fn overload_preempted_session_matches_solo_run() {
        crate::kvcache::audit::force_enable_for_tests(true);
        let victim = || {
            let mut j = mock_job(2, 1200, true);
            j.seed = 91;
            j
        };
        // uninterrupted baseline for the victim's exact job shape
        // (streaming does not change generation, only delivery)
        let solo = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, None, true);
        let mut j = victim();
        j.stream = false;
        let want = recv_done(&solo.submit(j, true).unwrap());
        assert!(want.error.is_none(), "solo run failed: {:?}", want.error);
        solo.shutdown();

        let gauge = Arc::new(AtomicU64::new(0));
        let policy = OverloadPolicy {
            page_budget: Some(10),
            gauge: Some(gauge.clone()),
            ..OverloadPolicy::default()
        };
        let sched = Scheduler::start_inner_policy(
            bad_dir(),
            MethodCfg::default(),
            8,
            1,
            2,
            None,
            true,
            policy,
        );
        // the shield (priority 1) survives preemption; the victim
        // streams so pressure lands only once it provably holds tokens
        let mut shield = mock_job(1, 2000, false);
        shield.priority = 1;
        shield.seed = 90;
        let rx_a = sched.submit(shield, true).unwrap();
        let rx_b = sched.submit(victim(), true).unwrap();
        let first = rx_b.recv().expect("victim produced no event");
        assert!(matches!(first, JobEvent::Delta { .. }), "victim must stream before pressure");
        gauge.store(1000, Ordering::Relaxed);
        wait_for("victim to park", || sched.stats().preemptions() >= 1);
        gauge.store(0, Ordering::Relaxed);
        let r = recv_done(&rx_b);
        assert!(r.error.is_none(), "resumed victim failed: {:?}", r.error);
        assert_eq!(r.text, want.text, "resumed output diverged from the solo run");
        assert_eq!(r.tokens, want.tokens);
        assert!(sched.stats().resumes() >= 1, "victim never resumed");
        assert!(recv_done(&rx_a).error.is_none());
        sched.shutdown();
        crate::kvcache::audit::force_enable_for_tests(false);
    }

    /// A parked session must stay responsive to cancellation: the
    /// cancel marker completes it with the standard "cancelled" error
    /// while the page gauge still pins it parked.
    #[test]
    fn overload_cancel_while_parked() {
        let gauge = Arc::new(AtomicU64::new(1000));
        let policy = OverloadPolicy {
            page_budget: Some(10),
            // admission must pass (the gauge models pages held elsewhere
            // in the pool); preemption still parks past 10 live pages
            admission_hwm: 1e6,
            gauge: Some(gauge.clone()),
            ..OverloadPolicy::default()
        };
        let sched = Scheduler::start_inner_policy(
            bad_dir(),
            MethodCfg::default(),
            8,
            1,
            2,
            None,
            true,
            policy,
        );
        let mut shield = mock_job(1, 2000, false);
        shield.priority = 1;
        let rx_a = sched.submit(shield, true).unwrap();
        let rx_b = sched.submit(mock_job(2, 50, false), true).unwrap();
        wait_for("victim to park", || sched.stats().preemptions() >= 1);
        sched.cancel(2);
        let r = recv_done(&rx_b);
        let err = r.error.expect("cancelled parked session must error");
        assert!(err.contains("cancelled"), "unexpected error: {err}");
        assert!(recv_done(&rx_a).error.is_none());
        sched.shutdown();
    }

    /// A parked session's client deadline keeps ticking: the sweep
    /// completes it with the deadline error while it waits for pages.
    #[test]
    fn overload_deadline_while_parked() {
        let gauge = Arc::new(AtomicU64::new(1000));
        let policy = OverloadPolicy {
            page_budget: Some(10),
            admission_hwm: 1e6,
            gauge: Some(gauge.clone()),
            ..OverloadPolicy::default()
        };
        let sched = Scheduler::start_inner_policy(
            bad_dir(),
            MethodCfg::default(),
            8,
            1,
            2,
            None,
            true,
            policy,
        );
        let mut shield = mock_job(1, 2000, false);
        shield.priority = 1;
        let rx_a = sched.submit(shield, true).unwrap();
        let mut b = mock_job(2, 50, false);
        b.deadline_ms = Some(80);
        let rx_b = sched.submit(b, true).unwrap();
        wait_for("victim to park", || sched.stats().preemptions() >= 1);
        let r = recv_done(&rx_b);
        let err = r.error.expect("expired parked session must error");
        assert!(err.contains("deadline_ms exceeded"), "unexpected error: {err}");
        assert!(recv_done(&rx_a).error.is_none());
        sched.shutdown();
    }

    /// The cycle fence aborts a runaway session with the distinct
    /// breaker status (`aborted: "breaker"`, counted on the stats wire)
    /// while a short job on the same pool completes untouched.
    #[test]
    fn overload_breaker_trips_on_max_cycles() {
        let policy = OverloadPolicy { breaker_max_cycles: Some(4), ..OverloadPolicy::default() };
        let sched = Scheduler::start_inner_policy(
            bad_dir(),
            MethodCfg::default(),
            8,
            1,
            1,
            None,
            true,
            policy,
        );
        // a short job stays under the fence (<= 3 cycles even if every
        // cycle accepts just one token)
        let ok = recv_done(&sched.submit(mock_job(1, 4, false), true).unwrap());
        assert!(ok.error.is_none(), "short job tripped the breaker: {:?}", ok.error);
        assert_eq!(ok.aborted, None);
        // a runaway (hundreds of cycles) is fenced
        let r = recv_done(&sched.submit(mock_job(2, 5000, false), true).unwrap());
        let err = r.error.expect("runaway must be aborted");
        assert!(err.contains("breaker: session exceeded 4 cycles"), "unexpected error: {err}");
        assert_eq!(r.aborted, Some("breaker"));
        let stats = sched.stats();
        assert_eq!(stats.breaker_trips(), 1);
        assert_eq!(stats.jobs_err(), 1);
        sched.shutdown();
    }

    /// The wall-clock fence: a 0 ms allowance trips on the first cycle,
    /// pinning the fence's plumbing (status string, aborted marker,
    /// counter) without any timing dependence.
    #[test]
    fn overload_breaker_trips_on_max_ms() {
        let policy = OverloadPolicy { breaker_max_ms: Some(0), ..OverloadPolicy::default() };
        let sched = Scheduler::start_inner_policy(
            bad_dir(),
            MethodCfg::default(),
            8,
            1,
            1,
            None,
            true,
            policy,
        );
        let r = recv_done(&sched.submit(mock_job(1, 64, false), true).unwrap());
        let err = r.error.expect("0 ms fence must abort");
        assert!(err.contains("breaker: session ran past 0 ms"), "unexpected error: {err}");
        assert_eq!(r.aborted, Some("breaker"));
        assert_eq!(sched.stats().breaker_trips(), 1);
        sched.shutdown();
    }

    /// Env-configured admission control end to end over REAL page
    /// pressure (`Scheduler::start` reads `HASS_PAGE_BUDGET`): runs only
    /// under the `overload` CI matrix entry, which sets the knob —
    /// unset, the test is a no-op so the default suite stays
    /// env-independent.
    #[test]
    fn overload_env_page_budget_sheds_then_recovers() {
        let Some(budget) =
            std::env::var("HASS_PAGE_BUDGET").ok().and_then(|v| v.parse::<u64>().ok())
        else {
            return;
        };
        // hold real pages until the pool-wide gauge is past the budget
        // (lazily allocated zero pages skip prefill dedup, so each one
        // counts toward the gauge)
        let mut ballast: Vec<crate::kvcache::KvCache> = Vec::new();
        while crate::kvcache::live_pages() <= budget && ballast.len() < 256 {
            let mut c = crate::kvcache::KvCache::with_page_size(1, 8, 2, 4, 1);
            c.page_ids_covering(8);
            ballast.push(c);
        }
        assert!(crate::kvcache::live_pages() > budget, "could not exceed the page budget");
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 8, 1, 1);
        let err = sched.submit(mock_job(1, 4, false), true).expect_err("past budget must shed");
        assert!(Overloaded::parse(&format!("{err:#}")).is_some(), "unparseable: {err:#}");
        assert_eq!(sched.stats().page_budget, budget);
        assert!(sched.stats().admission_rejects >= 1);
        drop(ballast);
        // other tests' transient pages may keep the gauge briefly
        // elevated: retry like a client would until the pool admits
        let sw = std::time::Instant::now();
        let r = loop {
            match sched.submit(mock_job(2, 4, false), true) {
                Ok(rx) => break recv_done(&rx),
                Err(e) => {
                    assert!(
                        Overloaded::parse(&format!("{e:#}")).is_some(),
                        "non-overload error: {e:#}"
                    );
                    assert!(
                        sw.elapsed() < std::time::Duration::from_secs(10),
                        "pool never recovered after the ballast dropped"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
            }
        };
        assert!(r.error.is_none(), "post-recovery job failed: {:?}", r.error);
        sched.shutdown();
    }

    // ---- robustness (fault injection + worker supervision) ----
    //
    // Every test here is named `chaos_*` so the `chaos` CI matrix entry
    // can run exactly this family (plus the `failpoint_*` unit suite)
    // under HASS_CHECK=1.  Faults are installed programmatically and
    // scoped to the pool's own thread tag (`fault_scope`) or to the
    // submitting test thread, so parallel tests never see each other's
    // chaos.

    fn fault(
        point: failpoint::Point,
        action: failpoint::Action,
        rate: f64,
    ) -> failpoint::FaultSpec {
        failpoint::FaultSpec { point, action, rate }
    }

    /// Satellite regression: a client whose worker dies on every cycle
    /// must receive the structured retryable `worker_lost` error once
    /// the redelivery budget runs out — never block until its deadline.
    /// (The old spawn wrapper only logged the death and left the
    /// session's event channel open forever.)
    #[test]
    fn chaos_dead_worker_fails_sessions_instead_of_hanging() {
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, None, true);
        let _g = failpoint::install(
            Some(sched.fault_scope()),
            vec![fault(failpoint::WORKER_TICK, failpoint::Action::Panic, 1.0)],
            7,
        );
        // needs several cycles, so at panic rate 1.0 no single worker
        // incarnation can ever finish it
        let rx = sched.submit(mock_job(1, 64, false), true).unwrap();
        let sw = std::time::Instant::now();
        let r = recv_done(&rx);
        let err = r.error.expect("job served by a dying worker must error");
        assert!(is_worker_lost(&err), "unexpected error: {err}");
        // structured failure lands well under any realistic deadline
        // (budget x respawn backoff, not a hang)
        assert!(sw.elapsed() < std::time::Duration::from_secs(4), "took {:?}", sw.elapsed());
        let stats = sched.stats();
        assert!(stats.worker_deaths() >= 1, "supervisor never counted the deaths");
        assert!(stats.requeues() >= 1, "the job was never redelivered");
        sched.shutdown();
    }

    /// Tentpole acceptance: a job interrupted by worker death is
    /// transparently requeued and completes token-identical to a
    /// fault-free run — exactly once, no duplicate terminal events.
    #[test]
    fn chaos_requeued_job_matches_fault_free_run() {
        let solo = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, None, true);
        let want = recv_done(&solo.submit(mock_job(1, 24, false), true).unwrap());
        assert!(want.error.is_none(), "baseline failed: {:?}", want.error);
        solo.shutdown();

        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, None, true);
        let g = failpoint::install(
            Some(sched.fault_scope()),
            vec![fault(failpoint::WORKER_TICK, failpoint::Action::Panic, 1.0)],
            11,
        );
        let rx = sched.submit(mock_job(1, 24, false), true).unwrap();
        wait_for("a requeue after worker death", || sched.stats().requeues() >= 1);
        drop(g); // chaos off: the next incarnation finishes the job
        let r = recv_done(&rx);
        assert!(r.error.is_none(), "requeued job failed: {:?}", r.error);
        assert_eq!(r.text, want.text, "requeued output diverged from the fault-free run");
        assert_eq!(r.tokens, want.tokens);
        // exactly once: no second terminal event ever lands
        assert!(rx.try_recv().is_err(), "duplicate event after completion");
        let stats = sched.stats();
        assert!(stats.worker_deaths() >= 1);
        assert!(stats.mean_recovery_ms() >= 0.0);
        sched.shutdown();
    }

    /// Tentpole acceptance, streamed: a job with deltas already
    /// delivered is replayed from its seeded request with the emitted
    /// prefix suppressed — the client sees every token exactly once and
    /// the final text matches a fault-free run byte for byte.
    #[test]
    fn chaos_streamed_replay_suppresses_prefix() {
        let solo = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, None, true);
        let want = recv_done(&solo.submit(mock_job(1, 24, false), true).unwrap());
        assert!(want.error.is_none(), "baseline failed: {:?}", want.error);
        solo.shutdown();

        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, None, true);
        let g = failpoint::install(
            Some(sched.fault_scope()),
            vec![fault(failpoint::WORKER_TICK, failpoint::Action::Panic, 1.0)],
            13,
        );
        let rx = sched.submit(mock_job(1, 24, true), true).unwrap();
        // the mock method emits a delta in its very first admission, so
        // the first death always takes the replay (not requeue) path
        wait_for("a streamed replay after worker death", || sched.stats().replays() >= 1);
        drop(g);
        let mut concat = String::new();
        let fin = loop {
            match rx.recv().expect("scheduler dropped the streamed job") {
                JobEvent::Delta { text, .. } => concat.push_str(&text),
                JobEvent::Done(r) => break r,
            }
        };
        assert!(fin.error.is_none(), "replayed job failed: {:?}", fin.error);
        assert_eq!(fin.text, want.text, "replayed output diverged from the fault-free run");
        assert_eq!(concat, fin.text, "deltas must concatenate to the text exactly once");
        assert!(sched.stats().replays() >= 1);
        sched.shutdown();
    }

    /// Chaos equivalence: a mixed streamed/plain batch under a low-rate
    /// worker panic completes every job exactly once, token-identical
    /// to a fault-free pool — supervision is invisible to clients apart
    /// from latency.  The `chaos` CI entry re-runs this under
    /// HASS_CHECK=1 so the lock-order and kv audits cover the recovery
    /// machinery too.
    #[test]
    fn chaos_pool_under_faults_matches_fault_free_pool() {
        let jobs: Vec<Job> = (0..10u64)
            .map(|i| {
                let mut j = mock_job(i, 12 + (i as usize % 3) * 6, i % 2 == 0);
                j.seed = 100 + i;
                j
            })
            .collect();
        let baseline =
            Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 1, 2, None, true);
        let mut want: Vec<JobResult> = jobs
            .iter()
            .map(|j| {
                let mut j = j.clone();
                j.stream = false; // streaming changes delivery, not text
                recv_done(&baseline.submit(j, true).unwrap())
            })
            .collect();
        baseline.shutdown();
        want.sort_by_key(|r| r.id);

        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 1, 2, None, true);
        let _g = failpoint::install(
            Some(sched.fault_scope()),
            vec![fault(failpoint::WORKER_TICK, failpoint::Action::Panic, 0.05)],
            5,
        );
        let (rtx, rrx) = std::sync::mpsc::channel();
        for j in &jobs {
            sched.submit_to(j.clone(), true, rtx.clone()).unwrap();
        }
        drop(rtx);
        let mut got: Vec<JobResult> = rrx.iter().filter_map(JobEvent::into_result).collect();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), jobs.len(), "lost or duplicated responses under chaos");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert!(g.error.is_none(), "job {} failed under chaos: {:?}", g.id, g.error);
            assert_eq!(g.text, w.text, "job {} output diverged under chaos", g.id);
            assert_eq!(g.tokens, w.tokens);
        }
        sched.shutdown();
    }

    /// Satellite: a panic while the per-worker stats lock is held
    /// poisons it; every consumer recovers via `into_inner`, so stats
    /// snapshots keep answering and fresh submissions serve normally
    /// once the fault is lifted.
    #[test]
    fn chaos_poisoned_stats_lock_recovers() {
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, None, true);
        let g = failpoint::install(
            Some(sched.fault_scope()),
            vec![fault(failpoint::STATS_UPDATE, failpoint::Action::Panic, 1.0)],
            17,
        );
        // every stats update panics the worker mid-job: the session is
        // redelivered until the budget expires, then fails structured
        let r = recv_done(&sched.submit(mock_job(1, 8, false), true).unwrap());
        let err = r.error.expect("job under a stats-lock panic must error");
        assert!(is_worker_lost(&err), "unexpected error: {err}");
        // the poisoned lock still serves snapshots (supervision counters
        // were updated through the quiet/into_inner path)...
        let stats = sched.stats();
        assert!(stats.worker_deaths() >= 1);
        drop(g);
        // ...and the pool still serves jobs once the chaos is lifted
        let r = recv_done(&sched.submit(mock_job(2, 4, false), true).unwrap());
        assert!(r.error.is_none(), "post-poison submit failed: {:?}", r.error);
        assert!(sched.stats().jobs_ok() >= 1);
        sched.shutdown();
    }

    /// Satellite: a panic inside the prefix-affinity critical section
    /// (which runs on the SUBMITTING thread) poisons the routing map;
    /// later submissions recover via `into_inner` and route normally.
    #[test]
    fn chaos_poisoned_affinity_lock_recovers() {
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 16, 2, 1, None, true);
        let tag = std::thread::current()
            .name()
            .expect("test threads are named")
            .to_string();
        let g = failpoint::install(
            Some(&tag),
            vec![fault(failpoint::AFFINITY_ROUTE, failpoint::Action::Panic, 1.0)],
            19,
        );
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sched.submit(mock_job(1, 4, false), true)
        }));
        assert!(boom.is_err(), "the affinity failpoint must panic the submitter");
        drop(g);
        // the map mutex is poisoned but routing recovers; jobs serve end
        // to end and the stats wire stays up
        let r = recv_done(&sched.submit(mock_job(2, 4, false), true).unwrap());
        assert!(r.error.is_none(), "post-poison submit failed: {:?}", r.error);
        assert!(sched.stats().jobs_ok() >= 1);
        assert_eq!(sched.stats().queue_depth, 0, "panicked submit leaked queue depth");
        sched.shutdown();
    }
}
