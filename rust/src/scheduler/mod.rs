//! Request scheduler: bounded FIFO queue + a pool of engine workers.
//!
//! The PJRT client (and thus every session) is thread-pinned, so each of
//! the N engine worker threads constructs its own `Runtime` and per-method
//! cache locally and serves jobs off a shared work queue.  Dispatch is
//! work-stealing off one bounded `Receiver` behind a mutex: a worker holds
//! the lock only while *waiting* for a message, never while running a job,
//! so jobs execute concurrently across workers while idle workers queue
//! fairly behind the lock.  Producers (server connections, load
//! generators) submit over the bounded channel — backpressure is the
//! channel bound, exactly as in the single-worker design.  Batch size
//! stays 1 per engine per the paper's serving setup; methods are cached
//! per name in each worker so checkpoint/compile costs are paid once per
//! worker thread.
//!
//! Observability: every worker maintains a [`WorkerStats`] slot (jobs
//! served, tokens, busy/idle seconds, acceptance [`Metrics`] merged over
//! its jobs); [`Scheduler::stats`] snapshots them as a [`PoolStats`]
//! aggregate, which the server exposes through the `{"stats": true}`
//! JSON-lines request.  [`Scheduler::shutdown`] is graceful: queued jobs
//! drain (FIFO) before the per-worker stop markers are consumed, then all
//! engine threads are joined.  `HASS_TEST_JOB_DELAY_MS` injects an
//! artificial per-job delay (test-only throttle for pool scheduling
//! tests and queueing demos).

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::engine::build_method;
use crate::engine::metrics::Metrics;
use crate::runtime::Runtime;
use crate::sampling::SampleParams;
use crate::spec::{GenRequest, Method, MethodCfg};
use crate::tokenizer;
use crate::util::stats::Stopwatch;

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub method: String,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub tau: f64,
    pub latency_s: f64,
    pub queue_s: f64,
    /// engine worker that served the job
    pub worker: usize,
    pub error: Option<String>,
}

// Results travel over an *unbounded* Sender: a worker must never block
// handing a result to a slow consumer (that would stall the shared pool
// for every other connection).  The bounded work queue is the
// backpressure; a client that never reads only grows its own buffer.
enum Msg {
    Run(Job, Stopwatch, Sender<JobResult>),
    Shutdown,
}

/// Live counters for one engine worker (updated by the worker thread).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub worker: usize,
    pub jobs_ok: u64,
    pub jobs_err: u64,
    /// tokens emitted across successful jobs
    pub tokens: u64,
    /// seconds spent running jobs
    pub busy_s: f64,
    /// seconds spent waiting for work
    pub idle_s: f64,
    /// acceptance metrics merged over every successful job
    pub metrics: Metrics,
}

impl WorkerStats {
    pub fn jobs(&self) -> u64 {
        self.jobs_ok + self.jobs_err
    }
}

/// Snapshot of the whole pool: per-worker counters + queue depth.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub workers: Vec<WorkerStats>,
    /// jobs submitted but not yet picked up by a worker
    pub queue_depth: usize,
}

impl PoolStats {
    pub fn jobs(&self) -> u64 {
        self.workers.iter().map(WorkerStats::jobs).sum()
    }

    pub fn jobs_ok(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs_ok).sum()
    }

    pub fn jobs_err(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs_err).sum()
    }

    pub fn tokens(&self) -> u64 {
        self.workers.iter().map(|w| w.tokens).sum()
    }

    pub fn busy_s(&self) -> f64 {
        self.workers.iter().map(|w| w.busy_s).sum()
    }

    /// Acceptance metrics merged across every worker.
    pub fn metrics(&self) -> Metrics {
        Metrics::merged(self.workers.iter().map(|w| &w.metrics))
    }

    /// Pool-wide acceptance length τ.
    pub fn tau(&self) -> f64 {
        self.metrics().tau()
    }
}

pub struct Scheduler {
    /// `None` once shutdown has begun: closing submissions *before* the
    /// stop markers are enqueued guarantees no job can land behind them
    /// (it would be dropped unserved and hang its client).
    tx: RwLock<Option<SyncSender<Msg>>>,
    workers: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
    stats: Arc<Mutex<Vec<WorkerStats>>>,
    queue_depth: Arc<AtomicUsize>,
}

impl Scheduler {
    /// Spawn `workers` engine threads sharing one bounded work queue.
    /// `queue_cap` bounds submitted-but-unserved requests.
    pub fn start(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
    ) -> Scheduler {
        // the env knob is read once per pool (demo/test throttle)
        let test_delay_ms: Option<u64> = std::env::var("HASS_TEST_JOB_DELAY_MS")
            .ok()
            .and_then(|v| v.parse().ok());
        Scheduler::start_inner(artifact_dir, cfg, queue_cap, workers, test_delay_ms)
    }

    fn start_inner(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        test_delay_ms: Option<u64>,
    ) -> Scheduler {
        let workers = workers.max(1);
        let (tx, rx) = sync_channel::<Msg>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let stats: Arc<Mutex<Vec<WorkerStats>>> = Arc::new(Mutex::new(
            (0..workers).map(|w| WorkerStats { worker: w, ..WorkerStats::default() }).collect(),
        ));
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let ctx = WorkerCtx {
                id: w,
                stats: stats.clone(),
                queue_depth: queue_depth.clone(),
                test_delay_ms,
            };
            let rx = rx.clone();
            let dir = artifact_dir.clone();
            let cfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("engine-{w}"))
                    .spawn(move || worker(ctx, dir, cfg, rx))
                    .expect("spawn engine worker"),
            );
        }
        Scheduler {
            tx: RwLock::new(Some(tx)),
            workers,
            handles: Mutex::new(handles),
            stats,
            queue_depth,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Submit a job; `blocking` waits for queue space, otherwise a full
    /// queue is an error (backpressure surfaced to the caller).
    pub fn submit(&self, job: Job, blocking: bool) -> Result<Receiver<JobResult>> {
        let (rtx, rrx) = channel();
        self.submit_to(job, blocking, rtx)?;
        Ok(rrx)
    }

    /// Submit with a caller-supplied result channel.  One channel can
    /// collect many jobs (results carry the job id), which lets a server
    /// connection drain all its responses with a single pump thread.
    pub fn submit_to(&self, job: Job, blocking: bool, rtx: Sender<JobResult>) -> Result<()> {
        // holding the read lock across the send excludes shutdown()'s
        // write-locked sender teardown, so an accepted job always sits
        // ahead of the stop markers and is guaranteed to be served
        let guard = self.tx.read().unwrap_or_else(|p| p.into_inner());
        let tx = match guard.as_ref() {
            Some(tx) => tx,
            None => return Err(anyhow::anyhow!("scheduler down")),
        };
        let msg = Msg::Run(job, Stopwatch::start(), rtx);
        // count before sending so the gauge never underflows when a worker
        // dequeues between the send and the increment
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
        let sent = if blocking {
            tx.send(msg).map_err(|_| anyhow::anyhow!("scheduler down"))
        } else {
            match tx.try_send(msg) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(_)) => Err(anyhow::anyhow!("queue full (backpressure)")),
                Err(TrySendError::Disconnected(_)) => Err(anyhow::anyhow!("scheduler down")),
            }
        };
        if let Err(e) = sent {
            self.queue_depth.fetch_sub(1, Ordering::Relaxed);
            return Err(e);
        }
        Ok(())
    }

    /// Snapshot per-worker counters + queue depth.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.stats.lock().unwrap_or_else(|p| p.into_inner()).clone(),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown: submissions close first (the write lock waits
    /// out in-flight submits), then the per-worker stop markers are
    /// enqueued — the queue is FIFO, so every accepted job drains before
    /// a worker stops — and all engine threads are joined.  Idempotent.
    pub fn shutdown(&self) {
        let tx = self.tx.write().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(tx) = tx {
            for _ in 0..self.workers {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        let mut handles = self.handles.lock().unwrap_or_else(|p| p.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct WorkerCtx {
    id: usize,
    stats: Arc<Mutex<Vec<WorkerStats>>>,
    queue_depth: Arc<AtomicUsize>,
    /// artificial per-job delay (test-only throttle; see module docs)
    test_delay_ms: Option<u64>,
}

impl WorkerCtx {
    fn add_idle(&self, idle_s: f64) {
        let mut stats = self.stats.lock().unwrap_or_else(|p| p.into_inner());
        stats[self.id].idle_s += idle_s;
    }
}

fn worker(ctx: WorkerCtx, artifact_dir: PathBuf, cfg: MethodCfg, rx: Arc<Mutex<Receiver<Msg>>>) {
    // The runtime is thread-pinned, so each worker owns one.  If init
    // fails (missing artifacts), keep serving: every job gets an error
    // result instead of a hang, and the pool stays observable.
    let (rt, init_err): (Option<Rc<Runtime>>, Option<String>) = match Runtime::new(&artifact_dir) {
        Ok(rt) => (Some(Rc::new(rt)), None),
        Err(e) => {
            eprintln!("[scheduler] worker {}: runtime init failed: {e:#}", ctx.id);
            (None, Some(format!("runtime init failed: {e:#}")))
        }
    };
    let mut methods: HashMap<String, Box<dyn Method>> = HashMap::new();
    loop {
        let idle_sw = Stopwatch::start();
        let msg = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            guard.recv()
        };
        let idle_s = idle_sw.secs();
        let (job, sw, rtx) = match msg {
            Ok(Msg::Run(j, s, t)) => (j, s, t),
            Ok(Msg::Shutdown) | Err(_) => {
                ctx.add_idle(idle_s);
                return;
            }
        };
        ctx.queue_depth.fetch_sub(1, Ordering::Relaxed);
        let queue_s = sw.secs();
        let busy_sw = Stopwatch::start();
        if let Some(ms) = ctx.test_delay_ms {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        let (result, job_metrics) = match (&rt, &init_err) {
            (Some(rt), _) => {
                // a panicking method (bad logits, artifact mismatch...)
                // must cost one error response, not the engine thread —
                // and certainly not a client hung waiting for a reply
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_job(rt, &mut methods, &cfg, &job, queue_s, ctx.id)
                }));
                match caught {
                    Ok(r) => r,
                    Err(p) => {
                        // session state may be mid-mutation: rebuild fresh
                        methods.clear();
                        let msg = panic_text(p.as_ref());
                        (
                            err_result(&job, queue_s, 0.0, &format!("engine panic: {msg}"), ctx.id),
                            None,
                        )
                    }
                }
            }
            (None, Some(err)) => (err_result(&job, queue_s, 0.0, err, ctx.id), None),
            (None, None) => unreachable!("worker without runtime or init error"),
        };
        let busy_s = busy_sw.secs();
        {
            let mut stats = ctx.stats.lock().unwrap_or_else(|p| p.into_inner());
            let w = &mut stats[ctx.id];
            w.idle_s += idle_s;
            w.busy_s += busy_s;
            w.tokens += result.tokens as u64;
            match result.error {
                Some(_) => w.jobs_err += 1,
                None => w.jobs_ok += 1,
            }
            if let Some(m) = &job_metrics {
                w.metrics.merge(m);
            }
        }
        let _ = rtx.send(result);
    }
}

fn run_job(
    rt: &Rc<Runtime>,
    methods: &mut HashMap<String, Box<dyn Method>>,
    cfg: &MethodCfg,
    job: &Job,
    queue_s: f64,
    worker: usize,
) -> (JobResult, Option<Metrics>) {
    let method = match methods.entry(job.method.clone()) {
        std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
        std::collections::hash_map::Entry::Vacant(e) => match build_method(rt, &job.method, cfg) {
            Ok(m) => e.insert(m),
            Err(err) => {
                return (err_result(job, queue_s, 0.0, &format!("{err:#}"), worker), None)
            }
        },
    };
    let lsw = Stopwatch::start();
    let req = GenRequest {
        prompt_tokens: tokenizer::encode(&job.prompt, true),
        max_new: job.max_new,
        params: SampleParams {
            temperature: job.temperature,
            seed: job.seed,
            ..Default::default()
        },
    };
    match method.generate(&req) {
        Ok(out) => {
            let metrics = out.metrics.clone();
            (
                JobResult {
                    id: job.id,
                    text: tokenizer::decode(&out.tokens),
                    tokens: out.tokens.len(),
                    tau: out.metrics.tau(),
                    latency_s: lsw.secs(),
                    queue_s,
                    worker,
                    error: None,
                },
                Some(metrics),
            )
        }
        Err(err) => (err_result(job, queue_s, lsw.secs(), &format!("{err:#}"), worker), None),
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

fn err_result(job: &Job, queue_s: f64, latency_s: f64, err: &str, worker: usize) -> JobResult {
    JobResult {
        id: job.id,
        text: String::new(),
        tokens: 0,
        tau: 0.0,
        latency_s,
        queue_s,
        worker,
        error: Some(err.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64) -> Job {
        Job {
            id,
            method: "hass".into(),
            prompt: "hi".into(),
            max_new: 4,
            temperature: 0.0,
            seed: 0,
        }
    }

    /// Nonexistent artifact dir: runtime init fails fast, so the pool's
    /// error path exercises the full dispatch machinery without weights.
    fn bad_dir() -> PathBuf {
        PathBuf::from("/nonexistent/hass-artifacts")
    }

    #[test]
    fn pool_serves_error_results_without_artifacts() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 2);
        let rxs: Vec<_> = (0..8).map(|i| sched.submit(job(i), true).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, i as u64);
            assert!(r.worker < 2);
            let err = r.error.expect("no artifacts must surface an error result");
            assert!(err.contains("runtime init failed"), "unexpected error: {err}");
        }
        let stats = sched.stats();
        assert_eq!(stats.workers.len(), 2);
        assert_eq!(stats.jobs(), 8);
        assert_eq!(stats.jobs_err(), 8);
        assert_eq!(stats.jobs_ok(), 0);
        assert!(stats.tau().is_finite());
        sched.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 4, 1);
        sched.shutdown();
        assert!(sched.submit(job(1), true).is_err());
        assert!(sched.submit(job(2), false).is_err());
        assert_eq!(sched.stats().queue_depth, 0);
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 32, 2);
        let rxs: Vec<_> = (0..12).map(|i| sched.submit(job(i), true).unwrap()).collect();
        sched.shutdown();
        for rx in rxs {
            assert!(rx.recv().is_ok(), "job dropped during graceful shutdown");
        }
        let stats = sched.stats();
        assert_eq!(stats.jobs(), 12);
        assert_eq!(stats.queue_depth, 0);
    }

    #[test]
    fn pool_distributes_across_workers_under_load() {
        // inject the per-job delay directly (mutating the process env from
        // a parallel test races other threads reading it) so one worker
        // can't drain the queue alone
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 2, Some(20));
        let rxs: Vec<_> = (0..8).map(|i| sched.submit(job(i), true).unwrap()).collect();
        let served: std::collections::HashSet<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().worker).collect();
        assert_eq!(served.len(), 2, "both engine threads must serve jobs");
        let stats = sched.stats();
        assert!(stats.workers.iter().all(|w| w.jobs() > 0));
        assert!(stats.busy_s() > 0.0);
        sched.shutdown();
    }

    #[test]
    fn submit_to_collects_many_jobs_on_one_channel() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 2);
        let (rtx, rrx) = std::sync::mpsc::channel();
        for i in 0..6 {
            sched.submit_to(job(i), true, rtx.clone()).unwrap();
        }
        drop(rtx);
        let mut ids: Vec<u64> = rrx.iter().map(|r: JobResult| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        sched.shutdown();
    }
}
