//! Request scheduler: bounded FIFO queue + a dedicated engine worker.
//!
//! The PJRT client (and thus every session) is thread-pinned, so the
//! scheduler owns exactly one engine thread that constructs the Runtime and
//! method instances locally and drains the queue; producers (server
//! connections, load generators) submit over a bounded channel —
//! backpressure is the channel bound.  Batch size is 1 per the paper's
//! serving setup; methods are cached per name so checkpoint/compile costs
//! are paid once.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::engine::build_method;
use crate::runtime::Runtime;
use crate::sampling::SampleParams;
use crate::spec::{GenRequest, Method, MethodCfg};
use crate::tokenizer;
use crate::util::stats::Stopwatch;

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub method: String,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub tau: f64,
    pub latency_s: f64,
    pub queue_s: f64,
    pub error: Option<String>,
}

enum Msg {
    Run(Job, Stopwatch, SyncSender<JobResult>),
    Shutdown,
}

pub struct Scheduler {
    tx: SyncSender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl Scheduler {
    /// Spawn the engine worker.  `queue_cap` bounds in-flight requests.
    pub fn start(artifact_dir: PathBuf, cfg: MethodCfg, queue_cap: usize) -> Scheduler {
        let (tx, rx) = sync_channel::<Msg>(queue_cap);
        let handle = std::thread::spawn(move || worker(artifact_dir, cfg, rx));
        Scheduler { tx, handle: Some(handle) }
    }

    /// Submit a job; `blocking` waits for queue space, otherwise a full
    /// queue is an error (backpressure surfaced to the caller).
    pub fn submit(
        &self,
        job: Job,
        blocking: bool,
    ) -> Result<Receiver<JobResult>> {
        let (rtx, rrx) = sync_channel(1);
        let msg = Msg::Run(job, Stopwatch::start(), rtx);
        if blocking {
            self.tx.send(msg).map_err(|_| anyhow::anyhow!("scheduler down"))?;
        } else {
            match self.tx.try_send(msg) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => bail!("queue full (backpressure)"),
                Err(TrySendError::Disconnected(_)) => bail!("scheduler down"),
            }
        }
        Ok(rrx)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(artifact_dir: PathBuf, cfg: MethodCfg, rx: Receiver<Msg>) {
    let rt = match Runtime::new(&artifact_dir) {
        Ok(rt) => Rc::new(rt),
        Err(e) => {
            eprintln!("[scheduler] runtime init failed: {e:#}");
            // drain and error out every job
            while let Ok(Msg::Run(job, sw, rtx)) = rx.recv() {
                let _ = rtx.send(JobResult {
                    id: job.id,
                    text: String::new(),
                    tokens: 0,
                    tau: 0.0,
                    latency_s: 0.0,
                    queue_s: sw.secs(),
                    error: Some(format!("runtime init failed: {e:#}")),
                });
            }
            return;
        }
    };
    let mut methods: HashMap<String, Box<dyn Method>> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        let (job, sw, rtx) = match msg {
            Msg::Run(j, s, t) => (j, s, t),
            Msg::Shutdown => break,
        };
        let queue_s = sw.secs();
        let method = match methods.entry(job.method.clone()) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => match build_method(&rt, &job.method, &cfg) {
                Ok(m) => e.insert(m),
                Err(err) => {
                    let _ = rtx.send(JobResult {
                        id: job.id,
                        text: String::new(),
                        tokens: 0,
                        tau: 0.0,
                        latency_s: 0.0,
                        queue_s,
                        error: Some(format!("{err:#}")),
                    });
                    continue;
                }
            },
        };
        let lsw = Stopwatch::start();
        let req = GenRequest {
            prompt_tokens: tokenizer::encode(&job.prompt, true),
            max_new: job.max_new,
            params: SampleParams { temperature: job.temperature, seed: job.seed, ..Default::default() },
        };
        let result = match method.generate(&req) {
            Ok(out) => JobResult {
                id: job.id,
                text: tokenizer::decode(&out.tokens),
                tokens: out.tokens.len(),
                tau: out.metrics.tau(),
                latency_s: lsw.secs(),
                queue_s,
                error: None,
            },
            Err(err) => JobResult {
                id: job.id,
                text: String::new(),
                tokens: 0,
                tau: 0.0,
                latency_s: lsw.secs(),
                queue_s,
                error: Some(format!("{err:#}")),
            },
        };
        let _ = rtx.send(result);
    }

}
