//! Request scheduler: bounded FIFO queue + a pool of engine workers with
//! cycle-granular continuous batching inside each worker.
//!
//! The PJRT client (and thus every session) is thread-pinned, so each of
//! the N engine worker threads constructs its own `Runtime` and per-method
//! instance pool locally and serves jobs off a shared work queue.
//! Dispatch is work-stealing off one bounded `Receiver` behind a mutex: a
//! worker holds the lock only while *waiting* for a message, never while
//! running a job.  Producers (server connections, load generators) submit
//! over the bounded channel — backpressure is the channel bound.
//!
//! **Continuous batching.**  `Method` is a resumable state machine
//! (`start`/`step`, see `spec`), so a worker no longer runs one job to
//! completion: it interleaves up to `max_active` live sessions
//! round-robin, one drafting-verification cycle per turn, polling the
//! queue between cycles.  A short job submitted behind a long one starts
//! immediately and finishes first instead of waiting out the long job's
//! tail (head-of-line blocking at job granularity becomes cycle
//! granularity).  Each live session checks out its own `Method` instance
//! (own KV caches) from a per-name free list, returned at completion.
//!
//! **Streaming / cancellation / deadlines.**  Results travel as
//! [`JobEvent`]s on an *unbounded* channel (a worker must never block
//! handing a result to a slow consumer): jobs with `stream: true` get a
//! [`JobEvent::Delta`] per cycle, every job ends with exactly one
//! [`JobEvent::Done`].  [`Scheduler::cancel`] marks a job id; the owning
//! worker aborts it between cycles (or at admission while still queued)
//! with a "cancelled" error result.  A job's `deadline_ms` is checked
//! between cycles against its submission clock.  Callers must only
//! cancel ids they actually submitted (the TCP server enforces this per
//! connection): a marker for a never-submitted id would linger and
//! cancel whatever job is eventually assigned that id.  Markers for
//! already-finished jobs are cleared lazily when the id is next seen.
//!
//! Observability: every worker maintains a [`WorkerStats`] slot (jobs
//! served, tokens, busy/idle seconds, acceptance [`Metrics`] merged over
//! its jobs — busy counts in-step CPU time, not interleaved wall time);
//! [`Scheduler::stats`] snapshots them as a [`PoolStats`] aggregate, which
//! the server exposes through the `{"stats": true}` JSON-lines request.
//! [`Scheduler::shutdown`] is graceful: queued jobs drain (FIFO) before
//! the per-worker stop markers are consumed — a worker that sees its
//! marker finishes its live sessions, then exits.  `HASS_TEST_JOB_DELAY_MS`
//! injects an artificial delay at job admission *and* after every step
//! (test-only throttle for pool scheduling tests and queueing demos).

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::engine::build_method;
use crate::engine::metrics::Metrics;
use crate::runtime::Runtime;
use crate::sampling::SampleParams;
use crate::spec::{GenRequest, GenState, Method, MethodCfg};
use crate::tokenizer;
use crate::util::stats::Stopwatch;

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub method: String,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
    /// emit a [`JobEvent::Delta`] per drafting-verification cycle
    pub stream: bool,
    /// abort with an error result once this many ms have passed since
    /// submission (checked between cycles, and at admission while queued)
    pub deadline_ms: Option<u64>,
}

#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub tau: f64,
    /// wall time from admission to completion (includes cycles of other
    /// interleaved jobs on the same worker)
    pub latency_s: f64,
    pub queue_s: f64,
    /// engine worker that served the job
    pub worker: usize,
    /// the request asked for streaming (final wire line carries "done")
    pub stream: bool,
    pub error: Option<String>,
}

/// One message on a job's result channel.  Non-streamed jobs produce a
/// single `Done`; streamed jobs produce one `Delta` per cycle first.
#[derive(Clone, Debug)]
pub enum JobEvent {
    Delta {
        id: u64,
        /// decoded text of the tokens emitted this cycle
        text: String,
        /// total tokens emitted so far
        tokens: usize,
    },
    Done(JobResult),
}

impl JobEvent {
    pub fn id(&self) -> u64 {
        match self {
            JobEvent::Delta { id, .. } => *id,
            JobEvent::Done(r) => r.id,
        }
    }

    /// The terminal result, if this is the `Done` event.
    pub fn into_result(self) -> Option<JobResult> {
        match self {
            JobEvent::Done(r) => Some(r),
            JobEvent::Delta { .. } => None,
        }
    }
}

enum Msg {
    Run(Job, Stopwatch, Sender<JobEvent>),
    Shutdown,
}

/// Live counters for one engine worker (updated by the worker thread).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub worker: usize,
    pub jobs_ok: u64,
    pub jobs_err: u64,
    /// tokens emitted across successful jobs
    pub tokens: u64,
    /// seconds spent doing per-job work — method build/checkout, start,
    /// and step calls (CPU occupancy, not interleaved wall time)
    pub busy_s: f64,
    /// seconds spent waiting for work
    pub idle_s: f64,
    /// acceptance metrics merged over every successful job
    pub metrics: Metrics,
}

impl WorkerStats {
    pub fn jobs(&self) -> u64 {
        self.jobs_ok + self.jobs_err
    }
}

/// Snapshot of the whole pool: per-worker counters + queue depth.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub workers: Vec<WorkerStats>,
    /// jobs submitted but not yet picked up by a worker
    pub queue_depth: usize,
}

impl PoolStats {
    pub fn jobs(&self) -> u64 {
        self.workers.iter().map(WorkerStats::jobs).sum()
    }

    pub fn jobs_ok(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs_ok).sum()
    }

    pub fn jobs_err(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs_err).sum()
    }

    pub fn tokens(&self) -> u64 {
        self.workers.iter().map(|w| w.tokens).sum()
    }

    pub fn busy_s(&self) -> f64 {
        self.workers.iter().map(|w| w.busy_s).sum()
    }

    /// Acceptance metrics merged across every worker.
    pub fn metrics(&self) -> Metrics {
        Metrics::merged(self.workers.iter().map(|w| &w.metrics))
    }

    /// Pool-wide acceptance length τ.
    pub fn tau(&self) -> f64 {
        self.metrics().tau()
    }
}

pub struct Scheduler {
    /// `None` once shutdown has begun: closing submissions *before* the
    /// stop markers are enqueued guarantees no job can land behind them
    /// (it would be dropped unserved and hang its client).
    tx: RwLock<Option<SyncSender<Msg>>>,
    workers: usize,
    max_active: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
    stats: Arc<Mutex<Vec<WorkerStats>>>,
    queue_depth: Arc<AtomicUsize>,
    cancels: Arc<Mutex<HashSet<u64>>>,
}

impl Scheduler {
    /// Spawn `workers` engine threads sharing one bounded work queue.
    /// `queue_cap` bounds submitted-but-unserved requests; `max_active`
    /// bounds the sessions one worker interleaves (1 = run-to-completion).
    pub fn start(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        max_active: usize,
    ) -> Scheduler {
        // the env knob is read once per pool (demo/test throttle)
        let test_delay_ms: Option<u64> = std::env::var("HASS_TEST_JOB_DELAY_MS")
            .ok()
            .and_then(|v| v.parse().ok());
        Scheduler::start_inner(artifact_dir, cfg, queue_cap, workers, max_active, test_delay_ms)
    }

    fn start_inner(
        artifact_dir: PathBuf,
        cfg: MethodCfg,
        queue_cap: usize,
        workers: usize,
        max_active: usize,
        test_delay_ms: Option<u64>,
    ) -> Scheduler {
        let workers = workers.max(1);
        let max_active = max_active.max(1);
        let (tx, rx) = sync_channel::<Msg>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let stats: Arc<Mutex<Vec<WorkerStats>>> = Arc::new(Mutex::new(
            (0..workers).map(|w| WorkerStats { worker: w, ..WorkerStats::default() }).collect(),
        ));
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let cancels: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let ctx = WorkerCtx {
                id: w,
                stats: stats.clone(),
                queue_depth: queue_depth.clone(),
                cancels: cancels.clone(),
                max_active,
                test_delay_ms,
            };
            let rx = rx.clone();
            let dir = artifact_dir.clone();
            let cfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("engine-{w}"))
                    .spawn(move || worker(ctx, dir, cfg, rx))
                    .expect("spawn engine worker"),
            );
        }
        Scheduler {
            tx: RwLock::new(Some(tx)),
            workers,
            max_active,
            handles: Mutex::new(handles),
            stats,
            queue_depth,
            cancels,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn max_active(&self) -> usize {
        self.max_active
    }

    /// Submit a job; `blocking` waits for queue space, otherwise a full
    /// queue is an error (backpressure surfaced to the caller).
    pub fn submit(&self, job: Job, blocking: bool) -> Result<Receiver<JobEvent>> {
        let (rtx, rrx) = channel();
        self.submit_to(job, blocking, rtx)?;
        Ok(rrx)
    }

    /// Submit with a caller-supplied event channel.  One channel can
    /// collect many jobs (events carry the job id), which lets a server
    /// connection drain all its responses with a single pump thread.
    pub fn submit_to(&self, job: Job, blocking: bool, rtx: Sender<JobEvent>) -> Result<()> {
        // holding the read lock across the send excludes shutdown()'s
        // write-locked sender teardown, so an accepted job always sits
        // ahead of the stop markers and is guaranteed to be served
        let guard = self.tx.read().unwrap_or_else(|p| p.into_inner());
        let tx = match guard.as_ref() {
            Some(tx) => tx,
            None => return Err(anyhow::anyhow!("scheduler down")),
        };
        let msg = Msg::Run(job, Stopwatch::start(), rtx);
        // count before sending so the gauge never underflows when a worker
        // dequeues between the send and the increment
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
        let sent = if blocking {
            tx.send(msg).map_err(|_| anyhow::anyhow!("scheduler down"))
        } else {
            match tx.try_send(msg) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(_)) => Err(anyhow::anyhow!("queue full (backpressure)")),
                Err(TrySendError::Disconnected(_)) => Err(anyhow::anyhow!("scheduler down")),
            }
        };
        if let Err(e) = sent {
            self.queue_depth.fetch_sub(1, Ordering::Relaxed);
            return Err(e);
        }
        Ok(())
    }

    /// Request cancellation of a job by id.  The job — queued or live —
    /// reports a "cancelled" error result through its own event channel;
    /// cancelling an unknown or already-finished id is a no-op.
    pub fn cancel(&self, id: u64) {
        self.cancels.lock().unwrap_or_else(|p| p.into_inner()).insert(id);
    }

    /// Snapshot per-worker counters + queue depth.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.stats.lock().unwrap_or_else(|p| p.into_inner()).clone(),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown: submissions close first (the write lock waits
    /// out in-flight submits), then the per-worker stop markers are
    /// enqueued — the queue is FIFO, so every accepted job drains before
    /// a worker stops — and all engine threads are joined.  Idempotent.
    pub fn shutdown(&self) {
        let tx = self.tx.write().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(tx) = tx {
            for _ in 0..self.workers {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        let mut handles = self.handles.lock().unwrap_or_else(|p| p.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct WorkerCtx {
    id: usize,
    stats: Arc<Mutex<Vec<WorkerStats>>>,
    queue_depth: Arc<AtomicUsize>,
    cancels: Arc<Mutex<HashSet<u64>>>,
    /// sessions this worker interleaves round-robin
    max_active: usize,
    /// artificial admission + per-step delay (test throttle; module docs)
    test_delay_ms: Option<u64>,
}

impl WorkerCtx {
    fn add_idle(&self, idle_s: f64) {
        let mut stats = self.stats.lock().unwrap_or_else(|p| p.into_inner());
        stats[self.id].idle_s += idle_s;
    }

    /// Consume a pending cancel marker for `id`.
    fn take_cancel(&self, id: u64) -> bool {
        self.cancels.lock().unwrap_or_else(|p| p.into_inner()).remove(&id)
    }

    fn sleep_throttle(&self) {
        if let Some(ms) = self.test_delay_ms {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// Per-name free list of method instances.  Each live session owns one
/// instance (sessions hold per-instance KV caches); at completion the
/// instance returns here so checkpoint/compile costs are paid at most
/// `max_active` times per name per worker.
type MethodPool = HashMap<String, Vec<Box<dyn Method>>>;

/// One live generation session on a worker.
struct ActiveJob {
    job: Job,
    rtx: Sender<JobEvent>,
    /// clock since submission (deadline base; keeps ticking while running)
    submit_sw: Stopwatch,
    queue_s: f64,
    /// clock since admission (reported latency)
    run_sw: Stopwatch,
    /// seconds spent inside start/step for this job
    cpu_s: f64,
    /// tokens already delivered as stream deltas
    sent: usize,
    state: GenState,
    method: Box<dyn Method>,
}

enum StepVerdict {
    Continue,
    /// job finished; `reuse` returns the method instance to the pool
    /// (false after a panic left its sessions mid-mutation)
    Done { reuse: bool },
}

fn worker(ctx: WorkerCtx, artifact_dir: PathBuf, cfg: MethodCfg, rx: Arc<Mutex<Receiver<Msg>>>) {
    // The runtime is thread-pinned, so each worker owns one.  If init
    // fails (missing artifacts), keep serving: runtime-backed jobs get an
    // error result instead of a hang (runtime-free methods still run),
    // and the pool stays observable.
    let (rt, init_err): (Option<Rc<Runtime>>, Option<String>) = match Runtime::new(&artifact_dir) {
        Ok(rt) => (Some(Rc::new(rt)), None),
        Err(e) => {
            eprintln!("[scheduler] worker {}: runtime init failed: {e:#}", ctx.id);
            (None, Some(format!("runtime init failed: {e:#}")))
        }
    };
    let mut pool: MethodPool = HashMap::new();
    let mut active: Vec<ActiveJob> = Vec::new();
    let mut draining = false;
    let mut cursor = 0usize;
    loop {
        // ---- admit new jobs up to max_active ----
        while !draining && active.len() < ctx.max_active {
            let msg = if active.is_empty() {
                // nothing to step: block for work (counted as idle)
                let idle_sw = Stopwatch::start();
                let m = {
                    let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
                    guard.recv()
                };
                ctx.add_idle(idle_sw.secs());
                match m {
                    Ok(m) => m,
                    Err(_) => return, // channel gone, nothing in flight
                }
            } else {
                // Live sessions waiting: poll without blocking.  try_lock,
                // not lock — an *idle* worker parks inside recv() while
                // holding the rx mutex, so lock() here would stall our
                // active sessions until new work arrived.  If the mutex is
                // held, whoever holds it will take the next job anyway.
                let m = match rx.try_lock() {
                    Ok(guard) => guard.try_recv(),
                    Err(std::sync::TryLockError::WouldBlock) => Err(TryRecvError::Empty),
                    Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner().try_recv(),
                };
                match m {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true;
                        break;
                    }
                }
            };
            match msg {
                Msg::Shutdown => {
                    if active.is_empty() {
                        return;
                    }
                    // finish live sessions, stop pulling new work
                    draining = true;
                }
                Msg::Run(job, submit_sw, rtx) => {
                    ctx.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    if let Some(a) =
                        admit(&ctx, rt.as_ref(), &init_err, &mut pool, &cfg, job, submit_sw, rtx)
                    {
                        active.push(a);
                    }
                }
            }
        }
        if active.is_empty() {
            if draining {
                return;
            }
            continue; // blocking recv above admitted nothing (rejected job)
        }
        // ---- one cycle of one live session, round-robin ----
        cursor %= active.len();
        match step_active(&ctx, &mut active[cursor]) {
            StepVerdict::Continue => cursor += 1,
            StepVerdict::Done { reuse } => {
                let a = active.swap_remove(cursor);
                if reuse {
                    let name = a.job.method.clone();
                    checkin(&mut pool, &name, a.method);
                }
            }
        }
    }
}

fn checkout(
    pool: &mut MethodPool,
    rt: Option<&Rc<Runtime>>,
    init_err: &Option<String>,
    cfg: &MethodCfg,
    name: &str,
) -> std::result::Result<Box<dyn Method>, String> {
    if let Some(m) = pool.get_mut(name).and_then(|v| v.pop()) {
        return Ok(m);
    }
    if let Some(m) = crate::engine::build_free_method(name) {
        return Ok(m);
    }
    match rt {
        Some(rt) => build_method(rt, name, cfg).map_err(|e| format!("{e:#}")),
        None => Err(init_err.clone().unwrap_or_else(|| "runtime init failed".to_string())),
    }
}

fn checkin(pool: &mut MethodPool, name: &str, m: Box<dyn Method>) {
    pool.entry(name.to_string()).or_default().push(m);
}

fn past_deadline(job: &Job, since_submit: &Stopwatch) -> bool {
    match job.deadline_ms {
        Some(ms) => since_submit.secs() * 1000.0 > ms as f64,
        None => false,
    }
}

/// Start a session for a dequeued job.  Returns the live session, or
/// `None` if the job already completed (rejected, or done at start).
#[allow(clippy::too_many_arguments)]
fn admit(
    ctx: &WorkerCtx,
    rt: Option<&Rc<Runtime>>,
    init_err: &Option<String>,
    pool: &mut MethodPool,
    cfg: &MethodCfg,
    job: Job,
    submit_sw: Stopwatch,
    rtx: Sender<JobEvent>,
) -> Option<ActiveJob> {
    let queue_s = submit_sw.secs();
    if ctx.take_cancel(job.id) {
        reject(ctx, &job, queue_s, 0.0, 0.0, "cancelled", &rtx);
        return None;
    }
    if past_deadline(&job, &submit_sw) {
        reject(ctx, &job, queue_s, 0.0, 0.0, "deadline_ms exceeded while queued", &rtx);
        return None;
    }
    // work clock: the test throttle, method build/compile, and start()
    // are all real worker occupancy and count toward busy_s
    let work_sw = Stopwatch::start();
    ctx.sleep_throttle();
    let mut method = match checkout(pool, rt, init_err, cfg, &job.method) {
        Ok(m) => m,
        Err(msg) => {
            reject(ctx, &job, queue_s, 0.0, work_sw.secs(), &msg, &rtx);
            return None;
        }
    };
    let req = GenRequest {
        prompt_tokens: tokenizer::encode(&job.prompt, true),
        max_new: job.max_new,
        params: SampleParams {
            temperature: job.temperature,
            seed: job.seed,
            ..Default::default()
        },
    };
    let run_sw = Stopwatch::start();
    // a panicking method (bad logits, artifact mismatch...) must cost one
    // error response, not the engine thread — and certainly not a client
    // hung waiting for a reply
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        let r = method.start(&req);
        (method, r)
    }));
    let cpu_s = work_sw.secs();
    match caught {
        Err(p) => {
            // instance sessions are mid-mutation: drop the instance
            let msg = panic_text(p.as_ref());
            reject(ctx, &job, queue_s, run_sw.secs(), cpu_s, &format!("engine panic: {msg}"), &rtx);
            None
        }
        Ok((method, Err(e))) => {
            checkin(pool, &job.method, method);
            reject(ctx, &job, queue_s, run_sw.secs(), cpu_s, &format!("{e:#}"), &rtx);
            None
        }
        Ok((method, Ok(state))) => {
            let mut a = ActiveJob {
                job,
                rtx,
                submit_sw,
                queue_s,
                run_sw,
                cpu_s,
                sent: 0,
                state,
                method,
            };
            flush_delta(&mut a);
            if a.state.done {
                complete(ctx, &mut a, None);
                let name = a.job.method.clone();
                checkin(pool, &name, a.method);
                None
            } else {
                Some(a)
            }
        }
    }
}

/// Advance one live session by one cycle (cancel/deadline checked first).
fn step_active(ctx: &WorkerCtx, a: &mut ActiveJob) -> StepVerdict {
    if ctx.take_cancel(a.job.id) {
        complete(ctx, a, Some("cancelled".to_string()));
        return StepVerdict::Done { reuse: true };
    }
    if past_deadline(&a.job, &a.submit_sw) {
        let ms = a.job.deadline_ms.unwrap_or(0);
        complete(ctx, a, Some(format!("deadline_ms exceeded ({ms} ms)")));
        return StepVerdict::Done { reuse: true };
    }
    let cpu_sw = Stopwatch::start();
    let caught =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| a.method.step(&mut a.state)));
    a.cpu_s += cpu_sw.secs();
    ctx.sleep_throttle();
    match caught {
        Err(p) => {
            let msg = panic_text(p.as_ref());
            complete(ctx, a, Some(format!("engine panic: {msg}")));
            StepVerdict::Done { reuse: false }
        }
        Ok(Err(e)) => {
            complete(ctx, a, Some(format!("{e:#}")));
            StepVerdict::Done { reuse: true }
        }
        Ok(Ok(_outcome)) => {
            flush_delta(a);
            if a.state.done {
                complete(ctx, a, None);
                StepVerdict::Done { reuse: true }
            } else {
                StepVerdict::Continue
            }
        }
    }
}

/// Send any not-yet-delivered tokens as a stream delta.
fn flush_delta(a: &mut ActiveJob) {
    if !a.job.stream || a.state.tokens.len() <= a.sent {
        return;
    }
    let text = tokenizer::decode(&a.state.tokens[a.sent..]);
    a.sent = a.state.tokens.len();
    if !text.is_empty() {
        let _ = a.rtx.send(JobEvent::Delta { id: a.job.id, text, tokens: a.sent });
    }
}

/// Finish a live session: record stats, send the terminal event.
fn complete(ctx: &WorkerCtx, a: &mut ActiveJob, error: Option<String>) {
    // clear any cancel marker that raced in after the last check
    ctx.take_cancel(a.job.id);
    let result = match error {
        Some(msg) => err_result(&a.job, a.queue_s, a.run_sw.secs(), &msg, ctx.id),
        None => JobResult {
            id: a.job.id,
            text: tokenizer::decode(&a.state.tokens),
            tokens: a.state.tokens.len(),
            tau: a.state.metrics.tau(),
            latency_s: a.run_sw.secs(),
            queue_s: a.queue_s,
            worker: ctx.id,
            stream: a.job.stream,
            error: None,
        },
    };
    {
        let mut stats = ctx.stats.lock().unwrap_or_else(|p| p.into_inner());
        let w = &mut stats[ctx.id];
        w.busy_s += a.cpu_s;
        a.cpu_s = 0.0;
        w.tokens += result.tokens as u64;
        match &result.error {
            Some(_) => w.jobs_err += 1,
            None => {
                w.jobs_ok += 1;
                w.metrics.merge(&a.state.metrics);
            }
        }
    }
    let _ = a.rtx.send(JobEvent::Done(result));
}

/// Fail a job that never became a live session.  `busy_s` is whatever
/// admission work (throttle, method build, start) was already spent.
fn reject(
    ctx: &WorkerCtx,
    job: &Job,
    queue_s: f64,
    latency_s: f64,
    busy_s: f64,
    msg: &str,
    rtx: &Sender<JobEvent>,
) {
    ctx.take_cancel(job.id);
    {
        let mut stats = ctx.stats.lock().unwrap_or_else(|p| p.into_inner());
        stats[ctx.id].jobs_err += 1;
        stats[ctx.id].busy_s += busy_s;
    }
    let _ = rtx.send(JobEvent::Done(err_result(job, queue_s, latency_s, msg, ctx.id)));
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

fn err_result(job: &Job, queue_s: f64, latency_s: f64, err: &str, worker: usize) -> JobResult {
    JobResult {
        id: job.id,
        text: String::new(),
        tokens: 0,
        tau: 0.0,
        latency_s,
        queue_s,
        worker,
        stream: job.stream,
        error: Some(err.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64) -> Job {
        Job {
            id,
            method: "hass".into(),
            prompt: "hi".into(),
            max_new: 4,
            temperature: 0.0,
            seed: 0,
            stream: false,
            deadline_ms: None,
        }
    }

    fn mock_job(id: u64, max_new: usize, stream: bool) -> Job {
        Job {
            id,
            method: "mock".into(),
            prompt: "hi".into(),
            max_new,
            temperature: 0.0,
            seed: 1,
            stream,
            deadline_ms: None,
        }
    }

    /// Block until the job's terminal event arrives (skipping deltas).
    fn recv_done(rx: &Receiver<JobEvent>) -> JobResult {
        loop {
            match rx.recv().expect("scheduler dropped a job") {
                JobEvent::Done(r) => return r,
                JobEvent::Delta { .. } => {}
            }
        }
    }

    /// Nonexistent artifact dir: runtime init fails fast, so the pool's
    /// error path exercises the full dispatch machinery without weights.
    fn bad_dir() -> PathBuf {
        PathBuf::from("/nonexistent/hass-artifacts")
    }

    #[test]
    fn pool_serves_error_results_without_artifacts() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 2, 1);
        let rxs: Vec<_> = (0..8).map(|i| sched.submit(job(i), true).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = recv_done(&rx);
            assert_eq!(r.id, i as u64);
            assert!(r.worker < 2);
            let err = r.error.expect("no artifacts must surface an error result");
            assert!(err.contains("runtime init failed"), "unexpected error: {err}");
        }
        let stats = sched.stats();
        assert_eq!(stats.workers.len(), 2);
        assert_eq!(stats.jobs(), 8);
        assert_eq!(stats.jobs_err(), 8);
        assert_eq!(stats.jobs_ok(), 0);
        assert!(stats.tau().is_finite());
        sched.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 4, 1, 1);
        sched.shutdown();
        assert!(sched.submit(job(1), true).is_err());
        assert!(sched.submit(job(2), false).is_err());
        assert_eq!(sched.stats().queue_depth, 0);
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 32, 2, 1);
        let rxs: Vec<_> = (0..12).map(|i| sched.submit(job(i), true).unwrap()).collect();
        sched.shutdown();
        for rx in rxs {
            assert!(rx.recv().is_ok(), "job dropped during graceful shutdown");
        }
        let stats = sched.stats();
        assert_eq!(stats.jobs(), 12);
        assert_eq!(stats.queue_depth, 0);
    }

    #[test]
    fn pool_distributes_across_workers_under_load() {
        // inject the per-job delay directly (mutating the process env from
        // a parallel test races other threads reading it) so one worker
        // can't drain the queue alone
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 2, 1, Some(20));
        let rxs: Vec<_> = (0..8).map(|i| sched.submit(job(i), true).unwrap()).collect();
        let served: std::collections::HashSet<usize> =
            rxs.into_iter().map(|rx| recv_done(&rx).worker).collect();
        assert_eq!(served.len(), 2, "both engine threads must serve jobs");
        let stats = sched.stats();
        assert!(stats.workers.iter().all(|w| w.jobs() > 0));
        // admission work (throttle + failed checkout) counts as busy
        assert!(stats.busy_s() > 0.0);
        sched.shutdown();
    }

    #[test]
    fn submit_to_collects_many_jobs_on_one_channel() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 16, 2, 1);
        let (rtx, rrx) = std::sync::mpsc::channel();
        for i in 0..6 {
            sched.submit_to(job(i), true, rtx.clone()).unwrap();
        }
        drop(rtx);
        let mut ids: Vec<u64> =
            rrx.iter().filter_map(JobEvent::into_result).map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        sched.shutdown();
    }

    /// Runtime-free `mock` jobs succeed even where every real method
    /// errors at init — the serving path is testable without artifacts.
    #[test]
    fn mock_jobs_run_without_artifacts() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 8, 1, 1);
        let r = recv_done(&sched.submit(mock_job(1, 8, false), true).unwrap());
        assert!(r.error.is_none(), "mock job failed: {:?}", r.error);
        assert_eq!(r.tokens, 8);
        assert_eq!(r.text.len(), 8);
        let stats = sched.stats();
        assert_eq!(stats.jobs_ok(), 1);
        assert_eq!(stats.tokens(), 8);
        sched.shutdown();
    }

    /// THE continuous-batching acceptance test: one worker interleaving
    /// two sessions must finish a short job submitted *behind* a long one
    /// first (cycle-granular scheduling beats head-of-line blocking).
    #[test]
    fn short_job_overtakes_long_job_when_interleaving() {
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 1, 2, Some(3));
        let (rtx, rrx) = std::sync::mpsc::channel();
        sched.submit_to(mock_job(1, 64, false), true, rtx.clone()).unwrap();
        sched.submit_to(mock_job(2, 4, false), true, rtx).unwrap();
        let first = recv_done(&rrx);
        assert_eq!(first.id, 2, "4-token job must return before the 64-token job");
        assert!(first.error.is_none());
        assert_eq!(first.tokens, 4);
        let second = recv_done(&rrx);
        assert_eq!(second.id, 1);
        assert!(second.error.is_none());
        assert_eq!(second.tokens, 64);
        sched.shutdown();
    }

    /// A cancelled job returns an error result and does not block the
    /// queue behind it.
    #[test]
    fn cancelled_job_errors_without_blocking_queue() {
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 32, 1, 1, Some(3));
        let rx1 = sched.submit(mock_job(1, 100_000, false), true).unwrap();
        sched.cancel(1);
        let rx2 = sched.submit(mock_job(2, 4, false), true).unwrap();
        let r1 = recv_done(&rx1);
        let err = r1.error.expect("cancelled job must error");
        assert!(err.contains("cancel"), "unexpected error: {err}");
        let r2 = recv_done(&rx2);
        assert!(r2.error.is_none(), "queue blocked behind cancelled job: {:?}", r2.error);
        assert_eq!(r2.tokens, 4);
        sched.shutdown();
    }

    #[test]
    fn deadline_exceeded_job_errors() {
        let sched = Scheduler::start_inner(bad_dir(), MethodCfg::default(), 8, 1, 1, Some(5));
        let mut j = mock_job(1, 100_000, false);
        j.deadline_ms = Some(20);
        let r = recv_done(&sched.submit(j, true).unwrap());
        let err = r.error.expect("deadline must abort the job");
        assert!(err.contains("deadline"), "unexpected error: {err}");
        sched.shutdown();
    }

    /// Streamed deltas concatenate to exactly the non-streamed text for a
    /// fixed seed, with at least two delta events before the terminal one.
    #[test]
    fn streamed_deltas_concatenate_to_final_text() {
        let sched = Scheduler::start(bad_dir(), MethodCfg::default(), 8, 1, 2);
        let mut j = mock_job(7, 12, true);
        j.seed = 42;
        let rx = sched.submit(j, true).unwrap();
        let mut concat = String::new();
        let mut n_deltas = 0usize;
        let fin = loop {
            match rx.recv().unwrap() {
                JobEvent::Delta { id, text, tokens } => {
                    assert_eq!(id, 7);
                    concat.push_str(&text);
                    assert_eq!(tokens, concat.len(), "delta token counter out of sync");
                    n_deltas += 1;
                }
                JobEvent::Done(r) => break r,
            }
        };
        assert!(n_deltas >= 2, "want >= 2 deltas, got {n_deltas}");
        assert!(fin.error.is_none());
        assert!(fin.stream);
        assert_eq!(concat, fin.text, "deltas must concatenate to the final text");
        // same seed, non-streamed: identical text
        let mut j2 = mock_job(8, 12, false);
        j2.seed = 42;
        let r2 = recv_done(&sched.submit(j2, true).unwrap());
        assert_eq!(r2.text, fin.text);
        sched.shutdown();
    }
}
