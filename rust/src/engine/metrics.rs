//! Acceptance + timing metrics (τ, per-depth α, phase breakdown) — the
//! quantities every paper table/figure is built from.

use crate::util::stats::PhaseTimer;

pub const MAX_DEPTH_TRACKED: usize = 16;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// drafting-verification cycles executed
    pub cycles: usize,
    /// tokens emitted (accepted + bonus per cycle)
    pub new_tokens: usize,
    /// per-depth: how many cycles reached speculation step d (0-based)
    pub reached: [usize; MAX_DEPTH_TRACKED],
    /// per-depth: how many of those accepted the draft token at step d
    pub accepted: [usize; MAX_DEPTH_TRACKED],
    /// wall-clock phases
    pub phases: PhaseTimer,
    /// target-model graph invocations (verify or AR steps)
    pub target_calls: usize,
    /// draft-model graph invocations
    pub draft_calls: usize,
    /// total draft tokens sent for verification
    pub draft_tokens_verified: usize,
}

impl Metrics {
    /// Acceptance length τ: mean tokens per drafting-verification cycle.
    pub fn tau(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.new_tokens as f64 / self.cycles as f64
    }

    /// Per-step acceptance rate α_d (paper Fig. 5/6): P(accept at step d |
    /// reached step d).
    pub fn alpha(&self, d: usize) -> f64 {
        if d >= MAX_DEPTH_TRACKED || self.reached[d] == 0 {
            return 0.0;
        }
        self.accepted[d] as f64 / self.reached[d] as f64
    }

    pub fn alphas(&self, max_d: usize) -> Vec<f64> {
        (0..max_d).map(|d| self.alpha(d)).collect()
    }

    pub fn record_cycle(&mut self, accepted_depth: usize, emitted: usize) {
        self.cycles += 1;
        self.new_tokens += emitted;
        for d in 0..accepted_depth.min(MAX_DEPTH_TRACKED) {
            self.reached[d] += 1;
            self.accepted[d] += 1;
        }
        if accepted_depth < MAX_DEPTH_TRACKED {
            self.reached[accepted_depth] += 1;
        }
    }

    pub fn merge(&mut self, o: &Metrics) {
        self.cycles += o.cycles;
        self.new_tokens += o.new_tokens;
        for d in 0..MAX_DEPTH_TRACKED {
            self.reached[d] += o.reached[d];
            self.accepted[d] += o.accepted[d];
        }
        self.phases.add(&o.phases);
        self.target_calls += o.target_calls;
        self.draft_calls += o.draft_calls;
        self.draft_tokens_verified += o.draft_tokens_verified;
    }

    /// Merge many Metrics into one aggregate (worker-pool / suite rollups).
    pub fn merged<'a, I: IntoIterator<Item = &'a Metrics>>(iter: I) -> Metrics {
        let mut out = Metrics::default();
        for m in iter {
            out.merge(m);
        }
        out
    }
}

/// Device cost model for the paper's speedup accounting (DESIGN.md §7).
///
/// `measured` uses honest CPU wall-clock.  `modeled` prices each target
/// forward (1..=N tokens) at ~one memory-bound AR step and each draft step
/// at `draft_ratio` of that — the H800 regime Table 2 reflects — while
/// charging the *measured* L3 overhead (tree/sampling/host) as-is.
///
/// `draft_ratio` defaults to the *paper's* draft/target ratio (a 1-layer
/// EAGLE head over a 32-layer LLaMA, ~0.05 of an AR step when
/// memory-bound), not this testbed's 1-vs-4-layer ratio: the modeled
/// accounting exists precisely to translate measured acceptance behaviour
/// into the paper's device regime (DESIGN.md §7).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// seconds per target AR step (calibrated on this machine)
    pub t_ar: f64,
    /// verify-call overhead multiplier vs a plain AR step
    pub verify_factor: f64,
    /// draft step cost as a fraction of an AR step
    pub draft_ratio: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { t_ar: 1.0, verify_factor: 1.05, draft_ratio: 0.05 }
    }
}

impl CostModel {
    /// Modeled wall-time for a run described by `m`.
    pub fn modeled_time(&self, m: &Metrics, host_overhead_s: f64) -> f64 {
        self.t_ar
            * (m.target_calls as f64 * self.verify_factor
                + m.draft_calls as f64 * self.draft_ratio)
            + host_overhead_s
    }

    /// Modeled vanilla-AR time for the same number of emitted tokens.
    pub fn vanilla_time(&self, tokens: usize) -> f64 {
        self.t_ar * tokens as f64
    }

    pub fn modeled_speedup(&self, m: &Metrics, host_overhead_s: f64) -> f64 {
        let t = self.modeled_time(m, host_overhead_s);
        if t <= 0.0 {
            return 0.0;
        }
        self.vanilla_time(m.new_tokens) / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_counts_tokens_per_cycle() {
        let mut m = Metrics::default();
        m.record_cycle(3, 4); // 3 accepted + bonus
        m.record_cycle(0, 1); // nothing accepted, bonus only
        assert!((m.tau() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn alpha_semantics() {
        let mut m = Metrics::default();
        // cycle 1: accepted depth 2 (steps 0,1 accepted; step 2 reached+rejected)
        m.record_cycle(2, 3);
        // cycle 2: accepted depth 0 (step 0 reached+rejected)
        m.record_cycle(0, 1);
        assert!((m.alpha(0) - 0.5).abs() < 1e-12);
        assert!((m.alpha(1) - 1.0).abs() < 1e-12);
        assert_eq!(m.alpha(2), 0.0);
        assert_eq!(m.reached[2], 1);
    }

    #[test]
    fn merge_adds() {
        let mut a = Metrics::default();
        a.record_cycle(1, 2);
        let mut b = Metrics::default();
        b.record_cycle(3, 4);
        a.merge(&b);
        assert_eq!(a.cycles, 2);
        assert_eq!(a.new_tokens, 6);
    }

    #[test]
    fn merged_aggregates_many() {
        let mut a = Metrics::default();
        a.record_cycle(1, 2);
        let mut b = Metrics::default();
        b.record_cycle(3, 4);
        let m = Metrics::merged([&a, &b]);
        assert_eq!(m.cycles, 2);
        assert_eq!(m.new_tokens, 6);
        assert!((m.tau() - 3.0).abs() < 1e-12);
        // empty merge is the identity (tau finite at 0)
        let empty = Metrics::merged(std::iter::empty());
        assert_eq!(empty.cycles, 0);
        assert_eq!(empty.tau(), 0.0);
    }

    #[test]
    fn cost_model_speedup_grows_with_tau() {
        let cm = CostModel { t_ar: 0.01, verify_factor: 1.0, draft_ratio: 0.1 };
        let mut fast = Metrics::default();
        fast.target_calls = 10;
        fast.draft_calls = 60;
        fast.new_tokens = 50; // tau 5
        let mut slow = Metrics::default();
        slow.target_calls = 25;
        slow.draft_calls = 150;
        slow.new_tokens = 50; // tau 2
        assert!(cm.modeled_speedup(&fast, 0.0) > cm.modeled_speedup(&slow, 0.0));
        // vanilla == 1.0x: one target call per token, no drafts
        let mut v = Metrics::default();
        v.target_calls = 50;
        v.new_tokens = 50;
        let s = cm.modeled_speedup(&v, 0.0);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
