//! Engine: method factory, suite runner, device-cost calibration.

pub mod metrics;
pub mod sessions;

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::Runtime;
use crate::sampling::SampleParams;
use crate::spec::eagle::{build_eagle, TreeKind};
use crate::spec::lookup::{Lookup, LookupKind};
use crate::spec::medusa::Medusa;
use crate::spec::sps::Sps;
use crate::spec::vanilla::Vanilla;
use crate::spec::{GenOutput, GenRequest, Method, MethodCfg};
use crate::tokenizer;
use crate::util::stats::{summarize, Stopwatch, Summary};

pub use metrics::{CostModel, Metrics};

/// Method names of the paper's comparison set (Tables 1/2 order).
pub const PAPER_METHODS: &[&str] = &[
    "pld", "lookahead", "sps", "medusa", "eagle", "eagle2", "hass",
];

/// Methods that need no `Runtime` (no artifacts, no compiled graphs).
/// The scheduler uses this to serve e.g. `mock` jobs even on hosts whose
/// runtime init failed; `build_method` delegates here first.
pub fn build_free_method(name: &str) -> Option<Box<dyn Method>> {
    match name {
        "mock" => Some(Box::new(crate::spec::mock::Mock)),
        _ => None,
    }
}

/// Build a method by name.  `eagle:<ckpt>` / `eagle2:<ckpt>` /
/// `hass:<ckpt>` select an ablation draft checkpoint with the base
/// method's tree kind.
pub fn build_method(rt: &Rc<Runtime>, name: &str, cfg: &MethodCfg) -> Result<Box<dyn Method>> {
    if let Some(m) = build_free_method(name) {
        return Ok(m);
    }
    let target_w = rt.checkpoint("target")?;
    // `kind` is authoritative from here on: the old code discarded it and
    // re-derived the tree from `name == "eagle"`, which silently gave
    // `eagle:<ckpt>`-style ablations a dynamic tree
    let (kind, ckpt_name, label): (TreeKind, String, String) = match name {
        "vanilla" => return Ok(Box::new(Vanilla::new(rt.clone(), target_w)?)),
        "sps" => {
            return Ok(Box::new(Sps::new(
                rt.clone(),
                target_w,
                rt.checkpoint("sps")?,
                cfg.gamma,
            )?))
        }
        "pld" => {
            return Ok(Box::new(Lookup::new(
                rt.clone(),
                target_w,
                LookupKind::Pld,
                cfg.lookup_len,
            )?))
        }
        "lookahead" => {
            return Ok(Box::new(Lookup::new(
                rt.clone(),
                target_w,
                LookupKind::Lookahead,
                cfg.lookup_len,
            )?))
        }
        "medusa" => {
            return Ok(Box::new(Medusa::new(
                rt.clone(),
                target_w,
                rt.checkpoint("medusa")?,
            )?))
        }
        "eagle" => (TreeKind::Static, "eagle".into(), "eagle".into()),
        "eagle2" => (TreeKind::Dynamic, "eagle".into(), "eagle2".into()),
        "hass" => (TreeKind::Dynamic, cfg.draft_ckpt.clone(), "hass".into()),
        other => {
            // "<base>:<ckpt>" — ablation checkpoints with base decoding
            if let Some((base, ck)) = other.split_once(':') {
                match base {
                    "eagle" => (TreeKind::Static, ck.to_string(), other.to_string()),
                    "eagle2" | "hass" => (TreeKind::Dynamic, ck.to_string(), other.to_string()),
                    _ => bail!("unknown method '{other}'"),
                }
            } else {
                bail!("unknown method '{other}'")
            }
        }
    };
    Ok(Box::new(build_eagle(
        rt.clone(),
        target_w,
        rt.checkpoint(&ckpt_name)?,
        kind,
        &label,
        cfg.depth,
        cfg.beam,
        cfg.total_tokens,
    )?))
}

/// Aggregated result of running one method over a prompt suite.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub method: String,
    pub suite: String,
    pub n_prompts: usize,
    pub tau: f64,
    pub alphas: Vec<f64>,
    pub wall_s: f64,
    pub tokens: usize,
    pub metrics: Metrics,
    pub latency: Summary,
    /// measured tokens/second
    pub tok_per_s: f64,
}

pub fn run_suite(
    method: &mut dyn Method,
    suite_name: &str,
    prompts: &[String],
    max_new: usize,
    params: &SampleParams,
) -> Result<SuiteResult> {
    let mut total = Metrics::default();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let sw = Stopwatch::start();
    for (i, p) in prompts.iter().enumerate() {
        let req = GenRequest {
            prompt_tokens: tokenizer::encode(p, true),
            max_new,
            params: SampleParams { seed: params.seed ^ (i as u64).wrapping_mul(0x9E37), ..*params },
        };
        let lsw = Stopwatch::start();
        let out = method.generate(&req)?;
        latencies.push(lsw.secs());
        tokens += out.tokens.len();
        total.merge(&out.metrics);
    }
    let wall = sw.secs();
    Ok(SuiteResult {
        method: method.name(),
        suite: suite_name.to_string(),
        n_prompts: prompts.len(),
        tau: total.tau(),
        alphas: total.alphas(8),
        wall_s: wall,
        tokens,
        metrics: total,
        latency: summarize(&latencies),
        // guard the divide: an empty/instant suite must report 0, not inf/NaN
        tok_per_s: if wall > 0.0 { tokens as f64 / wall } else { 0.0 },
    })
}

/// Run a single generation and return (text, output).
pub fn generate_once(
    rt: &Rc<Runtime>,
    method_name: &str,
    cfg: &MethodCfg,
    prompt: &str,
    max_new: usize,
    params: &SampleParams,
) -> Result<(String, GenOutput)> {
    let mut m = build_method(rt, method_name, cfg)?;
    let req = GenRequest { prompt_tokens: tokenizer::encode(prompt, true), max_new, params: *params };
    let out = m.generate(&req)?;
    Ok((tokenizer::decode(&out.tokens), out))
}

/// Calibrate the cost model: measure the mean wall time of a target AR
/// step on this machine (the paper-regime device model prices verify ≈ AR).
pub fn calibrate(rt: &Rc<Runtime>, steps: usize) -> Result<CostModel> {
    let mut v = Vanilla::new(rt.clone(), rt.checkpoint("target")?)?;
    let req = GenRequest {
        prompt_tokens: tokenizer::encode("User: calibrate the device model please\nAssistant:", true),
        max_new: steps.max(8),
        params: SampleParams { temperature: 0.0, ..Default::default() },
    };
    let sw = Stopwatch::start();
    let out = v.generate(&req)?;
    let t_ar = sw.secs() / out.tokens.len().max(1) as f64;
    Ok(CostModel { t_ar, ..Default::default() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_methods_build_without_a_runtime() {
        let mut m = build_free_method("mock").expect("mock is runtime-free");
        assert_eq!(m.name(), "mock");
        let req = GenRequest {
            prompt_tokens: vec![1],
            max_new: 5,
            params: SampleParams::default(),
        };
        let out = m.generate(&req).unwrap();
        assert_eq!(out.tokens.len(), 5);
        // real methods still require a runtime
        assert!(build_free_method("hass").is_none());
        assert!(build_free_method("vanilla").is_none());
    }
}
