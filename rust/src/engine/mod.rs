//! Engine: method factory, suite runner, device-cost calibration.

pub mod metrics;
pub mod sessions;

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::Runtime;
use crate::sampling::SampleParams;
use crate::spec::eagle::{build_eagle, TreeKind};
use crate::spec::lookup::{Lookup, LookupKind};
use crate::spec::medusa::Medusa;
use crate::spec::sps::Sps;
use crate::spec::vanilla::Vanilla;
use crate::spec::{GenOutput, GenRequest, Method, MethodCfg};
use crate::tokenizer;
use crate::util::stats::{summarize, Stopwatch, Summary};

pub use metrics::{CostModel, Metrics};

/// Method names of the paper's comparison set (Tables 1/2 order).
pub const PAPER_METHODS: &[&str] = &[
    "pld", "lookahead", "sps", "medusa", "eagle", "eagle2", "hass",
];

/// Build a method by name.  `eagle2:<ckpt>` / `hass:<ckpt>` select an
/// ablation draft checkpoint with EAGLE-2 decoding.
pub fn build_method(rt: &Rc<Runtime>, name: &str, cfg: &MethodCfg) -> Result<Box<dyn Method>> {
    let target_w = rt.checkpoint("target")?;
    let (kind, ckpt_name, label): (Option<TreeKind>, String, String) = match name {
        "vanilla" => return Ok(Box::new(Vanilla::new(rt.clone(), target_w)?)),
        "sps" => {
            return Ok(Box::new(Sps::new(
                rt.clone(),
                target_w,
                rt.checkpoint("sps")?,
                cfg.gamma,
            )?))
        }
        "pld" => {
            return Ok(Box::new(Lookup::new(
                rt.clone(),
                target_w,
                LookupKind::Pld,
                cfg.lookup_len,
            )?))
        }
        "lookahead" => {
            return Ok(Box::new(Lookup::new(
                rt.clone(),
                target_w,
                LookupKind::Lookahead,
                cfg.lookup_len,
            )?))
        }
        "medusa" => {
            return Ok(Box::new(Medusa::new(
                rt.clone(),
                target_w,
                rt.checkpoint("medusa")?,
            )?))
        }
        "eagle" => (Some(TreeKind::Static), "eagle".into(), "eagle".into()),
        "eagle2" => (Some(TreeKind::Dynamic), "eagle".into(), "eagle2".into()),
        "hass" => (Some(TreeKind::Dynamic), cfg.draft_ckpt.clone(), "hass".into()),
        other => {
            // "eagle2:<ckpt>" or "hass:<ckpt>" — ablation checkpoints
            if let Some((base, ck)) = other.split_once(':') {
                if base == "eagle2" || base == "hass" {
                    (Some(TreeKind::Dynamic), ck.to_string(), other.to_string())
                } else {
                    bail!("unknown method '{other}'")
                }
            } else {
                bail!("unknown method '{other}'")
            }
        }
    };
    let _ = kind;
    Ok(Box::new(build_eagle(
        rt.clone(),
        target_w,
        rt.checkpoint(&ckpt_name)?,
        if name == "eagle" { TreeKind::Static } else { TreeKind::Dynamic },
        &label,
        cfg.depth,
        cfg.beam,
        cfg.total_tokens,
    )?))
}

/// Aggregated result of running one method over a prompt suite.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub method: String,
    pub suite: String,
    pub n_prompts: usize,
    pub tau: f64,
    pub alphas: Vec<f64>,
    pub wall_s: f64,
    pub tokens: usize,
    pub metrics: Metrics,
    pub latency: Summary,
    /// measured tokens/second
    pub tok_per_s: f64,
}

pub fn run_suite(
    method: &mut dyn Method,
    suite_name: &str,
    prompts: &[String],
    max_new: usize,
    params: &SampleParams,
) -> Result<SuiteResult> {
    let mut total = Metrics::default();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let sw = Stopwatch::start();
    for (i, p) in prompts.iter().enumerate() {
        let req = GenRequest {
            prompt_tokens: tokenizer::encode(p, true),
            max_new,
            params: SampleParams { seed: params.seed ^ (i as u64).wrapping_mul(0x9E37), ..*params },
        };
        let lsw = Stopwatch::start();
        let out = method.generate(&req)?;
        latencies.push(lsw.secs());
        tokens += out.tokens.len();
        total.merge(&out.metrics);
    }
    let wall = sw.secs();
    Ok(SuiteResult {
        method: method.name(),
        suite: suite_name.to_string(),
        n_prompts: prompts.len(),
        tau: total.tau(),
        alphas: total.alphas(8),
        wall_s: wall,
        tokens,
        metrics: total,
        latency: summarize(&latencies),
        tok_per_s: tokens as f64 / wall,
    })
}

/// Run a single generation and return (text, output).
pub fn generate_once(
    rt: &Rc<Runtime>,
    method_name: &str,
    cfg: &MethodCfg,
    prompt: &str,
    max_new: usize,
    params: &SampleParams,
) -> Result<(String, GenOutput)> {
    let mut m = build_method(rt, method_name, cfg)?;
    let req = GenRequest { prompt_tokens: tokenizer::encode(prompt, true), max_new, params: *params };
    let out = m.generate(&req)?;
    Ok((tokenizer::decode(&out.tokens), out))
}

/// Calibrate the cost model: measure the mean wall time of a target AR
/// step on this machine (the paper-regime device model prices verify ≈ AR).
pub fn calibrate(rt: &Rc<Runtime>, steps: usize) -> Result<CostModel> {
    let mut v = Vanilla::new(rt.clone(), rt.checkpoint("target")?)?;
    let req = GenRequest {
        prompt_tokens: tokenizer::encode("User: calibrate the device model please\nAssistant:", true),
        max_new: steps.max(8),
        params: SampleParams { temperature: 0.0, ..Default::default() },
    };
    let sw = Stopwatch::start();
    let out = v.generate(&req)?;
    let t_ar = sw.secs() / out.tokens.len().max(1) as f64;
    Ok(CostModel { t_ar, ..Default::default() })
}
