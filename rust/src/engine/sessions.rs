//! Per-request model sessions: thin, stateful wrappers over the AOT graphs.
//!
//! A session owns the host-side KV cache and the argument plumbing for one
//! model (target GPT, EAGLE/HASS draft net, SpS tiny LM, Medusa heads).
//! All graph outputs come back as host tensors; the engine layers the
//! speculative policies (spec/) on top.
//!
//! The runtime/checkpoint handles stay per-thread `Rc` (each worker owns
//! its compiled graphs), but the KV pages underneath every session are
//! pool-shared `Arc<Page>` (see `kvcache`): fused packs here may stage
//! pages first absorbed on ANOTHER worker, and COW in `page_mut` keeps a
//! write on one worker from ever reaching a peer's image.

use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::kvcache::audit;
use crate::kvcache::{draft_page_size, FusedScratch, KvCache, MemberVis, PackMember, PackedLayout};
use crate::runtime::{scalar_i32, Checkpoint, Runtime, TensorF, TensorI};
use crate::spec::{DraftRows, VerifyRows};
use crate::util::failpoint;

/// Compiled decode-block widths, ascending (see `python/compile/aot.py`).
pub const BLOCK_WIDTHS: &[usize] = &[1, 8, 64, 128];

/// Largest compiled decode-block width.
pub const MAX_BLOCK: usize = 128;

/// Pick the smallest compiled decode-block width that fits `n` rows.
/// Row sets beyond the largest artifact are CHUNKED by the caller (see
/// [`plan_chunks`]), so this clamps to [`MAX_BLOCK`] instead of failing —
/// a wide tree + long γ must degrade to extra calls, not kill the job.
pub fn pick_block(n: usize) -> usize {
    for &cand in BLOCK_WIDTHS {
        if n <= cand {
            return cand;
        }
    }
    MAX_BLOCK
}

/// Split an oversized row set into chunk sizes, each fitting width `w`
/// (all but the last are `w`).
pub fn chunks_of(n: usize, w: usize) -> Vec<usize> {
    let w = w.max(1);
    let mut out = Vec::with_capacity(n / w + 1);
    let mut left = n;
    while left > w {
        out.push(w);
        left -= w;
    }
    out.push(left);
    out
}

/// Split an oversized row set into chunk sizes, each fitting a compiled
/// target width (all but the last are `MAX_BLOCK`).
pub fn plan_chunks(n: usize) -> Vec<usize> {
    chunks_of(n, MAX_BLOCK)
}

/// Smallest width in `widths` (ascending) that fits `n` rows; `None` when
/// `n` exceeds every compiled artifact (callers chunk, see [`chunks_of`]).
pub fn pick_width(widths: &[usize], n: usize) -> Option<usize> {
    widths.iter().copied().find(|&w| n <= w)
}

/// Cache slots a (possibly chunked) decode of `n` rows actually consumes:
/// every chunk is padded to a compiled width, so this is what capacity
/// checks must compare against `remaining()` — comparing against `n`
/// alone lets a session reach a boundary where the padded call no longer
/// fits and errors instead of finishing gracefully.
pub fn padded_span(n: usize) -> usize {
    if n <= MAX_BLOCK {
        return pick_block(n);
    }
    match n % MAX_BLOCK {
        0 => n,
        rem => (n / MAX_BLOCK) * MAX_BLOCK + pick_block(rem),
    }
}

fn call(
    rt: &Runtime,
    graph: &str,
    weights: &[Literal],
    extra_weights: &[&Literal],
    inputs: &[Literal],
) -> Result<Vec<Literal>> {
    let mut args: Vec<&Literal> = Vec::with_capacity(weights.len() + extra_weights.len() + inputs.len());
    args.extend(weights.iter());
    args.extend(extra_weights.iter().copied());
    args.extend(inputs.iter());
    rt.call(graph, &args)
}

fn tensor_out(lits: &[Literal], i: usize) -> Result<TensorF> {
    TensorF::from_literal(lits.get(i).context("missing graph output")?)
}

/// Output of a decode/verify call.
pub struct DecodeOut {
    /// [N, V] logits
    pub logits: TensorF,
    /// [N, d] post-LN features
    pub feats: TensorF,
}

// ---------------------------------------------------------------------------
// target GPT session
// ---------------------------------------------------------------------------

pub struct TargetSession {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    pub cache: KvCache,
    pub slots: usize,
    pub vocab: usize,
    pub d_model: usize,
    /// features of the committed sequence, one row per committed token
    /// (needed as draft inputs); grown incrementally.
    pub feats: Vec<Vec<f32>>,
}

impl TargetSession {
    pub fn new(rt: Rc<Runtime>, weights: Rc<Checkpoint>) -> Result<TargetSession> {
        let (slots, layers, heads, d_model, vocab) = {
            let m = rt.meta();
            (m.cache_slots(), m.dim("target", "n_layers"),
             m.dim("target", "n_heads"), m.dim("target", "d_model"),
             m.dim("target", "vocab"))
        };
        let hd = d_model / heads.max(1);
        Ok(TargetSession {
            rt,
            weights,
            cache: KvCache::new(layers, slots, heads, hd),
            slots,
            vocab,
            d_model,
            feats: Vec::new(),
        })
    }

    pub fn reset(&mut self) {
        self.cache.reset();
        self.feats.clear();
    }

    /// Prefill the prompt; returns the logits row at the last prompt token.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.is_empty() || tokens.len() > self.slots {
            bail!("prompt length {} out of range", tokens.len());
        }
        let mut padded = vec![0i32; self.slots];
        padded[..tokens.len()].copy_from_slice(tokens);
        let inp = TensorI::new(vec![self.slots], padded)?.to_literal()?;
        let out = call(&self.rt, "target_prefill", &self.weights.literals, &[], &[inp])?;
        let feats = tensor_out(&out, 0)?;
        let kv_k = tensor_out(&out, 1)?;
        let kv_v = tensor_out(&out, 2)?;
        let logits = tensor_out(&out, 3)?;
        self.cache.absorb(kv_k, kv_v, tokens.len())?;
        self.cache.committed = tokens.len();
        self.feats = (0..tokens.len()).map(|i| feats.row(i).to_vec()).collect();
        Ok(logits.row(tokens.len() - 1).to_vec())
    }

    /// Verify/decode a block of `tokens` (chain or tree).  `positions` are
    /// absolute sequence positions; `block_anc` is the intra-block ancestor
    /// mask (None = chain).  Returns per-row logits + features; KV rows are
    /// written at the committed boundary (commit/compact is the caller's
    /// decision).
    ///
    /// Row sets wider than the largest compiled artifact are chunked into
    /// several calls: chunk c's rows are written at `committed + c *
    /// MAX_BLOCK`, and since block row b always lands at slot `committed +
    /// b`, later chunks see earlier chunks' rows through the same
    /// row→slot mapping — the concatenated outputs are exactly those of a
    /// hypothetical single wide call.
    pub fn decode(
        &mut self,
        tokens: &[i32],
        positions: &[usize],
        block_anc: Option<&[Vec<bool>]>,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        if n <= MAX_BLOCK {
            return self.decode_at(tokens, positions, block_anc, 0);
        }
        let mut logits = Vec::with_capacity(n * self.vocab);
        let mut feats = Vec::new();
        let mut feat_w = 1usize;
        let mut off = 0usize;
        for take in plan_chunks(n) {
            let out = self.decode_at(
                &tokens[off..off + take],
                &positions[off..off + take],
                block_anc,
                off,
            )?;
            for r in 0..take {
                logits.extend_from_slice(out.logits.row(r));
                feats.extend_from_slice(out.feats.row(r));
            }
            feat_w = out.feats.dims[1];
            off += take;
        }
        Ok(DecodeOut {
            logits: TensorF::new(vec![n, self.vocab], logits)?,
            feats: TensorF::new(vec![n, feat_w], feats)?,
        })
    }

    /// One compiled decode call over `tokens` (≤ MAX_BLOCK rows), written
    /// at `committed + base`.  `block_anc` rows are indexed by ABSOLUTE
    /// block row (this chunk's row i is block row `base + i`), so a
    /// chunked tree mask can reference earlier chunks' rows.
    fn decode_at(
        &mut self,
        tokens: &[i32],
        positions: &[usize],
        block_anc: Option<&[Vec<bool>]>,
        base: usize,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        let nb = pick_block(n);
        let c = self.cache.committed;
        if c + base + nb > self.slots {
            bail!("target cache exhausted ({c} + {base} + {nb} > {})", self.slots);
        }
        // pad rows to the block width
        let mut tok = vec![0i32; nb];
        tok[..n].copy_from_slice(tokens);
        let mut pos = vec![0i32; nb];
        for (i, &p) in positions.iter().enumerate() {
            pos[i] = p as i32;
        }
        // visibility: committed prefix + in-block ancestors at slot
        // `committed + block_row`; padding rows see nothing (the masked
        // attention returns zeros for them, and their KV is never read)
        let mut mask = vec![0i32; nb * self.slots];
        for i in 0..n {
            let a = base + i;
            let off = i * self.slots;
            for s in 0..c {
                mask[off + s] = 1;
            }
            match block_anc {
                Some(anc) => {
                    // valid ancestor masks only reference earlier rows
                    // (BFS order), so b <= a keeps every slot in range
                    for (b, &vis) in anc[a].iter().enumerate().take(a + 1) {
                        if vis {
                            mask[off + c + b] = 1;
                        }
                    }
                }
                None => {
                    for b in 0..=a {
                        mask[off + c + b] = 1;
                    }
                }
            }
        }
        failpoint::fire(failpoint::TARGET_DECODE)?;
        let graph = format!("target_decode_n{nb}");
        // borrow the incrementally synced image (O(changed pages), no
        // full-buffer clone per call) just long enough to build literals
        let dims = [self.cache.layers, self.cache.slots, self.cache.heads, self.cache.head_dim];
        let (kv_k, kv_v) = {
            let (ik, iv) = self.cache.sync_image();
            (
                crate::runtime::tensor::f32_literal(&dims, ik)?,
                crate::runtime::tensor::f32_literal(&dims, iv)?,
            )
        };
        let out = call(
            &self.rt,
            &graph,
            &self.weights.literals,
            &[],
            &[
                kv_k,
                kv_v,
                scalar_i32((c + base) as i32),
                TensorI::new(vec![nb], tok)?.to_literal()?,
                TensorI::new(vec![nb], pos)?.to_literal()?,
                TensorI { dims: vec![nb, self.slots], data: mask }.to_literal()?,
            ],
        )?;
        self.rt.record_rows(&graph, n);
        let logits = tensor_out(&out, 0)?;
        let feats = tensor_out(&out, 1)?;
        // the graph only writes the nb block rows at c + base; scatter
        // exactly those back instead of replacing the whole paged cache
        let new_k = tensor_out(&out, 2)?;
        let new_v = tensor_out(&out, 3)?;
        self.cache.write_rows_from(&new_k, &new_v, c + base, c + base, nb)?;
        Ok(DecodeOut { logits, feats })
    }

    /// Commit block rows after acceptance (rows strictly increasing) and
    /// record their features as committed context.
    pub fn commit_rows(&mut self, rows: &[usize], feats: &TensorF) -> Result<()> {
        self.cache.compact_accepted(rows)?;
        for &r in rows {
            self.feats.push(feats.row(r).to_vec());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// fused cross-session verification
// ---------------------------------------------------------------------------

/// One fused target forward over several sessions' verification blocks.
///
/// Packs every member's committed KV pages and candidate rows into the
/// worker's persistent [`FusedScratch`] image (layout: [`PackedLayout`])
/// and runs a SINGLE compiled decode-block call with a block-diagonal
/// visibility mask — the graph is purely mask-driven (positions feed only
/// the positional embedding, the write pointer is an input scalar), so
/// relocating each member's pages to packed offsets is exact.  Packing is
/// O(changed pages): whole pages are memcpy'd, pages already staged from
/// a previous cycle (same `(id, stamp)`) are skipped, and a page shared
/// by several members (identical prompt prefix) occupies ONE fused
/// segment.  Afterwards the per-row logits/features are scattered back
/// per member, and each member's freshly written KV rows are copied into
/// its own cache at its own committed boundary — leaving every session
/// byte-identical to having run its solo `decode`.
///
/// All members must share one runtime (same worker thread), one target
/// checkpoint, and one cache geometry + page size; the caller is
/// responsible for grouping by capacity
/// (`(unique pages)·page_size + pick_block(Σ rows) <= slots`,
/// `Σ rows <= MAX_BLOCK`).
pub fn fused_decode(
    scratch: &mut FusedScratch,
    batch: &mut [(&mut TargetSession, &VerifyRows)],
) -> Result<Vec<DecodeOut>> {
    if batch.is_empty() {
        bail!("empty fused batch");
    }
    let rows_total: usize = batch.iter().map(|(_, r)| r.len()).sum();
    if rows_total > MAX_BLOCK {
        bail!("fused batch of {rows_total} rows exceeds the largest artifact ({MAX_BLOCK})");
    }
    let nb = pick_block(rows_total);
    let (layers, slots, heads, hd, page_size) = {
        let c = &batch[0].0.cache;
        (c.layers, c.slots, c.heads, c.head_dim, c.page_size())
    };
    for (t, _) in batch.iter() {
        if !Rc::ptr_eq(&t.weights, &batch[0].0.weights) {
            bail!("fused members must share one target checkpoint");
        }
        if t.cache.layers != layers
            || t.cache.slots != slots
            || t.cache.heads != heads
            || t.cache.head_dim != hd
            || t.cache.page_size() != page_size
        {
            bail!("fused members must share one cache geometry");
        }
    }

    // ---- pack: page handles -> layout -> incremental image assembly ----
    let mut handles = Vec::with_capacity(batch.len());
    let mut members = Vec::with_capacity(batch.len());
    for (t, r) in batch.iter_mut() {
        let pages = t.cache.committed_pages();
        members.push(PackMember {
            page_ids: pages.iter().map(|p| p.id()).collect(),
            prefix_len: t.cache.committed,
            rows: r.len(),
        });
        handles.push(pages);
    }
    let layout = PackedLayout::plan(&members, slots, page_size, nb)?;
    scratch.pack(&layout, &handles, layers, heads * hd)?;
    // release the page handles NOW: holding them through the scatter
    // would push every member's tail page to refcount > 1 and force a
    // whole-page COW on the per-row write below, every cycle
    drop(handles);

    let mut tok = vec![0i32; nb];
    let mut pos = vec![0i32; nb];
    for (j, (_, r)) in batch.iter().enumerate() {
        let off = layout.row_off[j];
        for i in 0..r.len() {
            tok[off + i] = r.tokens[i];
            pos[off + i] = r.positions[i] as i32;
        }
    }
    let ancs: Vec<Option<&[Vec<bool>]>> =
        batch.iter().map(|(_, r)| r.block_anc.as_deref()).collect();
    let mask = layout.mask(nb, &ancs)?;

    // ---- one graph call for every member ----
    failpoint::fire(failpoint::TARGET_DECODE)?;
    let rt = &batch[0].0.rt;
    let graph = format!("target_decode_n{nb}");
    let out = call(
        rt,
        &graph,
        &batch[0].0.weights.literals,
        &[],
        &[
            crate::runtime::tensor::f32_literal(&[layers, slots, heads, hd], scratch.k())?,
            crate::runtime::tensor::f32_literal(&[layers, slots, heads, hd], scratch.v())?,
            scalar_i32(layout.base as i32),
            TensorI::new(vec![nb], tok)?.to_literal()?,
            TensorI::new(vec![nb], pos)?.to_literal()?,
            mask.to_literal()?,
        ],
    )?;
    rt.record_rows(&graph, rows_total);
    let logits = tensor_out(&out, 0)?;
    let feats = tensor_out(&out, 1)?;
    let new_k = tensor_out(&out, 2)?;
    let new_v = tensor_out(&out, 3)?;

    // ---- scatter: per-member outputs + KV rows ----
    let vocab = logits.dims[1];
    let d = feats.dims[1];
    let mut outs = Vec::with_capacity(batch.len());
    for (j, (t, r)) in batch.iter_mut().enumerate() {
        let off = layout.row_off[j];
        let n_j = r.len();
        let mut lj = Vec::with_capacity(n_j * vocab);
        let mut fj = Vec::with_capacity(n_j * d);
        for i in 0..n_j {
            lj.extend_from_slice(logits.row(off + i));
            fj.extend_from_slice(feats.row(off + i));
        }
        let dst = t.cache.committed;
        t.cache.write_rows_from(&new_k, &new_v, layout.base + off, dst, n_j)?;
        audit::check_scatter(&mut t.cache, &new_k, &new_v, layout.base + off, dst, n_j);
        outs.push(DecodeOut {
            logits: TensorF::new(vec![n_j, vocab], lj)?,
            feats: TensorF::new(vec![n_j, d], fj)?,
        });
    }
    Ok(outs)
}

// ---------------------------------------------------------------------------
// EAGLE/HASS draft session
// ---------------------------------------------------------------------------

pub struct DraftSession {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    /// target checkpoint identity (the draft decodes through the target's
    /// LM head); fused batches must share it
    pub target_weights: Rc<Checkpoint>,
    /// target wte literal
    pub wte: Literal,
    /// Paged single-layer KV cache (PR 5) — the same COW pages, `(id,
    /// stamp)` identity and content-addressed prompt dedup the target
    /// cache uses, so draft pages are packable exactly like target pages.
    /// Solo decodes borrow the incrementally synced image
    /// (`sync_image`: O(changed pages) per call) and scatter back only
    /// the written rows; the committed prefix advances with `commit`,
    /// tree-scratch rows live above it and are simply overwritten next
    /// cycle (masks never expose stale slots).
    pub cache: KvCache,
    pub slots: usize,
    pub vocab: usize,
    pub d_model: usize,
    /// compiled draft decode-block widths, ascending — derived from the
    /// artifact metadata (`draft_decode_b{N}` graph inventory), not
    /// hardcoded
    widths: Vec<usize>,
    /// largest compiled draft width (per-level expansion cap; oversized
    /// row sets are CHUNKED across several calls, not rejected)
    pub block: usize,
}

impl DraftSession {
    pub fn new(
        rt: Rc<Runtime>,
        weights: Rc<Checkpoint>,
        target: &Rc<Checkpoint>,
    ) -> Result<DraftSession> {
        let (slots, d_model, heads, vocab) = {
            let m = rt.meta();
            (m.cache_slots(), m.dim("draft", "d_model"),
             m.dim("draft", "n_heads"), m.dim("draft", "vocab"))
        };
        let heads = heads.max(1);
        let hd = d_model / heads;
        let wte = target
            .tensor("['wte']")
            .context("target checkpoint missing wte")?
            .to_literal()?;
        // available decode widths come from the artifact inventory; the
        // seed compile ships b10 only, so that stays the fallback when the
        // metadata lists no draft decode graphs at all
        let mut widths: Vec<usize> = rt
            .meta()
            .graphs
            .keys()
            .filter_map(|g| g.strip_prefix("draft_decode_b").and_then(|s| s.parse().ok()))
            .filter(|&w: &usize| w > 0)
            .collect();
        widths.sort_unstable();
        widths.dedup();
        if widths.is_empty() {
            widths.push(10);
        }
        let block = widths.last().copied().unwrap_or(10);
        Ok(DraftSession {
            rt,
            weights,
            target_weights: target.clone(),
            wte,
            cache: KvCache::with_page_size(1, slots, heads, hd, draft_page_size()),
            slots,
            vocab,
            d_model,
            widths,
            block,
        })
    }

    /// Compiled draft decode-block widths, ascending.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    pub fn reset(&mut self) {
        self.cache.reset();
    }

    pub fn committed(&self) -> usize {
        self.cache.committed
    }

    pub fn remaining(&self) -> usize {
        self.cache.remaining()
    }

    pub fn commit(&mut self, n: usize) -> Result<()> {
        self.cache.commit(n).context("draft cache overflow")
    }

    /// Prefill: prompt tokens + target features (unshifted).  The KV pages
    /// route through the content-addressed dedup registry, so sessions
    /// prefilled with an identical prompt share physical draft pages.
    pub fn prefill(&mut self, tokens: &[i32], target_feats: &[Vec<f32>]) -> Result<()> {
        if tokens.is_empty() || tokens.len() > self.slots {
            bail!("draft prompt length {} out of range", tokens.len());
        }
        let mut padded = vec![0i32; self.slots];
        padded[..tokens.len()].copy_from_slice(tokens);
        let mut tf = vec![0.0f32; self.slots * self.d_model];
        for (i, row) in target_feats.iter().enumerate().take(tokens.len()) {
            tf[i * self.d_model..(i + 1) * self.d_model].copy_from_slice(row);
        }
        let out = call(
            &self.rt,
            "draft_prefill",
            &self.weights.literals,
            &[&self.wte],
            &[
                TensorI::new(vec![self.slots], padded)?.to_literal()?,
                TensorF::new(vec![self.slots, self.d_model], tf)?.to_literal()?,
            ],
        )?;
        let kv_k = tensor_out(&out, 0)?;
        let kv_v = tensor_out(&out, 1)?;
        self.cache.absorb(kv_k, kv_v, tokens.len())?;
        self.cache.committed = tokens.len();
        Ok(())
    }

    /// One draft forward over `rows` as produced by a method's draft walk.
    pub fn decode_rows(&mut self, rows: &DraftRows) -> Result<DecodeOut> {
        let feats: Vec<&[f32]> = rows.feats.iter().map(|f| f.as_slice()).collect();
        self.decode(&rows.tokens, &feats, &rows.positions, &rows.extra_visible, rows.write_start)
    }

    /// One draft forward over any number of rows.
    ///
    /// `rows`: (token, input-feature, position, visible-slots) per row; KV
    /// rows are written at `write_start` (contiguous).  `extra_visible[i]`
    /// lists visible slots beyond the committed prefix (tree ancestors —
    /// absolute cache slots; slots of earlier rows of this same call are
    /// legal, the graph updates the cache before attending); every row
    /// also sees its own slot.
    ///
    /// Row sets wider than the largest compiled artifact are CHUNKED into
    /// several calls (the old code bailed, killing EAGLE-2 jobs with
    /// `beam > block`): chunk c's rows land at `write_start + c·block`,
    /// and since each chunk scatters its KV rows back before the next
    /// call, later chunks see earlier chunks' rows through the same
    /// absolute slots — the concatenated outputs equal one wide call's.
    pub fn decode(
        &mut self,
        tokens: &[i32],
        in_feats: &[&[f32]],
        positions: &[usize],
        extra_visible: &[Vec<usize>],
        write_start: usize,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        if n == 0 {
            bail!("empty draft decode");
        }
        if n <= self.block {
            return self.decode_at(tokens, in_feats, positions, extra_visible, write_start);
        }
        let mut logits = Vec::with_capacity(n * self.vocab);
        let mut g = Vec::new();
        let mut g_w = 1usize;
        let mut off = 0usize;
        for take in chunks_of(n, self.block) {
            let out = self.decode_at(
                &tokens[off..off + take],
                &in_feats[off..off + take],
                &positions[off..off + take],
                &extra_visible[off..off + take],
                write_start + off,
            )?;
            for r in 0..take {
                logits.extend_from_slice(out.logits.row(r));
                g.extend_from_slice(out.feats.row(r));
            }
            g_w = out.feats.dims[1];
            off += take;
        }
        Ok(DecodeOut {
            logits: TensorF::new(vec![n, self.vocab], logits)?,
            feats: TensorF::new(vec![n, g_w], g)?,
        })
    }

    /// One compiled draft call over ≤ `block` rows at `write_start`.
    fn decode_at(
        &mut self,
        tokens: &[i32],
        in_feats: &[&[f32]],
        positions: &[usize],
        extra_visible: &[Vec<usize>],
        write_start: usize,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        let b = pick_width(&self.widths, n).context("draft rows exceed the chunk width")?;
        if write_start + b > self.slots {
            bail!("draft cache exhausted ({write_start} + {b} > {})", self.slots);
        }
        let mut tok = vec![0i32; b];
        tok[..n].copy_from_slice(tokens);
        let mut pos = vec![0i32; b];
        let mut feats = vec![0.0f32; b * self.d_model];
        for i in 0..n {
            pos[i] = positions[i] as i32;
            feats[i * self.d_model..(i + 1) * self.d_model].copy_from_slice(in_feats[i]);
        }
        let committed = self.cache.committed;
        let mut mask = vec![0i32; b * self.slots];
        for i in 0..n {
            let off = i * self.slots;
            for s in 0..committed {
                mask[off + s] = 1;
            }
            for &s in &extra_visible[i] {
                mask[off + s] = 1;
            }
            mask[off + write_start + i] = 1; // own slot
        }
        failpoint::fire(failpoint::DRAFT_DECODE)?;
        let graph = format!("draft_decode_b{b}");
        let dims = [self.slots, self.cache.heads, self.cache.head_dim];
        let (kv_k, kv_v) = {
            let (ik, iv) = self.cache.sync_image();
            (
                crate::runtime::tensor::f32_literal(&dims, ik)?,
                crate::runtime::tensor::f32_literal(&dims, iv)?,
            )
        };
        let inputs = [
            kv_k,
            kv_v,
            scalar_i32(write_start as i32),
            TensorI::new(vec![b], tok)?.to_literal()?,
            TensorF::new(vec![b, self.d_model], feats)?.to_literal()?,
            TensorI::new(vec![b], pos)?.to_literal()?,
            TensorI { dims: vec![b, self.slots], data: mask }.to_literal()?,
        ];
        let mut args: Vec<&Literal> = Vec::with_capacity(self.weights.literals.len() + 8);
        args.extend(self.weights.literals.iter());
        args.push(&self.wte);
        args.extend(inputs.iter());
        let out = self.rt.call(&graph, &args)?;
        self.rt.record_rows(&graph, n);
        let logits = tensor_out(&out, 0)?;
        let g = tensor_out(&out, 1)?;
        // scatter exactly the n real rows back (padding rows are never
        // visible, so they need not dirty pages)
        let new_k = tensor_out(&out, 2)?;
        let new_v = tensor_out(&out, 3)?;
        self.cache.write_rows_from(&new_k, &new_v, write_start, write_start, n)?;
        Ok(DecodeOut { logits, feats: g })
    }
}

// ---------------------------------------------------------------------------
// fused cross-session draft expansion
// ---------------------------------------------------------------------------

/// One fused draft forward over several sessions' same-level tree rows —
/// the draft-side mirror of [`fused_decode`].
///
/// Packs every member's draft pages covering `[0, write_start)` (committed
/// prefix AND the scratch tree rows written by earlier levels this cycle)
/// into the worker's persistent [`FusedScratch`] and runs ONE compiled
/// `draft_decode_b{w}` call over the concatenated rows.  Visibility is
/// composed sparsely ([`PackedLayout::mask_sparse`]): each row sees its
/// member's committed prefix, its listed ancestor slots (scratch slots map
/// through the member's page segments; same-call ancestors map into the
/// block region), and its own slot.  Outputs and fresh KV rows scatter
/// back per member at each member's own `write_start` — every session
/// ends byte-identical to having run its solo `decode`.
///
/// All members must share one runtime (same worker thread), one draft AND
/// target checkpoint, and one cache geometry + page size; the caller
/// groups by capacity (`(unique pages)·page_size + width <= slots`,
/// `Σ rows <=` widest artifact).
pub fn fused_draft_decode(
    scratch: &mut FusedScratch,
    batch: &mut [(&mut DraftSession, &DraftRows)],
) -> Result<Vec<DecodeOut>> {
    if batch.is_empty() {
        bail!("empty fused draft batch");
    }
    let rows_total: usize = batch.iter().map(|(_, r)| r.tokens.len()).sum();
    let widths = batch[0].0.widths.clone();
    let width = pick_width(&widths, rows_total).with_context(|| {
        format!("fused draft batch of {rows_total} rows exceeds the widest artifact")
    })?;
    let (slots, heads, hd, page_size, d_model) = {
        let d = &batch[0].0;
        (d.slots, d.cache.heads, d.cache.head_dim, d.cache.page_size(), d.d_model)
    };
    for (d, r) in batch.iter() {
        if !Rc::ptr_eq(&d.weights, &batch[0].0.weights)
            || !Rc::ptr_eq(&d.target_weights, &batch[0].0.target_weights)
        {
            bail!("fused draft members must share draft + target checkpoints");
        }
        if d.slots != slots
            || d.cache.heads != heads
            || d.cache.head_dim != hd
            || d.cache.page_size() != page_size
            || d.d_model != d_model
        {
            bail!("fused draft members must share one cache geometry");
        }
        let n = r.tokens.len();
        if n == 0 || r.positions.len() != n || r.feats.len() != n || r.extra_visible.len() != n {
            bail!("fused draft rows are empty or ragged");
        }
    }

    // ---- pack: pages up to each member's write_start ----
    let mut handles = Vec::with_capacity(batch.len());
    let mut members = Vec::with_capacity(batch.len());
    for (d, r) in batch.iter_mut() {
        let pages = d.cache.pages_covering(r.write_start);
        members.push(PackMember {
            page_ids: pages.iter().map(|p| p.id()).collect(),
            prefix_len: r.write_start,
            rows: r.tokens.len(),
        });
        handles.push(pages);
    }
    let layout = PackedLayout::plan(&members, slots, page_size, width)?;
    scratch.pack(&layout, &handles, 1, heads * hd)?;
    // release the handles before the per-member scatter below (held refs
    // would force whole-page COWs on every tail write)
    drop(handles);

    let mut tok = vec![0i32; width];
    let mut pos = vec![0i32; width];
    let mut feats = vec![0.0f32; width * d_model];
    for (j, (_, r)) in batch.iter().enumerate() {
        let off = layout.row_off[j];
        for i in 0..r.tokens.len() {
            tok[off + i] = r.tokens[i];
            pos[off + i] = r.positions[i] as i32;
            feats[(off + i) * d_model..(off + i + 1) * d_model].copy_from_slice(&r.feats[i]);
        }
    }
    let mask = {
        let vis: Vec<MemberVis> = batch
            .iter()
            .map(|(d, r)| MemberVis { committed: d.cache.committed, extra: &r.extra_visible })
            .collect();
        layout.mask_sparse(width, &vis)?
    };

    // ---- one graph call for every member's level ----
    failpoint::fire(failpoint::DRAFT_DECODE)?;
    let graph = format!("draft_decode_b{width}");
    let dims = [slots, heads, hd];
    let inputs = [
        crate::runtime::tensor::f32_literal(&dims, scratch.k())?,
        crate::runtime::tensor::f32_literal(&dims, scratch.v())?,
        scalar_i32(layout.base as i32),
        TensorI::new(vec![width], tok)?.to_literal()?,
        TensorF::new(vec![width, d_model], feats)?.to_literal()?,
        TensorI::new(vec![width], pos)?.to_literal()?,
        mask.to_literal()?,
    ];
    let out = {
        let first = &batch[0].0;
        let mut args: Vec<&Literal> = Vec::with_capacity(first.weights.literals.len() + 8);
        args.extend(first.weights.literals.iter());
        args.push(&first.wte);
        args.extend(inputs.iter());
        let out = first.rt.call(&graph, &args)?;
        first.rt.record_rows(&graph, rows_total);
        out
    };
    let logits = tensor_out(&out, 0)?;
    let g = tensor_out(&out, 1)?;
    let new_k = tensor_out(&out, 2)?;
    let new_v = tensor_out(&out, 3)?;

    // ---- scatter: per-member outputs + KV rows at each write_start ----
    let vocab = logits.dims[1];
    let gd = g.dims[1];
    let mut outs = Vec::with_capacity(batch.len());
    for (j, (d, r)) in batch.iter_mut().enumerate() {
        let off = layout.row_off[j];
        let n_j = r.tokens.len();
        let mut lj = Vec::with_capacity(n_j * vocab);
        let mut fj = Vec::with_capacity(n_j * gd);
        for i in 0..n_j {
            lj.extend_from_slice(logits.row(off + i));
            fj.extend_from_slice(g.row(off + i));
        }
        d.cache.write_rows_from(&new_k, &new_v, layout.base + off, r.write_start, n_j)?;
        audit::check_scatter(&mut d.cache, &new_k, &new_v, layout.base + off, r.write_start, n_j);
        outs.push(DecodeOut {
            logits: TensorF::new(vec![n_j, vocab], lj)?,
            feats: TensorF::new(vec![n_j, gd], fj)?,
        });
    }
    Ok(outs)
}

// ---------------------------------------------------------------------------
// SpS tiny-LM session (vanilla speculative sampling draft)
// ---------------------------------------------------------------------------

pub struct SpsSession {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    pub cache: KvCache,
    pub slots: usize,
    pub vocab: usize,
}

impl SpsSession {
    pub fn new(rt: Rc<Runtime>, weights: Rc<Checkpoint>) -> Result<SpsSession> {
        let (slots, d, heads, layers, vocab) = {
            let m = rt.meta();
            (m.cache_slots(), m.dim("sps", "d_model"), m.dim("sps", "n_heads"),
             m.dim("sps", "n_layers"), m.dim("sps", "vocab"))
        };
        Ok(SpsSession {
            rt,
            weights,
            cache: KvCache::new(layers, slots, heads, d / heads.max(1)),
            slots,
            vocab,
        })
    }

    pub fn reset(&mut self) {
        self.cache.reset();
    }

    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut padded = vec![0i32; self.slots];
        padded[..tokens.len()].copy_from_slice(tokens);
        let inp = TensorI::new(vec![self.slots], padded)?.to_literal()?;
        let out = call(&self.rt, "sps_prefill", &self.weights.literals, &[], &[inp])?;
        self.cache.absorb(tensor_out(&out, 1)?, tensor_out(&out, 2)?, tokens.len())?;
        self.cache.committed = tokens.len();
        let logits = tensor_out(&out, 3)?;
        Ok(logits.row(tokens.len() - 1).to_vec())
    }

    /// One AR step; writes the token's KV at `committed` and commits it.
    pub fn decode1(&mut self, token: i32, position: usize) -> Result<Vec<f32>> {
        let mask = self.cache.block_mask(1, None)?;
        let dims = [self.cache.layers, self.cache.slots, self.cache.heads, self.cache.head_dim];
        let (kv_k, kv_v) = {
            let (ik, iv) = self.cache.sync_image();
            (
                crate::runtime::tensor::f32_literal(&dims, ik)?,
                crate::runtime::tensor::f32_literal(&dims, iv)?,
            )
        };
        let out = call(
            &self.rt,
            "sps_decode_n1",
            &self.weights.literals,
            &[],
            &[
                kv_k,
                kv_v,
                scalar_i32(self.cache.committed as i32),
                TensorI::new(vec![1], vec![token])?.to_literal()?,
                TensorI::new(vec![1], vec![position as i32])?.to_literal()?,
                mask.to_literal()?,
            ],
        )?;
        self.rt.record_rows("sps_decode_n1", 1);
        let logits = tensor_out(&out, 0)?;
        let new_k = tensor_out(&out, 2)?;
        let new_v = tensor_out(&out, 3)?;
        let at = self.cache.committed;
        self.cache.write_rows_from(&new_k, &new_v, at, at, 1)?;
        self.cache.commit(1)?;
        Ok(logits.row(0).to_vec())
    }

    /// Roll back the last `n` committed rows (rejected chain suffix).
    pub fn rollback(&mut self, n: usize) {
        self.cache.committed = self.cache.committed.saturating_sub(n);
    }
}

// ---------------------------------------------------------------------------
// Medusa heads
// ---------------------------------------------------------------------------

pub struct MedusaHeads {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    pub wte: Literal,
    pub n_heads: usize,
    pub vocab: usize,
    pub d_model: usize,
}

impl MedusaHeads {
    pub fn new(rt: Rc<Runtime>, weights: Rc<Checkpoint>, target: &Rc<Checkpoint>) -> Result<MedusaHeads> {
        let (vocab, d_model) = {
            let m = rt.meta();
            (m.dim("target", "vocab"), m.dim("target", "d_model"))
        };
        let wte = target
            .tensor("['wte']")
            .context("target checkpoint missing wte")?
            .to_literal()?;
        Ok(MedusaHeads {
            rt,
            weights,
            wte,
            n_heads: 4,
            vocab,
            d_model,
        })
    }

    /// feat [d] -> per-head logits [n_heads][V].
    pub fn predict(&self, feat: &[f32]) -> Result<Vec<Vec<f32>>> {
        let inp = TensorF::new(vec![1, self.d_model], feat.to_vec())?.to_literal()?;
        let out = call(&self.rt, "medusa_heads", &self.weights.literals, &[&self.wte], &[inp])?;
        let logits = tensor_out(&out, 0)?; // [1, H, V]
        let v = self.vocab;
        Ok((0..self.n_heads)
            .map(|h| logits.data[h * v..(h + 1) * v].to_vec())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::{chunks_of, padded_span, pick_block, pick_width, plan_chunks, MAX_BLOCK};

    #[test]
    fn pick_width_finds_smallest_fit() {
        let widths = [4usize, 10, 40, 80];
        assert_eq!(pick_width(&widths, 1), Some(4));
        assert_eq!(pick_width(&widths, 4), Some(4));
        assert_eq!(pick_width(&widths, 5), Some(10));
        assert_eq!(pick_width(&widths, 40), Some(40));
        assert_eq!(pick_width(&widths, 41), Some(80));
        // beyond the widest artifact the caller must chunk
        assert_eq!(pick_width(&widths, 81), None);
        // the seed inventory (b10 only) still resolves
        assert_eq!(pick_width(&[10], 3), Some(10));
        assert_eq!(pick_width(&[10], 11), None);
    }

    #[test]
    fn chunks_of_covers_all_rows_at_any_width() {
        assert_eq!(chunks_of(25, 10), vec![10, 10, 5]);
        assert_eq!(chunks_of(10, 10), vec![10]);
        assert_eq!(chunks_of(11, 10), vec![10, 1]);
        for (n, w) in [(1usize, 10usize), (9, 4), (30, 7), (100, 10)] {
            let chunks = chunks_of(n, w);
            assert_eq!(chunks.iter().sum::<usize>(), n);
            assert!(chunks.iter().all(|&c| c >= 1 && c <= w));
        }
    }

    #[test]
    fn pick_block_choices() {
        assert_eq!(pick_block(1), 1);
        assert_eq!(pick_block(2), 8);
        assert_eq!(pick_block(8), 8);
        assert_eq!(pick_block(9), 64);
        assert_eq!(pick_block(61), 64);
        assert_eq!(pick_block(101), 128);
        // satellite: oversized row sets clamp (and get chunked) instead
        // of erroring out of the whole job
        assert_eq!(pick_block(129), MAX_BLOCK);
        assert_eq!(pick_block(1000), MAX_BLOCK);
    }

    #[test]
    fn padded_span_matches_chunked_writes() {
        assert_eq!(padded_span(1), 1);
        assert_eq!(padded_span(5), 8);
        assert_eq!(padded_span(61), 64);
        assert_eq!(padded_span(128), 128);
        assert_eq!(padded_span(129), 129); // 128 + pick_block(1)
        assert_eq!(padded_span(200), 256); // 128 + pick_block(72) = 128 + 128
        assert_eq!(padded_span(256), 256);
        // the span covers every chunk's padded width
        for n in [1usize, 7, 64, 100, 128, 129, 200, 300] {
            let mut base = 0usize;
            for take in plan_chunks(n) {
                base += if take == MAX_BLOCK { MAX_BLOCK } else { pick_block(take) };
            }
            assert_eq!(padded_span(n), base, "n={n}");
        }
    }

    #[test]
    fn plan_chunks_covers_all_rows() {
        assert_eq!(plan_chunks(1), vec![1]);
        assert_eq!(plan_chunks(128), vec![128]);
        assert_eq!(plan_chunks(129), vec![128, 1]);
        assert_eq!(plan_chunks(300), vec![128, 128, 44]);
        for n in [1usize, 64, 128, 129, 256, 257, 999] {
            let chunks = plan_chunks(n);
            assert_eq!(chunks.iter().sum::<usize>(), n);
            assert!(chunks.iter().all(|&c| c >= 1 && c <= MAX_BLOCK));
        }
    }
}
