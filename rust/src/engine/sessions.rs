//! Per-request model sessions: thin, stateful wrappers over the AOT graphs.
//!
//! A session owns the host-side KV cache and the argument plumbing for one
//! model (target GPT, EAGLE/HASS draft net, SpS tiny LM, Medusa heads).
//! All graph outputs come back as host tensors; the engine layers the
//! speculative policies (spec/) on top.

use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::kvcache::KvCache;
use crate::runtime::{scalar_i32, Checkpoint, Runtime, TensorF, TensorI};

/// Pick the smallest compiled decode-block width that fits `n` rows.
pub fn pick_block(n: usize) -> Result<usize> {
    for cand in [1usize, 8, 64, 128] {
        if n <= cand {
            return Ok(cand);
        }
    }
    bail!("verification block of {n} rows exceeds the largest artifact (128)")
}

fn call(
    rt: &Runtime,
    graph: &str,
    weights: &[Literal],
    extra_weights: &[&Literal],
    inputs: &[Literal],
) -> Result<Vec<Literal>> {
    let mut args: Vec<&Literal> = Vec::with_capacity(weights.len() + extra_weights.len() + inputs.len());
    args.extend(weights.iter());
    args.extend(extra_weights.iter().copied());
    args.extend(inputs.iter());
    rt.call(graph, &args)
}

fn tensor_out(lits: &[Literal], i: usize) -> Result<TensorF> {
    TensorF::from_literal(lits.get(i).context("missing graph output")?)
}

/// Output of a decode/verify call.
pub struct DecodeOut {
    /// [N, V] logits
    pub logits: TensorF,
    /// [N, d] post-LN features
    pub feats: TensorF,
}

// ---------------------------------------------------------------------------
// target GPT session
// ---------------------------------------------------------------------------

pub struct TargetSession {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    pub cache: KvCache,
    pub slots: usize,
    pub vocab: usize,
    pub d_model: usize,
    /// features of the committed sequence, one row per committed token
    /// (needed as draft inputs); grown incrementally.
    pub feats: Vec<Vec<f32>>,
}

impl TargetSession {
    pub fn new(rt: Rc<Runtime>, weights: Rc<Checkpoint>) -> Result<TargetSession> {
        let (slots, layers, heads, d_model, vocab) = {
            let m = rt.meta();
            (m.cache_slots(), m.dim("target", "n_layers"),
             m.dim("target", "n_heads"), m.dim("target", "d_model"),
             m.dim("target", "vocab"))
        };
        let hd = d_model / heads.max(1);
        Ok(TargetSession {
            rt,
            weights,
            cache: KvCache::new(layers, slots, heads, hd),
            slots,
            vocab,
            d_model,
            feats: Vec::new(),
        })
    }

    pub fn reset(&mut self) {
        self.cache.reset();
        self.feats.clear();
    }

    /// Prefill the prompt; returns the logits row at the last prompt token.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.is_empty() || tokens.len() > self.slots {
            bail!("prompt length {} out of range", tokens.len());
        }
        let mut padded = vec![0i32; self.slots];
        padded[..tokens.len()].copy_from_slice(tokens);
        let inp = TensorI::new(vec![self.slots], padded)?.to_literal()?;
        let out = call(&self.rt, "target_prefill", &self.weights.literals, &[], &[inp])?;
        let feats = tensor_out(&out, 0)?;
        let kv_k = tensor_out(&out, 1)?;
        let kv_v = tensor_out(&out, 2)?;
        let logits = tensor_out(&out, 3)?;
        self.cache.absorb(kv_k, kv_v)?;
        self.cache.committed = tokens.len();
        self.feats = (0..tokens.len()).map(|i| feats.row(i).to_vec()).collect();
        Ok(logits.row(tokens.len() - 1).to_vec())
    }

    /// Verify/decode a block of `tokens` (chain or tree).  `positions` are
    /// absolute sequence positions; `block_anc` is the intra-block ancestor
    /// mask (None = chain).  Returns per-row logits + features; KV rows are
    /// written at the committed boundary (commit/compact is the caller's
    /// decision).
    pub fn decode(
        &mut self,
        tokens: &[i32],
        positions: &[usize],
        block_anc: Option<&[Vec<bool>]>,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        let nb = pick_block(n)?;
        if self.cache.committed + nb > self.slots {
            bail!("target cache exhausted ({} + {nb} > {})", self.cache.committed, self.slots);
        }
        // pad rows to the block width
        let mut tok = vec![0i32; nb];
        tok[..n].copy_from_slice(tokens);
        let mut pos = vec![0i32; nb];
        for (i, &p) in positions.iter().enumerate() {
            pos[i] = p as i32;
        }
        // pad ancestor mask with all-false rows (padding rows see nothing)
        let mask = match block_anc {
            Some(anc) => {
                let mut padded: Vec<Vec<bool>> = anc.to_vec();
                for row in padded.iter_mut() {
                    row.resize(nb, false);
                }
                padded.resize(nb, vec![false; nb]);
                self.cache.block_mask(nb, Some(&padded))
            }
            None => {
                let mut m = self.cache.block_mask(nb, None);
                // zero out padding rows entirely
                for row in n..nb {
                    for s in 0..self.slots {
                        m.data[row * self.slots + s] = 0;
                    }
                }
                m
            }
        };
        let graph = format!("target_decode_n{nb}");
        let out = call(
            &self.rt,
            &graph,
            &self.weights.literals,
            &[],
            &[
                crate::runtime::tensor::f32_literal(
                    &[self.cache.layers, self.cache.slots, self.cache.heads, self.cache.head_dim],
                    &self.cache.k)?,
                crate::runtime::tensor::f32_literal(
                    &[self.cache.layers, self.cache.slots, self.cache.heads, self.cache.head_dim],
                    &self.cache.v)?,
                scalar_i32(self.cache.committed as i32),
                TensorI::new(vec![nb], tok)?.to_literal()?,
                TensorI::new(vec![nb], pos)?.to_literal()?,
                mask.to_literal()?,
            ],
        )?;
        let logits = tensor_out(&out, 0)?;
        let feats = tensor_out(&out, 1)?;
        self.cache.absorb(tensor_out(&out, 2)?, tensor_out(&out, 3)?)?;
        Ok(DecodeOut { logits, feats })
    }

    /// Commit block rows after acceptance (rows strictly increasing) and
    /// record their features as committed context.
    pub fn commit_rows(&mut self, rows: &[usize], feats: &TensorF) -> Result<()> {
        self.cache.compact_accepted(rows)?;
        for &r in rows {
            self.feats.push(feats.row(r).to_vec());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// EAGLE/HASS draft session
// ---------------------------------------------------------------------------

pub struct DraftSession {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    /// target wte literal (the draft decodes through the target's LM head)
    pub wte: Literal,
    /// KV cache kept as pass-through literals: graph outputs are fed back
    /// as the next call's inputs without host round-trips (perf pass §Perf;
    /// the draft cache never needs compaction, so host access is never
    /// required — unlike the target cache).
    kv_k: Option<Literal>,
    kv_v: Option<Literal>,
    pub committed: usize,
    pub slots: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub block: usize,
}

impl DraftSession {
    pub fn new(
        rt: Rc<Runtime>,
        weights: Rc<Checkpoint>,
        target: &Rc<Checkpoint>,
    ) -> Result<DraftSession> {
        let (slots, d_model, heads, vocab) = {
            let m = rt.meta();
            (m.cache_slots(), m.dim("draft", "d_model"),
             m.dim("draft", "n_heads"), m.dim("draft", "vocab"))
        };
        let _ = heads;
        let wte = target
            .tensor("['wte']")
            .context("target checkpoint missing wte")?
            .to_literal()?;
        Ok(DraftSession {
            rt,
            weights,
            wte,
            kv_k: None,
            kv_v: None,
            committed: 0,
            slots,
            vocab,
            d_model,
            block: 10,
        })
    }

    pub fn reset(&mut self) {
        self.committed = 0;
        self.kv_k = None;
        self.kv_v = None;
    }

    pub fn remaining(&self) -> usize {
        self.slots - self.committed
    }

    pub fn commit(&mut self, n: usize) -> Result<()> {
        if self.committed + n > self.slots {
            bail!("draft cache overflow");
        }
        self.committed += n;
        Ok(())
    }

    /// Prefill: prompt tokens + target features (unshifted).
    pub fn prefill(&mut self, tokens: &[i32], target_feats: &[Vec<f32>]) -> Result<()> {
        let mut padded = vec![0i32; self.slots];
        padded[..tokens.len()].copy_from_slice(tokens);
        let mut tf = vec![0.0f32; self.slots * self.d_model];
        for (i, row) in target_feats.iter().enumerate().take(tokens.len()) {
            tf[i * self.d_model..(i + 1) * self.d_model].copy_from_slice(row);
        }
        let mut out = call(
            &self.rt,
            "draft_prefill",
            &self.weights.literals,
            &[&self.wte],
            &[
                TensorI::new(vec![self.slots], padded)?.to_literal()?,
                TensorF::new(vec![self.slots, self.d_model], tf)?.to_literal()?,
            ],
        )?;
        // keep the KV literals as-is: zero host conversions on this path
        self.kv_v = Some(out.swap_remove(1));
        self.kv_k = Some(out.swap_remove(0));
        self.committed = tokens.len();
        Ok(())
    }

    /// One draft forward over up to `block` rows.
    ///
    /// `rows`: (token, input-feature, position, visible-slots) per row; KV
    /// rows are written at `write_start` (contiguous).  `mask_rows[i]`
    /// lists *extra* visible slots beyond the committed prefix (tree
    /// ancestors); every row also sees its own slot.
    pub fn decode(
        &mut self,
        tokens: &[i32],
        in_feats: &[&[f32]],
        positions: &[usize],
        extra_visible: &[Vec<usize>],
        write_start: usize,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        let b = self.block;
        if n > b {
            bail!("draft decode block too large: {n} > {b}");
        }
        if write_start + b > self.slots {
            bail!("draft cache exhausted");
        }
        let mut tok = vec![0i32; b];
        tok[..n].copy_from_slice(tokens);
        let mut pos = vec![0i32; b];
        let mut feats = vec![0.0f32; b * self.d_model];
        for i in 0..n {
            pos[i] = positions[i] as i32;
            feats[i * self.d_model..(i + 1) * self.d_model].copy_from_slice(in_feats[i]);
        }
        let mut mask = vec![0i32; b * self.slots];
        for i in 0..n {
            let off = i * self.slots;
            for s in 0..self.committed {
                mask[off + s] = 1;
            }
            for &s in &extra_visible[i] {
                mask[off + s] = 1;
            }
            mask[off + write_start + i] = 1; // own slot
        }
        let kv_k = self.kv_k.as_ref().context("draft decode before prefill")?;
        let kv_v = self.kv_v.as_ref().context("draft decode before prefill")?;
        let inputs = [
            scalar_i32(write_start as i32),
            TensorI::new(vec![b], tok)?.to_literal()?,
            TensorF::new(vec![b, self.d_model], feats)?.to_literal()?,
            TensorI::new(vec![b], pos)?.to_literal()?,
            TensorI::new(vec![b, self.slots], mask)?.to_literal()?,
        ];
        let mut args: Vec<&Literal> = Vec::with_capacity(self.weights.literals.len() + 8);
        args.extend(self.weights.literals.iter());
        args.push(&self.wte);
        args.push(kv_k);
        args.push(kv_v);
        args.extend(inputs.iter());
        let mut out = self.rt.call("draft_decode_b10", &args)?;
        let logits = tensor_out(&out, 0)?;
        let g = tensor_out(&out, 1)?;
        self.kv_v = Some(out.swap_remove(3));
        self.kv_k = Some(out.swap_remove(2));
        Ok(DecodeOut { logits, feats: g })
    }
}

// ---------------------------------------------------------------------------
// SpS tiny-LM session (vanilla speculative sampling draft)
// ---------------------------------------------------------------------------

pub struct SpsSession {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    pub cache: KvCache,
    pub slots: usize,
    pub vocab: usize,
}

impl SpsSession {
    pub fn new(rt: Rc<Runtime>, weights: Rc<Checkpoint>) -> Result<SpsSession> {
        let (slots, d, heads, layers, vocab) = {
            let m = rt.meta();
            (m.cache_slots(), m.dim("sps", "d_model"), m.dim("sps", "n_heads"),
             m.dim("sps", "n_layers"), m.dim("sps", "vocab"))
        };
        Ok(SpsSession {
            rt,
            weights,
            cache: KvCache::new(layers, slots, heads, d / heads.max(1)),
            slots,
            vocab,
        })
    }

    pub fn reset(&mut self) {
        self.cache.reset();
    }

    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut padded = vec![0i32; self.slots];
        padded[..tokens.len()].copy_from_slice(tokens);
        let inp = TensorI::new(vec![self.slots], padded)?.to_literal()?;
        let out = call(&self.rt, "sps_prefill", &self.weights.literals, &[], &[inp])?;
        self.cache.absorb(tensor_out(&out, 1)?, tensor_out(&out, 2)?)?;
        self.cache.committed = tokens.len();
        let logits = tensor_out(&out, 3)?;
        Ok(logits.row(tokens.len() - 1).to_vec())
    }

    /// One AR step; writes the token's KV at `committed` and commits it.
    pub fn decode1(&mut self, token: i32, position: usize) -> Result<Vec<f32>> {
        let mask = self.cache.block_mask(1, None);
        let out = call(
            &self.rt,
            "sps_decode_n1",
            &self.weights.literals,
            &[],
            &[
                crate::runtime::tensor::f32_literal(
                    &[self.cache.layers, self.cache.slots, self.cache.heads, self.cache.head_dim],
                    &self.cache.k)?,
                crate::runtime::tensor::f32_literal(
                    &[self.cache.layers, self.cache.slots, self.cache.heads, self.cache.head_dim],
                    &self.cache.v)?,
                scalar_i32(self.cache.committed as i32),
                TensorI::new(vec![1], vec![token])?.to_literal()?,
                TensorI::new(vec![1], vec![position as i32])?.to_literal()?,
                mask.to_literal()?,
            ],
        )?;
        let logits = tensor_out(&out, 0)?;
        self.cache.absorb(tensor_out(&out, 2)?, tensor_out(&out, 3)?)?;
        self.cache.commit(1)?;
        Ok(logits.row(0).to_vec())
    }

    /// Roll back the last `n` committed rows (rejected chain suffix).
    pub fn rollback(&mut self, n: usize) {
        self.cache.committed = self.cache.committed.saturating_sub(n);
    }
}

// ---------------------------------------------------------------------------
// Medusa heads
// ---------------------------------------------------------------------------

pub struct MedusaHeads {
    rt: Rc<Runtime>,
    pub weights: Rc<Checkpoint>,
    pub wte: Literal,
    pub n_heads: usize,
    pub vocab: usize,
    pub d_model: usize,
}

impl MedusaHeads {
    pub fn new(rt: Rc<Runtime>, weights: Rc<Checkpoint>, target: &Rc<Checkpoint>) -> Result<MedusaHeads> {
        let (vocab, d_model) = {
            let m = rt.meta();
            (m.dim("target", "vocab"), m.dim("target", "d_model"))
        };
        let wte = target
            .tensor("['wte']")
            .context("target checkpoint missing wte")?
            .to_literal()?;
        Ok(MedusaHeads {
            rt,
            weights,
            wte,
            n_heads: 4,
            vocab,
            d_model,
        })
    }

    /// feat [d] -> per-head logits [n_heads][V].
    pub fn predict(&self, feat: &[f32]) -> Result<Vec<Vec<f32>>> {
        let inp = TensorF::new(vec![1, self.d_model], feat.to_vec())?.to_literal()?;
        let out = call(&self.rt, "medusa_heads", &self.weights.literals, &[&self.wte], &[inp])?;
        let logits = tensor_out(&out, 0)?; // [1, H, V]
        let v = self.vocab;
        Ok((0..self.n_heads)
            .map(|h| logits.data[h * v..(h + 1) * v].to_vec())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::pick_block;

    #[test]
    fn pick_block_choices() {
        assert_eq!(pick_block(1).unwrap(), 1);
        assert_eq!(pick_block(2).unwrap(), 8);
        assert_eq!(pick_block(8).unwrap(), 8);
        assert_eq!(pick_block(9).unwrap(), 64);
        assert_eq!(pick_block(61).unwrap(), 64);
        assert_eq!(pick_block(101).unwrap(), 128);
        assert!(pick_block(129).is_err());
    }
}
