//! Lock-order tracker for the `HASS_CHECK=1` shadow sanitizer.
//!
//! The scheduler holds a handful of mutexes (per-worker queues, the
//! shared overflow channel, the stats vector, the cancel set, the
//! prefix-affinity map), and since the Arc page-pool migration the
//! kvcache adds the registry shard locks.  None of them may ever be
//! acquired in inconsistent order across threads, or the pool can
//! deadlock under load in ways no unit test reproduces.  The intended
//! order is: scheduler classes first ([`WORKER_QUEUE`], [`SHARED_RX`],
//! [`STATS`], [`CANCELS`], [`AFFINITY`] — each held alone in practice),
//! with the page-registry shard ([`PAGE_SHARD`]) strictly a leaf:
//! `dedup_page`/`registry_stats` take one shard at a time and call
//! nothing that locks.  When auditing
//! is enabled ([`crate::kvcache::audit::enabled`]), every traced
//! acquisition records a directed edge `held -> acquired` in a global
//! graph; acquiring `A` while holding `B` after some thread ever
//! acquired `B` while holding `A` panics with `hass-check[lock-order]`.
//!
//! Tracing is cooperative: call [`trace`] with the site's lock class
//! just before (or just after, for try-locks) taking the real mutex and
//! keep the returned token alive for the critical section.  When
//! auditing is off the token is inert and the call is a branch + return.

use std::cell::RefCell;
use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

/// Lock classes (coarse, per-role: two queues of the same class are not
/// distinguished — no current code path nests same-class locks, and the
/// tracker flags such nesting as a violation so it stays that way).
pub const WORKER_QUEUE: u16 = 1;
pub const SHARED_RX: u16 = 2;
pub const STATS: u16 = 3;
pub const CANCELS: u16 = 4;
/// Scheduler prefix-affinity map (fingerprint -> worker); held only
/// inside `Scheduler::route`, never across a queue push or stats update.
pub const AFFINITY: u16 = 5;
/// One shard of the pool-wide page registry (`kvcache::dedup_page`);
/// a leaf class — shard critical sections call nothing that locks, and
/// whole-pool walks visit shards strictly one at a time.
pub const PAGE_SHARD: u16 = 6;
/// The scheduler's flight board (per-worker in-flight job journal for
/// crash redelivery); a leaf class like [`PAGE_SHARD`] — records are
/// moved out of the critical section before any queue/stats lock.
pub const FLIGHT: u16 = 7;

fn class_name(c: u16) -> &'static str {
    match c {
        WORKER_QUEUE => "worker-queue",
        SHARED_RX => "shared-rx",
        STATS => "stats",
        CANCELS => "cancels",
        AFFINITY => "affinity",
        PAGE_SHARD => "page-shard",
        FLIGHT => "flight",
        _ => "unknown",
    }
}

/// The pure order graph — kept free of globals so the inversion logic is
/// directly unit-testable.
#[derive(Default)]
pub struct LockGraph {
    /// directed edges: held -> then-acquired
    edges: HashSet<(u16, u16)>,
}

impl LockGraph {
    pub fn new() -> LockGraph {
        LockGraph { edges: HashSet::new() }
    }

    /// Record acquiring `class` while `held` are held.  Returns a
    /// description of the violation, if this acquisition creates one.
    pub fn acquire(&mut self, held: &[u16], class: u16) -> Option<String> {
        for &h in held {
            if h == class {
                return Some(format!(
                    "lock class `{}` acquired while already held (self-deadlock risk)",
                    class_name(class)
                ));
            }
            if self.edges.contains(&(class, h)) {
                return Some(format!(
                    "inversion: acquiring `{}` while holding `{}`, but the opposite \
                     order `{}` -> `{}` was recorded earlier",
                    class_name(class),
                    class_name(h),
                    class_name(class),
                    class_name(h)
                ));
            }
        }
        for &h in held {
            self.edges.insert((h, class));
        }
        None
    }
}

fn graph() -> &'static Mutex<LockGraph> {
    static G: OnceLock<Mutex<LockGraph>> = OnceLock::new();
    G.get_or_init(|| Mutex::new(LockGraph::new()))
}

thread_local! {
    static HELD: RefCell<Vec<u16>> = RefCell::new(Vec::new());
}

/// RAII hold token; dropping it releases the class from this thread's
/// held set.  Inert (`live = false`) when auditing is disabled.
pub struct Token {
    class: u16,
    live: bool,
}

pub fn trace(class: u16) -> Token {
    if !crate::kvcache::audit::enabled() {
        return Token { class, live: false };
    }
    let violation = HELD.with(|h| {
        let held = h.borrow();
        let mut g = graph().lock().unwrap_or_else(|p| p.into_inner());
        g.acquire(&held, class)
    });
    if let Some(msg) = violation {
        panic!("hass-check[lock-order]: {msg}");
    }
    HELD.with(|h| h.borrow_mut().push(class));
    Token { class, live: true }
}

impl Drop for Token {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(p) = held.iter().rposition(|&c| c == self.class) {
                held.remove(p);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consistent_order_is_clean() {
        let mut g = LockGraph::new();
        assert!(g.acquire(&[], WORKER_QUEUE).is_none());
        assert!(g.acquire(&[WORKER_QUEUE], STATS).is_none());
        assert!(g.acquire(&[], WORKER_QUEUE).is_none());
        assert!(g.acquire(&[WORKER_QUEUE], STATS).is_none());
    }

    #[test]
    fn inversion_is_detected() {
        let mut g = LockGraph::new();
        // thread 1: queue then stats
        assert!(g.acquire(&[WORKER_QUEUE], STATS).is_none());
        // thread 2: stats then queue — inversion
        let v = g.acquire(&[STATS], WORKER_QUEUE);
        assert!(v.is_some());
        assert!(v.unwrap_or_default().contains("inversion"));
    }

    #[test]
    fn transitive_edges_are_per_pair() {
        let mut g = LockGraph::new();
        assert!(g.acquire(&[WORKER_QUEUE], SHARED_RX).is_none());
        assert!(g.acquire(&[SHARED_RX], STATS).is_none());
        // direct opposite of a recorded edge still fires
        assert!(g.acquire(&[STATS], SHARED_RX).is_some());
    }

    #[test]
    fn reacquire_same_class_is_flagged() {
        let mut g = LockGraph::new();
        let v = g.acquire(&[CANCELS], CANCELS);
        assert!(v.is_some());
        assert!(v.unwrap_or_default().contains("already held"));
    }

    #[test]
    fn page_shard_stays_a_leaf() {
        let mut g = LockGraph::new();
        // workers dedup pages with a stats update already traced (the
        // drain path), so stats -> shard is the recorded direction
        assert!(g.acquire(&[], PAGE_SHARD).is_none());
        assert!(g.acquire(&[], AFFINITY).is_none());
        assert!(g.acquire(&[STATS], PAGE_SHARD).is_none());
        // locking back out of a shard critical section is the inversion
        // the leaf rule exists to prevent
        assert!(g.acquire(&[PAGE_SHARD], STATS).is_some());
    }

    #[test]
    fn inert_token_when_disabled() {
        // auditing is off by default in tests (no force flag on this
        // thread, no HASS_CHECK): trace must be a no-op that never
        // touches the global graph
        if crate::kvcache::audit::enabled() {
            return; // HASS_CHECK=1 run: tokens are live by design
        }
        let t = trace(WORKER_QUEUE);
        assert!(!t.live);
        drop(t);
        HELD.with(|h| assert!(h.borrow().is_empty()));
    }
}
