//! Minimal JSON substrate (parser + writer).
//!
//! serde is not in the offline vendor set; the runtime needs JSON for
//! `artifacts/meta.json`, weight manifests, `suites.json`, and the TCP
//! serving protocol.  Supports the full JSON grammar minus exotic number
//! forms; preserves object key order (manifest order matters).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Clone, Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn str_at(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }
    pub fn usize_at(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }
    pub fn f64_at(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    // ---- builders ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn arr_num(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // ---- writer ----
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// The writer is exposed through `Display`, so both `format!("{j}")` and
/// the blanket `ToString::to_string` work (an inherent `to_string` would
/// shadow the trait and trip clippy's `inherent_to_string`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, ParseError> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| ParseError {
                        pos: start,
                        msg: "invalid utf8".into(),
                    })?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience: object -> map for lookups that don't care about order.
pub fn to_map(j: &Json) -> BTreeMap<String, Json> {
    match j {
        Json::Obj(kv) => kv.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().str_at("b"), Some("x"));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn preserves_key_order() {
        let j = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"hass","tensors":[{"shape":[2,3],"offset":0}],"f":1.5,"neg":-2,"esc":"a\"b\\c\nd","empty":[],"eo":{}}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn big_manifest_like() {
        let mut src = String::from("{\"tensors\":[");
        for i in 0..100 {
            if i > 0 {
                src.push(',');
            }
            src.push_str(&format!(
                r#"{{"name":"t{}","shape":[4,{}],"offset":{}}}"#,
                i,
                i + 1,
                i * 16
            ));
        }
        src.push_str("]}");
        let j = parse(&src).unwrap();
        assert_eq!(j.get("tensors").unwrap().as_arr().unwrap().len(), 100);
        assert_eq!(
            j.get("tensors").unwrap().idx(7).unwrap().usize_at("offset"),
            Some(112)
        );
    }
}
