//! Deterministic fault-injection points for chaos testing.
//!
//! A *failpoint* is a named site on a production code path where a fault
//! (an error return, a panic, or a delay) can be injected at a configured
//! rate from a seeded RNG, so worker supervision, in-flight recovery and
//! poison-recovery paths can be exercised reproducibly.  Faults come from
//! two sources:
//!
//! - **Process-wide, via env** (the CI chaos matrix entry):
//!   `HASS_FAULTS="<point>:<err|panic|delay:N>:<rate>[,<spec>...]"` with
//!   `HASS_FAULTS_SEED=<u64>` for a reproducible stream.  Parsing rejects
//!   unknown point names loudly (a typo'd chaos config must not silently
//!   inject nothing) — the known names live in one table,
//!   [`POINT_NAMES`].
//! - **Scoped, via [`install`]** (unit tests, `chaos_bench`): a spec set
//!   active only on threads whose *name* contains a tag (the scheduler
//!   names workers `engine-p{pool}-{w}`, so a test can target its own
//!   pool without perturbing tests running in parallel).  The returned
//!   [`Guard`] uninstalls on drop.
//!
//! The hot path is a branch on one atomic pointer: with nothing
//! installed, [`fire`] is a null-check and return.  Each installed spec
//! owns an atomic SplitMix64 stream (seed mixed with the point index) so
//! trigger decisions are reproducible per point for a given call
//! sequence, lock-free — `fire` never takes a lock, so it is safe inside
//! critical sections (that is exactly where the poison tests place it).
//! Per-point trigger counters are exported for the stats wire via
//! [`triggers`].

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

/// The single registry of failpoint names.  `HASS_FAULTS` parsing
/// rejects anything not listed here; indices match the `Point` consts.
pub const POINT_NAMES: &[&str] = &[
    "engine.target_decode",
    "engine.draft_decode",
    "kvcache.page_alloc",
    "kvcache.dedup_shard",
    "scheduler.spill_send",
    "scheduler.steal",
    "scheduler.worker_tick",
    "scheduler.stats_update",
    "scheduler.affinity_route",
    "server.conn_read",
    "server.conn_write",
];

/// Index into [`POINT_NAMES`]; construct via the named consts only.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Point(usize);

/// Fused and solo `target_decode` graph calls (`engine/sessions.rs`).
pub const TARGET_DECODE: Point = Point(0);
/// Fused and solo `draft_decode` graph calls (`engine/sessions.rs`).
pub const DRAFT_DECODE: Point = Point(1);
/// `kvcache::Page::alloc` — physical page allocation.
pub const PAGE_ALLOC: Point = Point(2);
/// Inside a dedup-registry shard critical section (`kvcache::dedup_page`).
pub const DEDUP_SHARD: Point = Point(3);
/// The scheduler spill path (`submit` overflowing to the shared channel).
pub const SPILL_SEND: Point = Point(4);
/// The work-stealing pull off the shared channel.
pub const STEAL: Point = Point(5);
/// Top of the engine worker main loop — `panic` here kills the worker
/// thread and exercises supervision/respawn.
pub const WORKER_TICK: Point = Point(6);
/// Inside the per-worker stats critical section (`WorkerCtx::with_stats`).
pub const STATS_UPDATE: Point = Point(7);
/// Inside the prefix-affinity map critical section (`Scheduler::route`).
pub const AFFINITY_ROUTE: Point = Point(8);
/// Server per-connection request read.
pub const CONN_READ: Point = Point(9);
/// Server per-connection response write.
pub const CONN_WRITE: Point = Point(10);

impl Point {
    pub fn name(self) -> &'static str {
        POINT_NAMES[self.0]
    }
}

/// What happens when a point triggers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Action {
    /// Return an injected `Err` from the fault site (ignored — but still
    /// counted — at sites that cannot fail, see [`fire_unit`]).
    Err,
    /// Panic at the fault site (worker death / lock poisoning).
    Panic,
    /// Sleep for N milliseconds (slow graph call / stalled I/O).
    Delay(u64),
}

/// One parsed `<point>:<action>:<rate>` clause.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub point: Point,
    pub action: Action,
    pub rate: f64,
}

/// Parse a `HASS_FAULTS` string: comma/semicolon-separated
/// `<point>:<err|panic|delay:N>:<rate>` clauses.  Unknown point names,
/// unknown actions and out-of-range rates are hard errors.
pub fn parse(s: &str) -> Result<Vec<FaultSpec>> {
    let mut out = Vec::new();
    for item in s.split([',', ';']).map(str::trim).filter(|t| !t.is_empty()) {
        let parts: Vec<&str> = item.split(':').collect();
        if parts.len() < 3 {
            bail!("failpoint spec `{item}`: want <point>:<err|panic|delay:N>:<rate>");
        }
        let point = POINT_NAMES
            .iter()
            .position(|&n| n == parts[0])
            .map(Point)
            .ok_or_else(|| {
                anyhow!("unknown failpoint `{}` (known: {})", parts[0], POINT_NAMES.join(", "))
            })?;
        let raw_rate = parts[parts.len() - 1];
        let rate: f64 = raw_rate
            .parse()
            .map_err(|_| anyhow!("failpoint spec `{item}`: bad rate `{raw_rate}`"))?;
        if !(0.0..=1.0).contains(&rate) {
            bail!("failpoint spec `{item}`: rate {rate} outside [0, 1]");
        }
        let action = match parts[1..parts.len() - 1] {
            ["err"] => Action::Err,
            ["panic"] => Action::Panic,
            ["delay", n] => Action::Delay(
                n.trim_end_matches("ms")
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("failpoint spec `{item}`: bad delay `{n}`"))?,
            ),
            ref other => bail!(
                "failpoint spec `{item}`: unknown action `{}` (want err, panic or delay:N)",
                other.join(":")
            ),
        };
        out.push(FaultSpec { point, action, rate });
    }
    Ok(out)
}

/// One spec compiled into the active snapshot; `rng` is an atomic
/// SplitMix64 state so trigger rolls are lock-free.
struct SpecState {
    action: Action,
    rate: f64,
    scope: Option<String>,
    rng: AtomicU64,
}

struct Config {
    by_point: Vec<Vec<SpecState>>,
}

/// Active snapshot.  Replaced (never freed — snapshots are intentionally
/// leaked so `fire` can hold a `&'static` without locking; installs are
/// rare and tiny) under the `sets()` mutex.
static CONFIG: AtomicPtr<Config> = AtomicPtr::new(std::ptr::null_mut());
static ENV_INIT: Once = Once::new();
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn triggers_vec() -> &'static Vec<AtomicU64> {
    static T: OnceLock<Vec<AtomicU64>> = OnceLock::new();
    T.get_or_init(|| POINT_NAMES.iter().map(|_| AtomicU64::new(0)).collect())
}

struct InstallSet {
    id: u64,
    scope: Option<String>,
    seed: u64,
    specs: Vec<FaultSpec>,
}

fn sets() -> &'static Mutex<Vec<InstallSet>> {
    static S: OnceLock<Mutex<Vec<InstallSet>>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(Vec::new()))
}

fn rebuild(live: &[InstallSet]) {
    let ptr = if live.is_empty() {
        std::ptr::null_mut()
    } else {
        let mut by_point: Vec<Vec<SpecState>> = POINT_NAMES.iter().map(|_| Vec::new()).collect();
        for set in live {
            for spec in &set.specs {
                by_point[spec.point.0].push(SpecState {
                    action: spec.action.clone(),
                    rate: spec.rate,
                    scope: set.scope.clone(),
                    rng: AtomicU64::new(
                        set.seed ^ (spec.point.0 as u64).wrapping_mul(0x9E3779B97F4A7C15),
                    ),
                });
            }
        }
        Box::into_raw(Box::new(Config { by_point }))
    };
    CONFIG.store(ptr, Ordering::Release);
}

/// Uninstalls its spec set on drop.
pub struct Guard {
    id: u64,
}

impl Drop for Guard {
    fn drop(&mut self) {
        let mut s = sets().lock().unwrap_or_else(|p| p.into_inner());
        s.retain(|x| x.id != self.id);
        rebuild(&s);
    }
}

/// Install a spec set.  `scope: Some(tag)` limits firing to threads
/// whose name contains `tag` (e.g. a scheduler pool tag, so parallel
/// tests do not see each other's faults); `None` is process-wide.
pub fn install(scope: Option<&str>, specs: Vec<FaultSpec>, seed: u64) -> Guard {
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let mut s = sets().lock().unwrap_or_else(|p| p.into_inner());
    s.push(InstallSet { id, scope: scope.map(str::to_string), seed, specs });
    rebuild(&s);
    Guard { id }
}

fn init_env() {
    let Ok(cfg) = std::env::var("HASS_FAULTS") else { return };
    if cfg.trim().is_empty() {
        return;
    }
    let specs = match parse(&cfg) {
        Ok(s) => s,
        // fail loudly: a typo'd chaos config must not silently inject nothing
        Err(e) => panic!("HASS_FAULTS: {e:#}"),
    };
    let seed = std::env::var("HASS_FAULTS_SEED")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut s = sets().lock().unwrap_or_else(|p| p.into_inner());
    s.push(InstallSet { id: 0, scope: None, seed, specs });
    rebuild(&s);
}

/// Advance an atomic SplitMix64 stream and return a uniform f64 in [0,1).
fn roll(state: &AtomicU64) -> f64 {
    let s = state
        .fetch_add(0x9E3779B97F4A7C15, Ordering::Relaxed)
        .wrapping_add(0x9E3779B97F4A7C15);
    let mut z = s;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    ((z ^ (z >> 31)) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Hit a failpoint on a fallible path.  With nothing installed this is a
/// null-check and return; otherwise it may inject `Err`, panic, or sleep
/// per the active specs.
#[inline]
pub fn fire(p: Point) -> Result<()> {
    ENV_INIT.call_once(init_env);
    let ptr = CONFIG.load(Ordering::Acquire);
    if ptr.is_null() {
        return Ok(());
    }
    // SAFETY: snapshots are only ever replaced and intentionally leaked,
    // never freed, so a loaded non-null pointer stays valid for 'static.
    fire_slow(unsafe { &*ptr }, p, true)
}

/// Hit a failpoint on an infallible path: `err` specs count a trigger
/// but are otherwise ignored; `panic`/`delay` act normally.
#[inline]
pub fn fire_unit(p: Point) {
    ENV_INIT.call_once(init_env);
    let ptr = CONFIG.load(Ordering::Acquire);
    if ptr.is_null() {
        return;
    }
    // SAFETY: as in `fire` — snapshots are leaked, never freed.
    let _ = fire_slow(unsafe { &*ptr }, p, false);
}

fn fire_slow(cfg: &'static Config, p: Point, can_err: bool) -> Result<()> {
    for spec in &cfg.by_point[p.0] {
        if let Some(tag) = &spec.scope {
            let cur = std::thread::current();
            if !cur.name().is_some_and(|n| n.contains(tag.as_str())) {
                continue;
            }
        }
        if roll(&spec.rng) >= spec.rate {
            continue;
        }
        triggers_vec()[p.0].fetch_add(1, Ordering::Relaxed);
        match spec.action {
            Action::Delay(ms) => std::thread::sleep(Duration::from_millis(ms)),
            Action::Panic => panic!("failpoint `{}` injected panic", p.name()),
            Action::Err => {
                if can_err {
                    return Err(anyhow!("failpoint `{}` injected error", p.name()));
                }
            }
        }
    }
    Ok(())
}

/// Per-point trigger counts since process start (for the stats wire).
pub fn triggers() -> Vec<(&'static str, u64)> {
    POINT_NAMES
        .iter()
        .zip(triggers_vec().iter())
        .map(|(&n, c)| (n, c.load(Ordering::Relaxed)))
        .collect()
}

/// Trigger count for one point (test assertions on deltas).
pub fn triggered(p: Point) -> u64 {
    triggers_vec()[p.0].load(Ordering::Relaxed)
}

/// True if an injected-error message came from the named point (callers
/// that want to classify a failure as chaos-injected).
pub fn is_injected(msg: &str) -> bool {
    msg.contains("failpoint `") && msg.contains("` injected")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tag() -> String {
        // scope to this test thread's name so parallel tests are untouched
        std::thread::current().name().unwrap_or("failpoint-test").to_string()
    }

    #[test]
    fn failpoint_parse_accepts_all_forms() {
        let specs = parse(
            "engine.target_decode:err:0.01, scheduler.worker_tick:panic:1.0; \
             server.conn_read:delay:25ms:0.5,kvcache.page_alloc:delay:3:1",
        )
        .expect("parse");
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].point, TARGET_DECODE);
        assert_eq!(specs[0].action, Action::Err);
        assert!((specs[0].rate - 0.01).abs() < 1e-12);
        assert_eq!(specs[1].action, Action::Panic);
        assert_eq!(specs[2].action, Action::Delay(25));
        assert_eq!(specs[3].action, Action::Delay(3));
    }

    #[test]
    fn failpoint_parse_rejects_unknown_point() {
        let e = parse("engine.target_decoed:err:0.5").expect_err("typo must fail");
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown failpoint"), "{msg}");
        assert!(msg.contains("engine.target_decode"), "message should list known names: {msg}");
    }

    #[test]
    fn failpoint_parse_rejects_bad_action_and_rate() {
        assert!(parse("engine.target_decode:explode:0.5").is_err());
        assert!(parse("engine.target_decode:err:1.5").is_err());
        assert!(parse("engine.target_decode:err:x").is_err());
        assert!(parse("engine.target_decode").is_err());
        assert!(parse("engine.target_decode:delay:abc:0.5").is_err());
    }

    #[test]
    fn failpoint_disabled_is_noop() {
        // no install for this thread's scope: must never error
        for _ in 0..100 {
            assert!(fire(TARGET_DECODE).is_ok());
        }
    }

    #[test]
    fn failpoint_scoped_err_fires_and_counts() {
        let t = tag();
        let before = triggered(SPILL_SEND);
        let _g = install(
            Some(&t),
            vec![FaultSpec { point: SPILL_SEND, action: Action::Err, rate: 1.0 }],
            7,
        );
        let e = fire(SPILL_SEND).expect_err("rate 1.0 must fire");
        assert!(is_injected(&format!("{e:#}")));
        assert!(triggered(SPILL_SEND) > before);
        // a different point is unaffected
        assert!(fire(STEAL).is_ok());
    }

    #[test]
    fn failpoint_scope_does_not_leak_to_other_threads() {
        let t = tag();
        let _g = install(
            Some(&t),
            vec![FaultSpec { point: CONN_WRITE, action: Action::Err, rate: 1.0 }],
            7,
        );
        let h = std::thread::Builder::new()
            .name("failpoint-other-scope".to_string())
            .spawn(|| fire(CONN_WRITE).is_ok())
            .expect("spawn");
        assert!(h.join().expect("join"), "fault scoped to this thread fired elsewhere");
        assert!(fire(CONN_WRITE).is_err(), "fault must fire on the scoped thread");
    }

    #[test]
    fn failpoint_guard_uninstalls_on_drop() {
        let t = tag();
        let g = install(
            Some(&t),
            vec![FaultSpec { point: CONN_READ, action: Action::Err, rate: 1.0 }],
            7,
        );
        assert!(fire(CONN_READ).is_err());
        drop(g);
        assert!(fire(CONN_READ).is_ok());
    }

    #[test]
    fn failpoint_rate_is_seeded_and_partial() {
        let t = tag();
        let _g = install(
            Some(&t),
            vec![FaultSpec { point: DEDUP_SHARD, action: Action::Err, rate: 0.5 }],
            42,
        );
        let fired = (0..200).filter(|_| fire(DEDUP_SHARD).is_err()).count();
        // seeded stream: stable, roughly half
        assert!((60..=140).contains(&fired), "fired={fired}");
    }

    #[test]
    fn failpoint_fire_unit_ignores_err_but_counts() {
        let t = tag();
        let before = triggered(STATS_UPDATE);
        let _g = install(
            Some(&t),
            vec![FaultSpec { point: STATS_UPDATE, action: Action::Err, rate: 1.0 }],
            7,
        );
        fire_unit(STATS_UPDATE); // must not panic or fail
        assert!(triggered(STATS_UPDATE) > before);
    }

    #[test]
    fn failpoint_triggers_snapshot_names_every_point() {
        let snap = triggers();
        assert_eq!(snap.len(), POINT_NAMES.len());
        for (name, _) in snap {
            assert!(POINT_NAMES.contains(&name));
        }
    }
}
