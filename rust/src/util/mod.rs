//! Substrate utilities built in-repo (the offline vendor set only carries
//! the `xla` crate closure — see DESIGN.md §2 substitution table).

pub mod cli;
pub mod failpoint;
pub mod json;
pub mod lockorder;
pub mod prop;
pub mod rng;
pub mod stats;
