//! Summary statistics + timing helpers (criterion substitute building block).

use std::time::Instant;

#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

/// p-th percentile (p in 0.0..=1.0) of an ascending-sorted sample set,
/// nearest-rank on the rounded fractional index; 0.0 on empty input.
/// Shared by `summarize` and the latency/TTFT tails the load bench and
/// SLO reporting quote, so every percentile in the repo means the same
/// thing.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[i]
}

pub fn summarize(samples: &[f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p95: percentile_sorted(&sorted, 0.95),
        p99: percentile_sorted(&sorted, 0.99),
    }
}

/// Accumulates phase wall-times (draft/verify/sample/host) per request.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    pub draft_s: f64,
    pub verify_s: f64,
    pub sample_s: f64,
    pub host_s: f64,
}

impl PhaseTimer {
    pub fn total(&self) -> f64 {
        self.draft_s + self.verify_s + self.sample_s + self.host_s
    }
    pub fn add(&mut self, other: &PhaseTimer) {
        self.draft_s += other.draft_s;
        self.verify_s += other.verify_s;
        self.sample_s += other.sample_s;
        self.host_s += other.host_s;
    }
}

pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(summarize(&[]).n, 0);
    }

    #[test]
    fn summary_single() {
        let s = summarize(&[7.0]);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let sorted: Vec<f64> = (1..=100).map(f64::from).collect();
        assert_eq!(percentile_sorted(&sorted, 0.0), 1.0);
        assert_eq!(percentile_sorted(&sorted, 0.50), 51.0); // round(0.5*99)=50
        assert_eq!(percentile_sorted(&sorted, 0.95), 95.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
        assert_eq!(percentile_sorted(&[], 0.5), 0.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut a = PhaseTimer { draft_s: 1.0, verify_s: 2.0, sample_s: 0.5, host_s: 0.25 };
        let b = a.clone();
        a.add(&b);
        assert!((a.total() - 7.5).abs() < 1e-12);
    }
}
