//! Minimal CLI argument parser (clap substitute for the offline build).
//!
//! Grammar: `binary <subcommand> [positionals] [--flag value | --switch]`.
//! Flags may appear anywhere after the subcommand; `--flag=value` also works.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    a.flags.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    a.switches.push(stripped.to_string());
                }
            } else {
                a.positionals.push(arg.clone());
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args("table 1 --temp 0.0 --suite code --verbose");
        assert_eq!(a.subcommand, "table");
        assert_eq!(a.positionals, ["1"]);
        assert_eq!(a.get("temp"), Some("0.0"));
        assert_eq!(a.get("suite"), Some("code"));
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = args("serve --port=9000 --depth=6");
        assert_eq!(a.usize_or("port", 0), 9000);
        assert_eq!(a.usize_or("depth", 0), 6);
    }

    #[test]
    fn defaults() {
        let a = args("gen");
        assert_eq!(a.usize_or("tokens", 64), 64);
        assert_eq!(a.f64_or("temp", 1.0), 1.0);
        assert_eq!(a.get_or("method", "hass"), "hass");
        assert!(!a.has("anything"));
    }

    #[test]
    fn trailing_switch() {
        let a = args("bench --fast");
        assert!(a.has("fast"));
        assert!(a.get("fast").is_none());
    }
}
