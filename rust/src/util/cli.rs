//! Minimal CLI argument parser (clap substitute for the offline build).
//!
//! Grammar: `binary <subcommand> [positionals] [--flag value | --switch]`.
//! Flags may appear anywhere after the subcommand; `--flag=value` also
//! works.  Comma-separated list values (`--workers 1,2,4`) parse through
//! `usize_list_or`; flagless drivers (examples) can read positionals with
//! the `pos_*` helpers.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    a.flags.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    a.switches.push(stripped.to_string());
                }
            } else {
                a.positionals.push(arg.clone());
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Optional u64 flag: absent (or unparseable) stays `None` — for
    /// knobs like `--deadline-ms` where "unset" must stay distinguishable
    /// from any numeric default.
    pub fn u64_opt(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Comma-separated usize list flag (`--workers 1,2,4`).  Unparseable
    /// entries are dropped; a missing flag — or a value with no parseable
    /// entry at all — yields `default` (never a silent empty list).
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => {
                let parsed: Vec<usize> =
                    v.split(',').filter_map(|s| s.trim().parse().ok()).collect();
                if parsed.is_empty() {
                    default.to_vec()
                } else {
                    parsed
                }
            }
            None => default.to_vec(),
        }
    }

    pub fn pos_or(&self, i: usize, default: &str) -> String {
        self.positionals.get(i).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn pos_usize_or(&self, i: usize, default: usize) -> usize {
        self.positionals.get(i).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args("table 1 --temp 0.0 --suite code --verbose");
        assert_eq!(a.subcommand, "table");
        assert_eq!(a.positionals, ["1"]);
        assert_eq!(a.get("temp"), Some("0.0"));
        assert_eq!(a.get("suite"), Some("code"));
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = args("serve --port=9000 --depth=6");
        assert_eq!(a.usize_or("port", 0), 9000);
        assert_eq!(a.usize_or("depth", 0), 6);
    }

    #[test]
    fn defaults() {
        let a = args("gen");
        assert_eq!(a.usize_or("tokens", 64), 64);
        assert_eq!(a.f64_or("temp", 1.0), 1.0);
        assert_eq!(a.get_or("method", "hass"), "hass");
        assert!(!a.has("anything"));
    }

    #[test]
    fn trailing_switch() {
        let a = args("bench --fast");
        assert!(a.has("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn usize_lists() {
        let a = args("serve --workers 1,2,4");
        assert_eq!(a.usize_list_or("workers", &[8]), vec![1, 2, 4]);
        assert_eq!(a.usize_list_or("missing", &[8]), vec![8]);
        let b = args("serve --workers 2,x,3");
        assert_eq!(b.usize_list_or("workers", &[]), vec![2, 3]);
        // fully unparseable values fall back to the default, not []
        let c = args("serve --workers two,4x");
        assert_eq!(c.usize_list_or("workers", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn optional_u64_flags() {
        let a = args("client --deadline-ms 1500");
        assert_eq!(a.u64_opt("deadline-ms"), Some(1500));
        assert_eq!(a.u64_opt("missing"), None);
        let b = args("client --deadline-ms soon");
        assert_eq!(b.u64_opt("deadline-ms"), None);
    }

    #[test]
    fn positional_helpers() {
        let a = args("table 3 fast");
        assert_eq!(a.pos_usize_or(0, 1), 3);
        assert_eq!(a.pos_or(1, "slow"), "fast");
        assert_eq!(a.pos_usize_or(5, 9), 9);
        assert_eq!(a.pos_or(5, "d"), "d");
    }
}
