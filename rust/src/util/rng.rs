//! Deterministic PRNG substrate (SplitMix64) — no external rand crate in
//! the offline vendor set, and the engine needs reproducible, seedable
//! sampling anyway (losslessness tests replay exact RNG streams).

/// SplitMix64: tiny, fast, passes BigCrush for our purposes (sampling,
/// workload generation, property tests). Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // rejection-free Lemire-style: good enough at our n << 2^64
        (self.next_u64() % n as u64) as usize
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.gen_range(i + 1));
        }
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.gen_range(weights.len().max(1));
        }
        let mut r = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w.max(0.0) as f64;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a decorrelated child stream (for per-request RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_sampling_roughly_proportional() {
        let mut r = Rng::new(11);
        let w = [1.0f32, 3.0];
        let mut counts = [0usize; 2];
        for _ in 0..20_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        let frac = counts[1] as f64 / 20_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn weighted_sampling_degenerate() {
        let mut r = Rng::new(12);
        assert_eq!(r.sample_weighted(&[0.0, 0.0, 1.0]), 2);
        // all-zero weights: uniform fallback, must not panic
        let _ = r.sample_weighted(&[0.0, 0.0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
