//! Property-testing substrate (proptest substitute for the offline build).
//!
//! Seeded case sweeps with failure reporting: every failing case prints its
//! seed so it can be replayed with `PROP_SEED=<seed>`.  No automatic
//! shrinking — generators should be written size-parameterized so a failing
//! seed is already small (the `sized` combinator draws small sizes first).

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        let base_seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        PropConfig { cases: 64, base_seed }
    }
}

/// Run `prop` on `cases` generated inputs; panic with seed on first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check_with(PropConfig::default(), name, gen, prop)
}

pub fn check_with<T: std::fmt::Debug>(
    cfg: PropConfig,
    name: &str,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64 * 0x9E3779B9);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (case {case}, replay with PROP_SEED={seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Draw sizes small-first: early cases use the low end of [lo, hi].
pub fn sized(rng: &mut Rng, case_frac: f64, lo: usize, hi: usize) -> usize {
    let span = ((hi - lo) as f64 * case_frac.clamp(0.05, 1.0)).ceil() as usize;
    lo + rng.gen_range(span.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("reverse twice is identity", |r| {
            (0..r.gen_range(20)).map(|_| r.next_u64()).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if w == *v { Ok(()) } else { Err("mismatch".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", |r| r.gen_range(10), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_cases() {
        let mut seen = Vec::new();
        check_with(
            PropConfig { cases: 5, base_seed: 77 },
            "record",
            |r| r.next_u64(),
            |v| {
                seen.push(*v);
                Ok(())
            },
        );
        let mut seen2 = Vec::new();
        check_with(
            PropConfig { cases: 5, base_seed: 77 },
            "record2",
            |r| r.next_u64(),
            |v| {
                seen2.push(*v);
                Ok(())
            },
        );
        assert_eq!(seen, seen2);
    }
}
