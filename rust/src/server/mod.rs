//! TCP JSON-lines serving front-end + client.
//!
//! Protocol: one JSON object per line.
//!   generate: {"prompt": "...", "max_tokens": 64, "temperature": 0.0,
//!              "method": "hass", "seed": 1}
//!          -> {"id": 1, "text": "...", "tokens": 12, "tau": 4.2,
//!              "latency_ms": 180.0, "queue_ms": 2.0, "worker": 0}
//!   stats:    {"stats": true}
//!          -> {"stats": {"workers": [{"worker": 0, "jobs_ok": 3, ...}],
//!              "aggregate": {"jobs": 3, "tokens": 120, "tau": 3.1, ...}}}
//!   error:    {"id": 1, "error": "..."}  ("id" omitted when the line
//!             could not be parsed; messages are JSON-escaped)
//!
//! Connections are pipelined over the worker pool: each generate request
//! is submitted to the scheduler as soon as its line is read, and a
//! single per-connection pump thread writes each response line when its
//! job finishes (`Scheduler::submit_to` routes every job's result onto
//! one channel).  Responses carry "id" so clients can pair them; with
//! N>1 engine workers they may arrive out of order relative to the
//! requests on the same connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::scheduler::{Job, JobResult, PoolStats, Scheduler};
use crate::util::json::{self, Json};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A parsed JSON-lines request.
pub enum Request {
    Gen(Job),
    Stats,
}

pub fn parse_request(line: &str) -> Result<Request> {
    let j = json::parse(line)?;
    if j.get("stats").and_then(|v| v.as_bool()).unwrap_or(false) {
        return Ok(Request::Stats);
    }
    Ok(Request::Gen(Job {
        id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
        method: j.str_at("method").unwrap_or("hass").to_string(),
        prompt: j.str_at("prompt").context("missing 'prompt'")?.to_string(),
        max_new: j.usize_at("max_tokens").unwrap_or(64),
        temperature: j.f64_at("temperature").unwrap_or(0.0) as f32,
        seed: j.usize_at("seed").unwrap_or(0) as u64,
    }))
}

/// Seconds -> milliseconds rounded to 2 decimals (wire format).
fn wire_ms(s: f64) -> f64 {
    (s * 100_000.0).round() / 100.0
}

/// Round to 3 decimals (wire format for τ).
fn wire_r3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

pub fn format_response(r: &JobResult) -> String {
    match &r.error {
        Some(e) => format_error(Some(r.id), e),
        None => Json::obj(vec![
            ("id", Json::num(r.id as f64)),
            ("text", Json::str(r.text.clone())),
            ("tokens", Json::num(r.tokens as f64)),
            ("tau", Json::num(wire_r3(r.tau))),
            ("latency_ms", Json::num(wire_ms(r.latency_s))),
            ("queue_ms", Json::num(wire_ms(r.queue_s))),
            ("worker", Json::num(r.worker as f64)),
        ])
        .to_string(),
    }
}

/// Escape-safe error line.  Built through the JSON writer so messages
/// containing quotes/backslashes stay valid JSON (the old `format!`
/// interpolation emitted them raw).
pub fn format_error(id: Option<u64>, msg: &str) -> String {
    let mut kv: Vec<(&str, Json)> = Vec::new();
    if let Some(id) = id {
        kv.push(("id", Json::num(id as f64)));
    }
    kv.push(("error", Json::str(msg)));
    Json::obj(kv).to_string()
}

/// Render a pool snapshot as the `{"stats": ...}` response line.
pub fn format_pool_stats(p: &PoolStats) -> String {
    let workers: Vec<Json> = p
        .workers
        .iter()
        .map(|w| {
            Json::obj(vec![
                ("worker", Json::num(w.worker as f64)),
                ("jobs_ok", Json::num(w.jobs_ok as f64)),
                ("jobs_err", Json::num(w.jobs_err as f64)),
                ("tokens", Json::num(w.tokens as f64)),
                ("busy_ms", Json::num(wire_ms(w.busy_s))),
                ("idle_ms", Json::num(wire_ms(w.idle_s))),
                ("tau", Json::num(wire_r3(w.metrics.tau()))),
            ])
        })
        .collect();
    let aggregate = Json::obj(vec![
        ("workers", Json::num(p.workers.len() as f64)),
        ("jobs", Json::num(p.jobs() as f64)),
        ("jobs_ok", Json::num(p.jobs_ok() as f64)),
        ("jobs_err", Json::num(p.jobs_err() as f64)),
        ("tokens", Json::num(p.tokens() as f64)),
        ("queue_depth", Json::num(p.queue_depth as f64)),
        ("busy_ms", Json::num(wire_ms(p.busy_s()))),
        ("tau", Json::num(wire_r3(p.tau()))),
    ]);
    Json::obj(vec![(
        "stats",
        Json::obj(vec![("workers", Json::Arr(workers)), ("aggregate", aggregate)]),
    )])
    .to_string()
}

/// Blocking accept loop; each connection gets a reader thread that submits
/// to the shared scheduler pool.
pub fn serve(listener: TcpListener, scheduler: Arc<Scheduler>) -> Result<()> {
    eprintln!(
        "[server] listening on {} ({} engine workers)",
        listener.local_addr()?,
        scheduler.workers()
    );
    for stream in listener.incoming() {
        let stream = stream?;
        let sched = scheduler.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &sched) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn write_line(writer: &Arc<Mutex<TcpStream>>, line: &str) -> std::io::Result<()> {
    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")
}

fn handle_conn(stream: TcpStream, sched: &Arc<Scheduler>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    // One pump thread per connection drains every job result.  The
    // channel is unbounded on purpose: engine workers must never block
    // handing a result to a slow client (that would stall the shared
    // pool for every other connection) — a client that never reads only
    // grows its own connection's buffer.
    let (rtx, rrx) = channel::<JobResult>();
    let pump = {
        let w = writer.clone();
        std::thread::spawn(move || {
            for r in rrx {
                if write_line(&w, &format_response(&r)).is_err() {
                    return; // client gone; drain-by-drop
                }
            }
        })
    };
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(Request::Stats) => write_line(&writer, &format_pool_stats(&sched.stats()))?,
            Ok(Request::Gen(job)) => {
                let id = job.id;
                if let Err(e) = sched.submit_to(job, true, rtx.clone()) {
                    write_line(&writer, &format_error(Some(id), &format!("{e:#}")))?;
                }
            }
            Err(e) => write_line(&writer, &format_error(None, &format!("bad request: {e:#}")))?,
        }
    }
    // closing our sender ends the pump once all in-flight jobs have
    // reported (workers hold the remaining clones)
    drop(rtx);
    let _ = pump.join();
    eprintln!("[server] {peer} disconnected");
    Ok(())
}

/// Simple blocking client for examples/load generators.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    pub fn request(
        &mut self,
        method: &str,
        prompt: &str,
        max_tokens: usize,
        temperature: f32,
    ) -> Result<Json> {
        let req = Json::obj(vec![
            ("method", Json::str(method)),
            ("prompt", Json::str(prompt)),
            ("max_tokens", Json::num(max_tokens as f64)),
            ("temperature", Json::num(temperature as f64)),
        ])
        .to_string();
        self.roundtrip(&req)
    }

    /// Fetch the pool's `{"stats": ...}` snapshot.
    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"stats":true}"#)
    }

    fn roundtrip(&mut self, line: &str) -> Result<Json> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut resp = String::new();
        reader.read_line(&mut resp)?;
        Ok(json::parse(resp.trim())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::metrics::Metrics;
    use crate::scheduler::WorkerStats;

    fn gen(line: &str) -> Job {
        match parse_request(line).unwrap() {
            Request::Gen(j) => j,
            Request::Stats => panic!("expected a generate request"),
        }
    }

    #[test]
    fn parse_request_fields() {
        let j = gen(r#"{"prompt": "hi", "max_tokens": 10, "temperature": 1.0, "method": "eagle2"}"#);
        assert_eq!(j.prompt, "hi");
        assert_eq!(j.max_new, 10);
        assert_eq!(j.method, "eagle2");
        assert!((j.temperature - 1.0).abs() < 1e-6);
    }

    #[test]
    fn parse_request_defaults() {
        let j = gen(r#"{"prompt": "x"}"#);
        assert_eq!(j.max_new, 64);
        assert_eq!(j.method, "hass");
        assert_eq!(j.temperature, 0.0);
    }

    #[test]
    fn missing_prompt_is_error() {
        assert!(parse_request(r#"{"max_tokens": 3}"#).is_err());
    }

    #[test]
    fn stats_request_parses() {
        assert!(matches!(parse_request(r#"{"stats": true}"#).unwrap(), Request::Stats));
        // "stats": false is not a stats request (and needs a prompt)
        assert!(parse_request(r#"{"stats": false}"#).is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        let r = JobResult {
            id: 7,
            text: "a\"b".into(),
            tokens: 3,
            tau: 4.25,
            latency_s: 0.5,
            queue_s: 0.001,
            worker: 1,
            error: None,
        };
        let j = json::parse(&format_response(&r)).unwrap();
        assert_eq!(j.usize_at("id"), Some(7));
        assert_eq!(j.str_at("text"), Some("a\"b"));
        assert_eq!(j.f64_at("latency_ms"), Some(500.0));
        assert_eq!(j.usize_at("worker"), Some(1));
    }

    /// Satellite regression: error messages containing quotes/backslashes
    /// must still produce valid JSON lines.
    #[test]
    fn quoted_error_message_is_valid_json() {
        let msg = r#"bad "quoted" thing with a \ backslash"#;
        let j = json::parse(&format_error(Some(3), msg)).unwrap();
        assert_eq!(j.usize_at("id"), Some(3));
        assert_eq!(j.str_at("error"), Some(msg));
        // parse-failure path (no id) stays valid too
        let j = json::parse(&format_error(None, "a \"b\" c")).unwrap();
        assert!(j.get("id").is_none());
        assert_eq!(j.str_at("error"), Some("a \"b\" c"));
        // and through a JobResult carrying a quoted error
        let r = JobResult {
            id: 9,
            text: String::new(),
            tokens: 0,
            tau: 0.0,
            latency_s: 0.0,
            queue_s: 0.0,
            worker: 0,
            error: Some("engine said \"no\"".into()),
        };
        let j = json::parse(&format_response(&r)).unwrap();
        assert_eq!(j.str_at("error"), Some("engine said \"no\""));
    }

    #[test]
    fn pool_stats_roundtrip() {
        let mut m = Metrics::default();
        m.record_cycle(2, 3);
        let p = PoolStats {
            workers: vec![
                WorkerStats {
                    worker: 0,
                    jobs_ok: 3,
                    jobs_err: 1,
                    tokens: 30,
                    busy_s: 0.5,
                    idle_s: 0.1,
                    metrics: m.clone(),
                },
                WorkerStats {
                    worker: 1,
                    jobs_ok: 2,
                    jobs_err: 0,
                    tokens: 20,
                    busy_s: 0.25,
                    idle_s: 0.2,
                    metrics: m,
                },
            ],
            queue_depth: 4,
        };
        let j = json::parse(&format_pool_stats(&p)).unwrap();
        let stats = j.get("stats").unwrap();
        let agg = stats.get("aggregate").unwrap();
        assert_eq!(agg.usize_at("jobs"), Some(6));
        assert_eq!(agg.usize_at("jobs_ok"), Some(5));
        assert_eq!(agg.usize_at("tokens"), Some(50));
        assert_eq!(agg.usize_at("queue_depth"), Some(4));
        assert_eq!(agg.f64_at("tau"), Some(3.0));
        let workers = stats.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].usize_at("jobs_ok"), Some(3));
        assert_eq!(workers[1].usize_at("worker"), Some(1));
    }
}
