//! TCP JSON-lines serving front-end + client.
//!
//! Protocol: one JSON object per line.
//!   generate: {"prompt": "...", "max_tokens": 64, "temperature": 0.0,
//!              "method": "hass", "seed": 1, "stream": false,
//!              "deadline_ms": 2000, "priority": 0}
//!          -> {"id": 1, "text": "...", "tokens": 12, "tau": 4.2,
//!              "latency_ms": 180.0, "queue_ms": 2.0, "worker": 0}
//!   streaming ("stream": true): one line per drafting-verification cycle
//!          -> {"id": 1, "delta": "...", "tokens": 3, "done": false}
//!             ... then the normal final object with "done": true
//!   cancel:   {"cancel": 1}   fire-and-forget — no ack line; the
//!             cancelled job reports {"id": 1, "error": "cancelled", ...}
//!             through its own response (queued or mid-generation).
//!             Only ids submitted on the same connection are honored;
//!             foreign/unknown ids are silently ignored.
//!   stats:    {"stats": true}
//!          -> {"stats": {"workers": [{"worker": 0, "jobs_ok": 3,
//!              "fused_calls": 9, "solo_calls": 2, "mean_fused_rows": 17.5,
//!              "draft_fused_calls": 30, "draft_solo_calls": 4,
//!              "mean_draft_fused_rows": 6.5,
//!              "pack_pages_copied": 12, "pack_pages_reused": 87,
//!              "draft_pack_pages_copied": 9, "draft_pack_pages_reused": 60,
//!              "shared_pages": 3, "affinity_hits": 5,
//!              "affinity_misses": 2, "cross_worker_shared_pages": 4, ...}],
//!              "aggregate": {"jobs": 3, "tokens": 120, "tau": 3.1,
//!              "registry_entries": 12, "registry_evictions": 0, ...}}}
//!             (fused_calls/solo_calls/fused_rows are the worker's verify
//!             batch occupancy: how many verify executions covered >= 2
//!             sessions, and how many candidate rows those carried;
//!             draft_fused_calls/draft_solo_calls/draft_fused_rows are
//!             the same ledger for DRAFT executions — fused level-
//!             synchronous expansion vs levels driven solo inside plan;
//!             pack_pages_copied/pack_pages_reused (and their draft_
//!             twins) are the paged-KV pack traffic — steady-state cycles
//!             copy only changed tail pages — and shared_pages gauges
//!             cross-session prompt-page sharing in the latest fused
//!             pack; affinity_hits/affinity_misses count prefix-affine
//!             dispatch decisions, cross_worker_shared_pages counts dedup
//!             registry hits against pages first absorbed on a *different*
//!             worker, and registry_entries/registry_evictions gauge the
//!             pool-wide page registry)
//!   error:    {"id": 1, "error": "..."}  ("id" omitted when the line
//!             could not be parsed; messages are JSON-escaped)
//!   overload: {"id": 1, "error": "overloaded", "retry_after_ms": 250}
//!             — admission control or a timed-out spill shed the job at
//!             submit time; clients should back off and retry.  A job
//!             aborted by a circuit breaker reports its error result
//!             with "aborted": "breaker" alongside "error" (see the
//!             scheduler module docs' overload-policy section).
//!   worker_lost: {"id": 1, "error": "worker_lost", "retryable": true}
//!             — the engine worker serving the job died and the job could
//!             not be transparently recovered (redelivery budget spent,
//!             or a streamed replay diverged from the already-emitted
//!             prefix).  Safe to retry: the scheduler checks a job out of
//!             its crash journal only immediately before the final
//!             response is handed over, so a job that reports this line
//!             never also completed.
//!
//! `priority` (0 = default, higher = more important) orders preemption:
//! over the page budget a worker parks its lowest-priority/youngest
//! session first and resumes the highest-priority/oldest first.
//!
//! `deadline_ms` counts from submission; the worker aborts the job with an
//! error result once exceeded (checked between cycles).
//!
//! Connections are pipelined over the worker pool: each generate request
//! is submitted to the scheduler as soon as its line is read, and a
//! single per-connection pump thread writes each response line when its
//! event arrives (`Scheduler::submit_to` routes every job's events onto
//! one channel).  Responses carry "id" so clients can pair them; with
//! N>1 engine workers (or in-worker interleaving) they may arrive out of
//! order relative to the requests on the same connection.
//!
//! # Failure semantics
//!
//! Engine-worker crashes are supervised by the scheduler (see the
//! scheduler module docs' failure-semantics section): recoverable jobs
//! are requeued or replayed transparently, and unrecoverable ones report
//! the retryable `worker_lost` line above instead of leaving the client
//! blocked until its deadline.  [`Client::generate_with_retry`] layers
//! client-side recovery on top: it retries both `worker_lost` failures
//! and `overloaded` rejections with jittered exponential backoff,
//! honoring the server's `retry_after_ms` hint when present.
//!
//! Connection I/O carries the `server.conn_read` / `server.conn_write`
//! failpoints (`HASS_FAULTS` — see `util::failpoint`): an injected read
//! error ends the connection exactly like a peer reset, and an injected
//! write error ends a response write the way a closed socket would.
//! Either way in-flight jobs run to completion and their events are
//! discarded (drain-by-drop), identical to a genuine disconnect — the
//! pool is never stalled by a failed or slow connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::scheduler::{is_worker_lost, Job, JobEvent, JobResult, Overloaded, PoolStats, Scheduler};
use crate::util::failpoint;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A parsed JSON-lines request.
pub enum Request {
    Gen(Job),
    Stats,
    Cancel(u64),
}

pub fn parse_request(line: &str) -> Result<Request> {
    parse_request_with(line, &NEXT_ID)
}

/// `next_id` is injected so tests can assert id accounting: the old
/// field-order initializer ran `fetch_add` *before* the prompt check,
/// burning an id on every invalid line.
pub fn parse_request_with(line: &str, next_id: &AtomicU64) -> Result<Request> {
    let j = json::parse(line)?;
    if j.get("stats").and_then(|v| v.as_bool()).unwrap_or(false) {
        return Ok(Request::Stats);
    }
    if let Some(v) = j.get("cancel") {
        let id = v.as_usize().context("'cancel' must be a job id")?;
        return Ok(Request::Cancel(id as u64));
    }
    // validate the line fully BEFORE allocating an id
    let prompt = j.str_at("prompt").context("missing 'prompt'")?.to_string();
    Ok(Request::Gen(Job {
        id: next_id.fetch_add(1, Ordering::Relaxed),
        method: j.str_at("method").unwrap_or("hass").to_string(),
        prompt,
        max_new: j.usize_at("max_tokens").unwrap_or(64),
        temperature: j.f64_at("temperature").unwrap_or(0.0) as f32,
        seed: j.usize_at("seed").unwrap_or(0) as u64,
        stream: j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false),
        deadline_ms: j.usize_at("deadline_ms").map(|v| v as u64),
        priority: j.usize_at("priority").unwrap_or(0).min(u8::MAX as usize) as u8,
    }))
}

/// Seconds -> milliseconds rounded to 2 decimals (wire format).
fn wire_ms(s: f64) -> f64 {
    (s * 100_000.0).round() / 100.0
}

/// Round to 3 decimals (wire format for τ).
fn wire_r3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

fn error_json(id: Option<u64>, msg: &str) -> Json {
    let mut kv: Vec<(&str, Json)> = Vec::new();
    if let Some(id) = id {
        kv.push(("id", Json::num(id as f64)));
    }
    kv.push(("error", Json::str(msg)));
    Json::obj(kv)
}

fn response_json(r: &JobResult) -> Json {
    match &r.error {
        Some(e) => {
            // a lost-worker failure renders as the explicit retryable
            // shape (module docs) instead of the raw scheduler message
            let mut j = if is_worker_lost(e) {
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    ("error", Json::str("worker_lost")),
                    ("retryable", Json::Bool(true)),
                ])
            } else {
                error_json(Some(r.id), e)
            };
            if let (Json::Obj(kv), Some(a)) = (&mut j, r.aborted) {
                kv.push(("aborted".to_string(), Json::str(a)));
            }
            j
        }
        None => Json::obj(vec![
            ("id", Json::num(r.id as f64)),
            ("text", Json::str(r.text.clone())),
            ("tokens", Json::num(r.tokens as f64)),
            ("tau", Json::num(wire_r3(r.tau))),
            ("latency_ms", Json::num(wire_ms(r.latency_s))),
            ("queue_ms", Json::num(wire_ms(r.queue_s))),
            ("worker", Json::num(r.worker as f64)),
        ]),
    }
}

pub fn format_response(r: &JobResult) -> String {
    response_json(r).to_string()
}

/// Escape-safe error line.  Built through the JSON writer so messages
/// containing quotes/backslashes stay valid JSON (the old `format!`
/// interpolation emitted them raw).
pub fn format_error(id: Option<u64>, msg: &str) -> String {
    error_json(id, msg).to_string()
}

/// Wire line for a submit-time failure.  Admission-control and
/// spill-timeout sheds render as the explicit machine-readable overload
/// shape so clients can back off and retry; every other error keeps the
/// generic line.
pub fn format_submit_error(id: u64, msg: &str) -> String {
    match Overloaded::parse(msg) {
        Some(o) => Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("error", Json::str("overloaded")),
            ("retry_after_ms", Json::num(o.retry_after_ms as f64)),
        ])
        .to_string(),
        None => format_error(Some(id), msg),
    }
}

/// Wire line for one scheduler event.  Streamed jobs get per-cycle delta
/// lines and a final line tagged `"done": true` (success or error); the
/// non-streamed final line keeps the legacy shape.
pub fn format_event(ev: &JobEvent) -> String {
    match ev {
        JobEvent::Delta { id, text, tokens } => Json::obj(vec![
            ("id", Json::num(*id as f64)),
            ("delta", Json::str(text.clone())),
            ("tokens", Json::num(*tokens as f64)),
            ("done", Json::Bool(false)),
        ])
        .to_string(),
        JobEvent::Done(r) => {
            let mut j = response_json(r);
            if r.stream {
                if let Json::Obj(kv) = &mut j {
                    kv.push(("done".to_string(), Json::Bool(true)));
                }
            }
            j.to_string()
        }
    }
}

/// Render a pool snapshot as the `{"stats": ...}` response line.
pub fn format_pool_stats(p: &PoolStats) -> String {
    let workers: Vec<Json> = p
        .workers
        .iter()
        .map(|w| {
            Json::obj(vec![
                ("worker", Json::num(w.worker as f64)),
                ("jobs_ok", Json::num(w.jobs_ok as f64)),
                ("jobs_err", Json::num(w.jobs_err as f64)),
                ("tokens", Json::num(w.tokens as f64)),
                ("busy_ms", Json::num(wire_ms(w.busy_s))),
                ("idle_ms", Json::num(wire_ms(w.idle_s))),
                ("fused_calls", Json::num(w.fused_calls as f64)),
                ("solo_calls", Json::num(w.solo_calls as f64)),
                ("fused_rows", Json::num(w.fused_rows as f64)),
                ("mean_fused_rows", Json::num(wire_r3(w.mean_fused_rows()))),
                ("draft_fused_calls", Json::num(w.draft_fused_calls as f64)),
                ("draft_solo_calls", Json::num(w.draft_solo_calls as f64)),
                ("draft_fused_rows", Json::num(w.draft_fused_rows as f64)),
                ("mean_draft_fused_rows", Json::num(wire_r3(w.mean_draft_fused_rows()))),
                ("pack_pages_copied", Json::num(w.pack_pages_copied as f64)),
                ("pack_pages_reused", Json::num(w.pack_pages_reused as f64)),
                ("draft_pack_pages_copied", Json::num(w.draft_pack_pages_copied as f64)),
                ("draft_pack_pages_reused", Json::num(w.draft_pack_pages_reused as f64)),
                ("shared_pages", Json::num(w.shared_pages as f64)),
                ("affinity_hits", Json::num(w.affinity_hits as f64)),
                ("affinity_misses", Json::num(w.affinity_misses as f64)),
                ("cross_worker_shared_pages", Json::num(w.cross_worker_shared_pages as f64)),
                ("preemptions", Json::num(w.preemptions as f64)),
                ("resumes", Json::num(w.resumes as f64)),
                ("breaker_trips", Json::num(w.breaker_trips as f64)),
                ("requeues", Json::num(w.requeues as f64)),
                ("replays", Json::num(w.replays as f64)),
                ("worker_deaths", Json::num(w.worker_deaths as f64)),
                ("mean_recovery_ms", Json::num(wire_r3(w.mean_recovery_ms()))),
                ("mean_queue_wait_ms", Json::num(wire_r3(w.mean_queue_wait_ms()))),
                ("mean_ttft_ms", Json::num(wire_r3(w.mean_ttft_ms()))),
                ("tau", Json::num(wire_r3(w.metrics.tau()))),
            ])
        })
        .collect();
    let aggregate = Json::obj(vec![
        ("workers", Json::num(p.workers.len() as f64)),
        ("jobs", Json::num(p.jobs() as f64)),
        ("jobs_ok", Json::num(p.jobs_ok() as f64)),
        ("jobs_err", Json::num(p.jobs_err() as f64)),
        ("tokens", Json::num(p.tokens() as f64)),
        ("queue_depth", Json::num(p.queue_depth as f64)),
        ("busy_ms", Json::num(wire_ms(p.busy_s()))),
        ("idle_ms", Json::num(wire_ms(p.idle_s()))),
        ("fused_calls", Json::num(p.fused_calls() as f64)),
        ("solo_calls", Json::num(p.solo_calls() as f64)),
        ("fused_rows", Json::num(p.fused_rows() as f64)),
        ("mean_fused_rows", Json::num(wire_r3(p.mean_fused_rows()))),
        ("draft_fused_calls", Json::num(p.draft_fused_calls() as f64)),
        ("draft_solo_calls", Json::num(p.draft_solo_calls() as f64)),
        ("draft_fused_rows", Json::num(p.draft_fused_rows() as f64)),
        ("mean_draft_fused_rows", Json::num(wire_r3(p.mean_draft_fused_rows()))),
        ("pack_pages_copied", Json::num(p.pack_pages_copied() as f64)),
        ("pack_pages_reused", Json::num(p.pack_pages_reused() as f64)),
        ("draft_pack_pages_copied", Json::num(p.draft_pack_pages_copied() as f64)),
        ("draft_pack_pages_reused", Json::num(p.draft_pack_pages_reused() as f64)),
        ("shared_pages", Json::num(p.shared_pages() as f64)),
        ("affinity_hits", Json::num(p.affinity_hits() as f64)),
        ("affinity_misses", Json::num(p.affinity_misses() as f64)),
        ("cross_worker_shared_pages", Json::num(p.cross_worker_shared_pages() as f64)),
        ("registry_entries", Json::num(p.registry_entries as f64)),
        ("registry_evictions", Json::num(p.registry_evictions as f64)),
        ("admission_rejects", Json::num(p.admission_rejects as f64)),
        ("preemptions", Json::num(p.preemptions() as f64)),
        ("resumes", Json::num(p.resumes() as f64)),
        ("breaker_trips", Json::num(p.breaker_trips() as f64)),
        ("requeues", Json::num(p.requeues() as f64)),
        ("replays", Json::num(p.replays() as f64)),
        ("worker_deaths", Json::num(p.worker_deaths() as f64)),
        ("mean_recovery_ms", Json::num(wire_r3(p.mean_recovery_ms()))),
        ("live_pages", Json::num(p.live_pages as f64)),
        ("page_budget", Json::num(p.page_budget as f64)),
        ("free_pages", Json::num(p.free_pages as f64)),
        ("mean_queue_wait_ms", Json::num(wire_r3(p.mean_queue_wait_ms()))),
        ("mean_ttft_ms", Json::num(wire_r3(p.mean_ttft_ms()))),
        ("tau", Json::num(wire_r3(p.tau()))),
    ]);
    // chaos observability: per-point trigger counters (non-zero only, so
    // fault-free runs emit an empty object and the line stays compact)
    let fired: Vec<(&str, Json)> = failpoint::triggers()
        .into_iter()
        .filter(|&(_, n)| n > 0)
        .map(|(name, n)| (name, Json::num(n as f64)))
        .collect();
    Json::obj(vec![(
        "stats",
        Json::obj(vec![
            ("workers", Json::Arr(workers)),
            ("aggregate", aggregate),
            ("failpoints", Json::obj(fired)),
        ]),
    )])
    .to_string()
}

/// Blocking accept loop; each connection gets a reader thread that submits
/// to the shared scheduler pool.
pub fn serve(listener: TcpListener, scheduler: Arc<Scheduler>) -> Result<()> {
    eprintln!(
        "[server] listening on {} ({} engine workers, {} sessions each)",
        listener.local_addr()?,
        scheduler.workers(),
        scheduler.max_active()
    );
    for stream in listener.incoming() {
        let stream = stream?;
        let sched = scheduler.clone();
        // panic isolation: a handler bug costs one connection, never the
        // accept loop or the process
        std::thread::spawn(move || {
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_conn(stream, &sched)
            }));
            match run {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("[server] connection error: {e:#}"),
                Err(_) => eprintln!("[server] connection handler panicked"),
            }
        });
    }
    Ok(())
}

fn write_line(writer: &Arc<Mutex<TcpStream>>, line: &str) -> std::io::Result<()> {
    // chaos: an injected write error behaves exactly like a closed socket
    // (callers drop the connection; the pool is unaffected)
    if let Err(e) = failpoint::fire(failpoint::CONN_WRITE) {
        return Err(std::io::Error::other(e.to_string()));
    }
    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")
}

fn handle_conn(stream: TcpStream, sched: &Arc<Scheduler>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    // One pump thread per connection drains every job event.  The channel
    // is unbounded on purpose: engine workers must never block handing an
    // event to a slow client (that would stall the shared pool for every
    // other connection) — a client that never reads only grows its own
    // connection's buffer.
    let (rtx, rrx) = channel::<JobEvent>();
    let pump = {
        let w = writer.clone();
        // panic isolation: a formatter/writer bug must not leave the
        // connection with a silently dead pump and no diagnostic
        std::thread::spawn(move || {
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                for ev in rrx {
                    if write_line(&w, &format_event(&ev)).is_err() {
                        return; // client gone; drain-by-drop
                    }
                }
            }));
            if run.is_err() {
                eprintln!("[server] event pump panicked; dropping connection events");
            }
        })
    };
    // ids submitted on THIS connection: a cancel is only forwarded for
    // one of them, so a client can neither kill another connection's job
    // nor plant a marker for a not-yet-allocated id (which would cancel
    // whatever unrelated job eventually received it)
    let mut submitted: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for line in reader.lines() {
        let line = line?;
        // chaos: an injected read error ends the connection like a peer
        // reset; in-flight jobs finish and drain-by-drop as usual
        failpoint::fire(failpoint::CONN_READ)?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(Request::Stats) => write_line(&writer, &format_pool_stats(&sched.stats()))?,
            Ok(Request::Cancel(id)) => {
                // no ack either way (module docs); foreign ids are ignored
                if submitted.contains(&id) {
                    sched.cancel(id);
                }
            }
            Ok(Request::Gen(job)) => {
                let id = job.id;
                submitted.insert(id);
                if let Err(e) = sched.submit_to(job, true, rtx.clone()) {
                    write_line(&writer, &format_submit_error(id, &format!("{e:#}")))?;
                }
            }
            Err(e) => write_line(&writer, &format_error(None, &format!("bad request: {e:#}")))?,
        }
    }
    // closing our sender ends the pump once all in-flight jobs have
    // reported (workers hold the remaining clones)
    drop(rtx);
    let _ = pump.join();
    eprintln!("[server] {peer} disconnected");
    Ok(())
}

/// Options for one [`Client`] generate request.
#[derive(Clone, Debug)]
pub struct ReqOpts {
    pub method: String,
    pub max_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    pub stream: bool,
    pub deadline_ms: Option<u64>,
    /// Overload class (0 = default; higher survives preemption longer).
    pub priority: u8,
}

impl Default for ReqOpts {
    fn default() -> Self {
        ReqOpts {
            method: "hass".into(),
            max_tokens: 64,
            temperature: 0.0,
            seed: 0,
            stream: false,
            deadline_ms: None,
            priority: 0,
        }
    }
}

/// Simple blocking client for examples/load generators.
pub struct Client {
    stream: TcpStream,
    /// One persistent reader for the connection's lifetime.  The old code
    /// built a fresh `BufReader` per call, which buffered bytes past the
    /// first line and dropped them on return — losing pipelined and
    /// streamed responses (satellite regression fix).
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        Ok(())
    }

    fn read_json(&mut self) -> Result<Json> {
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Ok(json::parse(resp.trim())?)
    }

    pub fn request(
        &mut self,
        method: &str,
        prompt: &str,
        max_tokens: usize,
        temperature: f32,
    ) -> Result<Json> {
        let opts = ReqOpts {
            method: method.to_string(),
            max_tokens,
            temperature,
            ..Default::default()
        };
        self.generate(prompt, &opts, |_| {})
    }

    /// Send a generate request; `on_delta` fires once per streamed delta
    /// line (never for `stream: false`); returns the final response line.
    pub fn generate(
        &mut self,
        prompt: &str,
        opts: &ReqOpts,
        mut on_delta: impl FnMut(&str),
    ) -> Result<Json> {
        let mut kv = vec![
            ("method", Json::str(opts.method.clone())),
            ("prompt", Json::str(prompt)),
            ("max_tokens", Json::num(opts.max_tokens as f64)),
            ("temperature", Json::num(opts.temperature as f64)),
            ("seed", Json::num(opts.seed as f64)),
        ];
        if opts.stream {
            kv.push(("stream", Json::Bool(true)));
        }
        if let Some(d) = opts.deadline_ms {
            kv.push(("deadline_ms", Json::num(d as f64)));
        }
        if opts.priority > 0 {
            kv.push(("priority", Json::num(opts.priority as f64)));
        }
        self.send_line(&Json::obj(kv).to_string())?;
        loop {
            let j = self.read_json()?;
            match j.str_at("delta") {
                Some(d) => on_delta(d),
                None => return Ok(j), // final line (success or error)
            }
        }
    }

    /// [`Client::generate`] with client-side recovery: retries up to
    /// `retries` additional attempts when the final line is a retryable
    /// failure — an `overloaded` rejection (honoring the server's
    /// `retry_after_ms` hint) or a `worker_lost` report — sleeping a
    /// jittered exponential backoff between attempts (full jitter in
    /// [base/2, base), base doubling from 25 ms, capped at 2 s; the
    /// server hint raises the base when longer).  Non-retryable errors
    /// and successes return immediately; the last attempt's line is
    /// returned as-is when the budget runs out.
    ///
    /// Each retry resubmits a fresh job, so for `stream: true` requests
    /// `on_delta` may replay text already seen before the failed
    /// attempt's final line — callers that render deltas incrementally
    /// should reset their buffer when a retry starts (non-streamed
    /// requests are unaffected).
    pub fn generate_with_retry(
        &mut self,
        prompt: &str,
        opts: &ReqOpts,
        retries: usize,
        mut on_delta: impl FnMut(&str),
    ) -> Result<Json> {
        // deterministic jitter: seeded from the request seed so load
        // tests replay identical schedules
        let mut rng = Rng::new(opts.seed ^ 0x5EED_BACC_0FF5);
        let mut base_ms: u64 = 25;
        for attempt in 0..=retries {
            let j = self.generate(prompt, opts, &mut on_delta)?;
            let err = match j.str_at("error") {
                None => return Ok(j),
                Some(e) => e.to_string(),
            };
            let retryable = err == "overloaded" || is_worker_lost(&err);
            if !retryable || attempt == retries {
                return Ok(j);
            }
            let hint = j.usize_at("retry_after_ms").unwrap_or(0) as u64;
            let base = base_ms.max(hint).max(2);
            let wait = base / 2 + rng.next_u64() % (base / 2);
            std::thread::sleep(std::time::Duration::from_millis(wait));
            base_ms = (base_ms * 2).min(2_000);
        }
        unreachable!("the final attempt returns from inside the loop")
    }

    /// Fetch the pool's `{"stats": ...}` snapshot.
    pub fn stats(&mut self) -> Result<Json> {
        self.send_line(r#"{"stats":true}"#)?;
        self.read_json()
    }

    /// Fire-and-forget cancel: the cancelled job answers with its own
    /// error result (no ack line for the cancel itself).
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        self.send_line(&format!("{{\"cancel\":{id}}}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::metrics::Metrics;
    use crate::scheduler::WorkerStats;

    fn gen(line: &str) -> Job {
        match parse_request(line).unwrap() {
            Request::Gen(j) => j,
            _ => panic!("expected a generate request"),
        }
    }

    fn result(id: u64, text: &str, stream: bool, error: Option<&str>) -> JobResult {
        JobResult {
            id,
            text: text.to_string(),
            tokens: text.len(),
            tau: 1.0,
            latency_s: 0.5,
            queue_s: 0.001,
            worker: 1,
            stream,
            error: error.map(str::to_string),
            aborted: None,
        }
    }

    #[test]
    fn parse_request_fields() {
        let j = gen(r#"{"prompt": "hi", "max_tokens": 10, "temperature": 1.0, "method": "eagle2"}"#);
        assert_eq!(j.prompt, "hi");
        assert_eq!(j.max_new, 10);
        assert_eq!(j.method, "eagle2");
        assert!((j.temperature - 1.0).abs() < 1e-6);
        assert!(!j.stream);
        assert_eq!(j.deadline_ms, None);
        assert_eq!(j.priority, 0);
    }

    #[test]
    fn parse_request_priority() {
        assert_eq!(gen(r#"{"prompt": "x", "priority": 2}"#).priority, 2);
        // out-of-range priorities clamp instead of erroring
        assert_eq!(gen(r#"{"prompt": "x", "priority": 9999}"#).priority, 255);
    }

    #[test]
    fn parse_request_defaults() {
        let j = gen(r#"{"prompt": "x"}"#);
        assert_eq!(j.max_new, 64);
        assert_eq!(j.method, "hass");
        assert_eq!(j.temperature, 0.0);
    }

    #[test]
    fn parse_request_stream_and_deadline() {
        let j = gen(r#"{"prompt": "x", "stream": true, "deadline_ms": 1500}"#);
        assert!(j.stream);
        assert_eq!(j.deadline_ms, Some(1500));
        // "stream": false is a plain request
        assert!(!gen(r#"{"prompt": "x", "stream": false}"#).stream);
    }

    #[test]
    fn parse_cancel_request() {
        assert!(matches!(
            parse_request(r#"{"cancel": 17}"#).unwrap(),
            Request::Cancel(17)
        ));
        // non-numeric cancel is a bad request
        assert!(parse_request(r#"{"cancel": "x"}"#).is_err());
    }

    #[test]
    fn missing_prompt_is_error() {
        assert!(parse_request(r#"{"max_tokens": 3}"#).is_err());
    }

    /// Satellite regression: an invalid line must not consume a job id
    /// (the old field-order initializer ran `fetch_add` before the
    /// prompt validation).
    #[test]
    fn invalid_line_does_not_burn_an_id() {
        let next = AtomicU64::new(10);
        assert!(parse_request_with(r#"{"max_tokens": 3}"#, &next).is_err());
        assert!(parse_request_with("not json at all", &next).is_err());
        assert_eq!(next.load(Ordering::Relaxed), 10, "invalid lines must not consume ids");
        let j = match parse_request_with(r#"{"prompt": "x"}"#, &next).unwrap() {
            Request::Gen(j) => j,
            _ => panic!("expected gen"),
        };
        assert_eq!(j.id, 10);
        assert_eq!(next.load(Ordering::Relaxed), 11);
        // stats/cancel lines don't consume ids either
        assert!(matches!(parse_request_with(r#"{"stats": true}"#, &next).unwrap(), Request::Stats));
        assert!(matches!(
            parse_request_with(r#"{"cancel": 3}"#, &next).unwrap(),
            Request::Cancel(3)
        ));
        assert_eq!(next.load(Ordering::Relaxed), 11);
    }

    #[test]
    fn stats_request_parses() {
        assert!(matches!(parse_request(r#"{"stats": true}"#).unwrap(), Request::Stats));
        // "stats": false is not a stats request (and needs a prompt)
        assert!(parse_request(r#"{"stats": false}"#).is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        let r = result(7, "a\"b", false, None);
        let j = json::parse(&format_response(&r)).unwrap();
        assert_eq!(j.usize_at("id"), Some(7));
        assert_eq!(j.str_at("text"), Some("a\"b"));
        assert_eq!(j.f64_at("latency_ms"), Some(500.0));
        assert_eq!(j.usize_at("worker"), Some(1));
    }

    /// Satellite regression: error messages containing quotes/backslashes
    /// must still produce valid JSON lines.
    #[test]
    fn quoted_error_message_is_valid_json() {
        let msg = r#"bad "quoted" thing with a \ backslash"#;
        let j = json::parse(&format_error(Some(3), msg)).unwrap();
        assert_eq!(j.usize_at("id"), Some(3));
        assert_eq!(j.str_at("error"), Some(msg));
        // parse-failure path (no id) stays valid too
        let j = json::parse(&format_error(None, "a \"b\" c")).unwrap();
        assert!(j.get("id").is_none());
        assert_eq!(j.str_at("error"), Some("a \"b\" c"));
        // and through a JobResult carrying a quoted error
        let j = json::parse(&format_response(&result(9, "", false, Some("engine said \"no\""))))
            .unwrap();
        assert_eq!(j.str_at("error"), Some("engine said \"no\""));
    }

    /// Overload satellite: a submit-time `Overloaded` error renders as the
    /// explicit machine-readable shape; other submit errors keep the
    /// generic line.
    #[test]
    fn overload_submit_error_wire_shapes() {
        use crate::scheduler::Overloaded;
        let msg = format!("{:#}", Overloaded { retry_after_ms: 250 }.to_error());
        let j = json::parse(&format_submit_error(6, &msg)).unwrap();
        assert_eq!(j.usize_at("id"), Some(6));
        assert_eq!(j.str_at("error"), Some("overloaded"));
        assert_eq!(j.usize_at("retry_after_ms"), Some(250));
        // non-overload submit errors keep the generic error line
        let j = json::parse(&format_submit_error(7, "scheduler down")).unwrap();
        assert_eq!(j.str_at("error"), Some("scheduler down"));
        assert!(j.get("retry_after_ms").is_none());
    }

    /// Breaker satellite: an aborted result carries the distinct
    /// "aborted" marker next to its error message.
    #[test]
    fn overload_breaker_abort_carries_marker() {
        let mut r = result(8, "", false, Some("breaker: session exceeded 4 cycles"));
        r.aborted = Some("breaker");
        let j = json::parse(&format_response(&r)).unwrap();
        assert_eq!(j.str_at("aborted"), Some("breaker"));
        assert_eq!(j.str_at("error"), Some("breaker: session exceeded 4 cycles"));
        // plain errors never grow the marker
        let j = json::parse(&format_response(&result(9, "", false, Some("cancelled")))).unwrap();
        assert!(j.get("aborted").is_none());
    }

    /// Stream wire format: deltas carry done:false, the streamed final
    /// line (success or error) carries done:true, and non-streamed final
    /// lines keep the legacy shape (no "done" key).
    #[test]
    fn stream_wire_format() {
        let ev = JobEvent::Delta { id: 4, text: "ab".into(), tokens: 2 };
        let j = json::parse(&format_event(&ev)).unwrap();
        assert_eq!(j.usize_at("id"), Some(4));
        assert_eq!(j.str_at("delta"), Some("ab"));
        assert_eq!(j.usize_at("tokens"), Some(2));
        assert_eq!(j.get("done").and_then(|v| v.as_bool()), Some(false));

        let j = json::parse(&format_event(&JobEvent::Done(result(4, "abc", true, None)))).unwrap();
        assert_eq!(j.get("done").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(j.str_at("text"), Some("abc"));

        let j = json::parse(&format_event(&JobEvent::Done(result(4, "", true, Some("cancelled")))))
            .unwrap();
        assert_eq!(j.get("done").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(j.str_at("error"), Some("cancelled"));

        let j = json::parse(&format_event(&JobEvent::Done(result(5, "xy", false, None)))).unwrap();
        assert!(j.get("done").is_none(), "legacy final line must not grow a done key");
    }

    /// Satellite regression: the client must keep ONE BufReader for the
    /// connection.  The fake server answers the first request with BOTH
    /// response lines in one write — the old per-call reader buffered the
    /// second line and dropped it, so the second request would hang.
    #[test]
    fn client_pipelined_responses_survive_buffering() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap(); // request 1
            let mut w = stream.try_clone().unwrap();
            w.write_all(b"{\"id\":1,\"text\":\"first\"}\n{\"id\":2,\"text\":\"second\"}\n")
                .unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap(); // request 2 (ignored)
        });
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let r1 = c.request("hass", "p1", 4, 0.0).unwrap();
        assert_eq!(r1.str_at("text"), Some("first"));
        let r2 = c.request("hass", "p2", 4, 0.0).unwrap();
        assert_eq!(r2.str_at("text"), Some("second"), "buffered response lost");
        server.join().unwrap();
    }

    /// Robustness satellite: an unrecoverable lost-worker result renders
    /// as the explicit retryable shape, streamed or not; other errors
    /// keep the raw message and never grow the marker.
    #[test]
    fn worker_lost_wire_shape() {
        use crate::scheduler::WORKER_LOST_MSG;
        let r = result(3, "", false, Some(WORKER_LOST_MSG));
        let j = json::parse(&format_response(&r)).unwrap();
        assert_eq!(j.usize_at("id"), Some(3));
        assert_eq!(j.str_at("error"), Some("worker_lost"));
        assert_eq!(j.get("retryable").and_then(|v| v.as_bool()), Some(true));
        // streamed final line keeps done:true alongside the shape
        let r = result(4, "", true, Some(WORKER_LOST_MSG));
        let j = json::parse(&format_event(&JobEvent::Done(r))).unwrap();
        assert_eq!(j.get("done").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(j.str_at("error"), Some("worker_lost"));
        assert_eq!(j.get("retryable").and_then(|v| v.as_bool()), Some(true));
        // unrelated errors are untouched
        let j = json::parse(&format_response(&result(5, "", false, Some("cancelled")))).unwrap();
        assert_eq!(j.str_at("error"), Some("cancelled"));
        assert!(j.get("retryable").is_none());
    }

    /// Retry satellite: a scripted server rejects with overloaded
    /// (carrying a retry_after_ms hint), then reports worker_lost, then
    /// accepts — generate_with_retry must walk through all three and
    /// return the success.
    #[test]
    fn client_retries_overload_then_worker_lost() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || -> usize {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = stream.try_clone().unwrap();
            let responses = [
                "{\"id\":1,\"error\":\"overloaded\",\"retry_after_ms\":1}\n",
                "{\"id\":2,\"error\":\"worker_lost\",\"retryable\":true}\n",
                "{\"id\":3,\"text\":\"ok\",\"tokens\":2}\n",
            ];
            let mut seen = 0;
            for r in responses {
                let mut line = String::new();
                if reader.read_line(&mut line).unwrap() == 0 {
                    break;
                }
                seen += 1;
                w.write_all(r.as_bytes()).unwrap();
            }
            seen
        });
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let r = c.generate_with_retry("p", &ReqOpts::default(), 3, |_| {}).unwrap();
        assert_eq!(r.str_at("text"), Some("ok"));
        assert_eq!(server.join().unwrap(), 3, "expected exactly three attempts");
    }

    /// Retry satellite: a zero budget returns the retryable line as-is,
    /// and non-retryable errors never burn retries.
    #[test]
    fn client_retry_budget_and_non_retryable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || -> usize {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = stream.try_clone().unwrap();
            let responses = [
                "{\"id\":1,\"error\":\"worker_lost\",\"retryable\":true}\n",
                "{\"id\":2,\"error\":\"cancelled\"}\n",
            ];
            let mut seen = 0;
            for r in responses {
                let mut line = String::new();
                if reader.read_line(&mut line).unwrap() == 0 {
                    break;
                }
                seen += 1;
                w.write_all(r.as_bytes()).unwrap();
            }
            seen
        });
        let mut c = Client::connect(&addr.to_string()).unwrap();
        // retries=0: the worker_lost line comes back untouched
        let r = c.generate_with_retry("p", &ReqOpts::default(), 0, |_| {}).unwrap();
        assert_eq!(r.str_at("error"), Some("worker_lost"));
        // a non-retryable error returns immediately despite budget left
        let r = c.generate_with_retry("p", &ReqOpts::default(), 5, |_| {}).unwrap();
        assert_eq!(r.str_at("error"), Some("cancelled"));
        assert_eq!(server.join().unwrap(), 2);
    }

    #[test]
    fn pool_stats_roundtrip() {
        let mut m = Metrics::default();
        m.record_cycle(2, 3);
        let p = PoolStats {
            workers: vec![
                WorkerStats {
                    worker: 0,
                    jobs_ok: 3,
                    jobs_err: 1,
                    tokens: 30,
                    busy_s: 0.5,
                    idle_s: 0.1,
                    fused_calls: 4,
                    solo_calls: 2,
                    fused_rows: 70,
                    draft_fused_calls: 10,
                    draft_solo_calls: 3,
                    draft_fused_rows: 40,
                    draft_pack_pages_copied: 6,
                    draft_pack_pages_reused: 30,
                    pack_pages_copied: 12,
                    pack_pages_reused: 88,
                    shared_pages: 3,
                    affinity_hits: 5,
                    affinity_misses: 2,
                    cross_worker_shared_pages: 4,
                    preemptions: 2,
                    resumes: 2,
                    breaker_trips: 1,
                    requeues: 2,
                    replays: 1,
                    worker_deaths: 2,
                    recovery_ms_sum: 50.0,
                    queue_wait_ms_sum: 8.0,
                    ttft_ms_sum: 30.0,
                    ttft_count: 3,
                    metrics: m.clone(),
                },
                WorkerStats {
                    worker: 1,
                    jobs_ok: 2,
                    jobs_err: 0,
                    tokens: 20,
                    busy_s: 0.25,
                    idle_s: 0.2,
                    fused_calls: 1,
                    solo_calls: 3,
                    fused_rows: 10,
                    draft_fused_calls: 0,
                    draft_solo_calls: 5,
                    draft_fused_rows: 0,
                    draft_pack_pages_copied: 0,
                    draft_pack_pages_reused: 0,
                    pack_pages_copied: 4,
                    pack_pages_reused: 2,
                    shared_pages: 0,
                    affinity_hits: 1,
                    affinity_misses: 1,
                    cross_worker_shared_pages: 0,
                    preemptions: 0,
                    resumes: 0,
                    breaker_trips: 0,
                    requeues: 0,
                    replays: 0,
                    worker_deaths: 0,
                    recovery_ms_sum: 0.0,
                    queue_wait_ms_sum: 4.0,
                    ttft_ms_sum: 10.0,
                    ttft_count: 2,
                    metrics: m,
                },
            ],
            queue_depth: 4,
            registry_entries: 12,
            registry_evictions: 1,
            admission_rejects: 3,
            live_pages: 40,
            page_budget: 48,
            free_pages: 8,
        };
        let j = json::parse(&format_pool_stats(&p)).unwrap();
        let stats = j.get("stats").unwrap();
        let agg = stats.get("aggregate").unwrap();
        assert_eq!(agg.usize_at("jobs"), Some(6));
        assert_eq!(agg.usize_at("jobs_ok"), Some(5));
        assert_eq!(agg.usize_at("tokens"), Some(50));
        assert_eq!(agg.usize_at("queue_depth"), Some(4));
        assert_eq!(agg.f64_at("tau"), Some(3.0));
        // batch-occupancy satellite: fused/solo counts + mean rows/fused
        assert_eq!(agg.usize_at("fused_calls"), Some(5));
        assert_eq!(agg.usize_at("solo_calls"), Some(5));
        assert_eq!(agg.usize_at("fused_rows"), Some(80));
        assert_eq!(agg.f64_at("mean_fused_rows"), Some(16.0));
        // paged-KV satellite: pack traffic + shared-page gauge
        assert_eq!(agg.usize_at("pack_pages_copied"), Some(16));
        assert_eq!(agg.usize_at("pack_pages_reused"), Some(90));
        assert_eq!(agg.usize_at("shared_pages"), Some(3));
        // draft-batching satellite: fused/solo draft executions, rows, and
        // draft-page pack traffic
        assert_eq!(agg.usize_at("draft_fused_calls"), Some(10));
        assert_eq!(agg.usize_at("draft_solo_calls"), Some(8));
        assert_eq!(agg.usize_at("draft_fused_rows"), Some(40));
        assert_eq!(agg.f64_at("mean_draft_fused_rows"), Some(4.0));
        assert_eq!(agg.usize_at("draft_pack_pages_copied"), Some(6));
        assert_eq!(agg.usize_at("draft_pack_pages_reused"), Some(30));
        // shared-pool satellite: prefix-affinity routing + pool registry
        assert_eq!(agg.usize_at("affinity_hits"), Some(6));
        assert_eq!(agg.usize_at("affinity_misses"), Some(3));
        assert_eq!(agg.usize_at("cross_worker_shared_pages"), Some(4));
        assert_eq!(agg.usize_at("registry_entries"), Some(12));
        assert_eq!(agg.usize_at("registry_evictions"), Some(1));
        // overload satellite: shed/preempt/breaker counters, page gauges,
        // and the SLO means (queue wait + TTFT) cross-checkable against
        // BENCH_load.json
        assert_eq!(agg.usize_at("admission_rejects"), Some(3));
        assert_eq!(agg.usize_at("preemptions"), Some(2));
        assert_eq!(agg.usize_at("resumes"), Some(2));
        assert_eq!(agg.usize_at("breaker_trips"), Some(1));
        assert_eq!(agg.usize_at("live_pages"), Some(40));
        assert_eq!(agg.usize_at("page_budget"), Some(48));
        assert_eq!(agg.usize_at("free_pages"), Some(8));
        assert_eq!(agg.f64_at("mean_queue_wait_ms"), Some(2.0));
        assert_eq!(agg.f64_at("mean_ttft_ms"), Some(8.0));
        // robustness satellite: supervision/recovery counters
        assert_eq!(agg.usize_at("requeues"), Some(2));
        assert_eq!(agg.usize_at("replays"), Some(1));
        assert_eq!(agg.usize_at("worker_deaths"), Some(2));
        assert_eq!(agg.f64_at("mean_recovery_ms"), Some(25.0));
        // failpoint trigger counters ride along as their own object
        // (empty in a fault-free process, but the key is always present)
        assert!(stats.get("failpoints").is_some());
        let workers = stats.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].usize_at("jobs_ok"), Some(3));
        assert_eq!(workers[0].usize_at("fused_calls"), Some(4));
        assert_eq!(workers[0].f64_at("mean_fused_rows"), Some(17.5));
        assert_eq!(workers[0].usize_at("pack_pages_copied"), Some(12));
        assert_eq!(workers[0].usize_at("pack_pages_reused"), Some(88));
        assert_eq!(workers[0].usize_at("shared_pages"), Some(3));
        assert_eq!(workers[0].usize_at("draft_fused_calls"), Some(10));
        assert_eq!(workers[0].f64_at("mean_draft_fused_rows"), Some(4.0));
        assert_eq!(workers[0].usize_at("draft_pack_pages_copied"), Some(6));
        assert_eq!(workers[0].usize_at("affinity_hits"), Some(5));
        assert_eq!(workers[0].usize_at("cross_worker_shared_pages"), Some(4));
        assert_eq!(workers[0].usize_at("preemptions"), Some(2));
        assert_eq!(workers[0].usize_at("resumes"), Some(2));
        assert_eq!(workers[0].usize_at("breaker_trips"), Some(1));
        assert_eq!(workers[0].usize_at("requeues"), Some(2));
        assert_eq!(workers[0].usize_at("replays"), Some(1));
        assert_eq!(workers[0].usize_at("worker_deaths"), Some(2));
        assert_eq!(workers[0].f64_at("mean_recovery_ms"), Some(25.0));
        assert_eq!(workers[1].usize_at("worker_deaths"), Some(0));
        assert_eq!(workers[1].f64_at("mean_recovery_ms"), Some(0.0));
        assert_eq!(workers[0].f64_at("mean_queue_wait_ms"), Some(2.0));
        assert_eq!(workers[0].f64_at("mean_ttft_ms"), Some(10.0));
        assert_eq!(workers[1].usize_at("worker"), Some(1));
        assert_eq!(workers[1].usize_at("affinity_misses"), Some(1));
        assert_eq!(workers[1].f64_at("mean_ttft_ms"), Some(5.0));
        assert_eq!(workers[1].usize_at("solo_calls"), Some(3));
        assert_eq!(workers[1].usize_at("draft_solo_calls"), Some(5));
        assert_eq!(workers[1].f64_at("mean_draft_fused_rows"), Some(0.0));
    }
}
