//! TCP JSON-lines serving front-end + client.
//!
//! Protocol: one JSON object per line.
//!   request:  {"prompt": "...", "max_tokens": 64, "temperature": 0.0,
//!              "method": "hass", "seed": 1}
//!   response: {"id": 1, "text": "...", "tokens": 12, "tau": 4.2,
//!              "latency_ms": 180.0, "queue_ms": 2.0}
//!   error:    {"id": 1, "error": "..."}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::scheduler::{Job, JobResult, Scheduler};
use crate::util::json::{self, Json};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

pub fn parse_request(line: &str) -> Result<Job> {
    let j = json::parse(line)?;
    Ok(Job {
        id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
        method: j.str_at("method").unwrap_or("hass").to_string(),
        prompt: j.str_at("prompt").context("missing 'prompt'")?.to_string(),
        max_new: j.usize_at("max_tokens").unwrap_or(64),
        temperature: j.f64_at("temperature").unwrap_or(0.0) as f32,
        seed: j.usize_at("seed").unwrap_or(0) as u64,
    })
}

pub fn format_response(r: &JobResult) -> String {
    match &r.error {
        Some(e) => Json::obj(vec![
            ("id", Json::num(r.id as f64)),
            ("error", Json::str(e.clone())),
        ])
        .to_string(),
        None => Json::obj(vec![
            ("id", Json::num(r.id as f64)),
            ("text", Json::str(r.text.clone())),
            ("tokens", Json::num(r.tokens as f64)),
            ("tau", Json::num((r.tau * 1000.0).round() / 1000.0)),
            ("latency_ms", Json::num((r.latency_s * 100_000.0).round() / 100.0)),
            ("queue_ms", Json::num((r.queue_s * 100_000.0).round() / 100.0)),
        ])
        .to_string(),
    }
}

/// Blocking accept loop; each connection gets a reader thread that submits
/// to the shared scheduler.
pub fn serve(listener: TcpListener, scheduler: Arc<Scheduler>) -> Result<()> {
    eprintln!("[server] listening on {}", listener.local_addr()?);
    for stream in listener.incoming() {
        let stream = stream?;
        let sched = scheduler.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &sched) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, sched: &Scheduler) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match parse_request(&line) {
            Ok(job) => match sched.submit(job, true) {
                Ok(rx) => match rx.recv() {
                    Ok(r) => format_response(&r),
                    Err(_) => r#"{"error":"engine dropped"}"#.to_string(),
                },
                Err(e) => format!(r#"{{"error":"{e}"}}"#),
            },
            Err(e) => format!(r#"{{"error":"bad request: {e}"}}"#),
        };
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    eprintln!("[server] {peer} disconnected");
    Ok(())
}

/// Simple blocking client for examples/load generators.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    pub fn request(&mut self, method: &str, prompt: &str, max_tokens: usize, temperature: f32) -> Result<Json> {
        let req = Json::obj(vec![
            ("method", Json::str(method)),
            ("prompt", Json::str(prompt)),
            ("max_tokens", Json::num(max_tokens as f64)),
            ("temperature", Json::num(temperature as f64)),
        ])
        .to_string();
        self.stream.write_all(req.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Ok(json::parse(line.trim())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_fields() {
        let j = parse_request(
            r#"{"prompt": "hi", "max_tokens": 10, "temperature": 1.0, "method": "eagle2"}"#,
        )
        .unwrap();
        assert_eq!(j.prompt, "hi");
        assert_eq!(j.max_new, 10);
        assert_eq!(j.method, "eagle2");
        assert!((j.temperature - 1.0).abs() < 1e-6);
    }

    #[test]
    fn parse_request_defaults() {
        let j = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(j.max_new, 64);
        assert_eq!(j.method, "hass");
        assert_eq!(j.temperature, 0.0);
    }

    #[test]
    fn missing_prompt_is_error() {
        assert!(parse_request(r#"{"max_tokens": 3}"#).is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        let r = JobResult {
            id: 7,
            text: "a\"b".into(),
            tokens: 3,
            tau: 4.25,
            latency_s: 0.5,
            queue_s: 0.001,
            error: None,
        };
        let j = json::parse(&format_response(&r)).unwrap();
        assert_eq!(j.usize_at("id"), Some(7));
        assert_eq!(j.str_at("text"), Some("a\"b"));
        assert_eq!(j.f64_at("latency_ms"), Some(500.0));
    }
}
