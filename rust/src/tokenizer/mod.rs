//! Char-level tokenizer, vocab 128 — the exact mirror of
//! `python/compile/data.py` (ids 0/1/2/3 = PAD/BOS/EOS/UNK; '\t'=9,
//! '\n'=10; printable ASCII 32..=126 map to their own byte value).

pub const VOCAB: usize = 128;
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;

pub fn encode(text: &str, bos: bool) -> Vec<i32> {
    let mut ids = Vec::with_capacity(text.len() + 1);
    if bos {
        ids.push(BOS);
    }
    for ch in text.chars() {
        let o = ch as u32;
        if o == 9 || o == 10 || (32..=126).contains(&o) {
            ids.push(o as i32);
        } else {
            ids.push(UNK);
        }
    }
    ids
}

pub fn decode(ids: &[i32]) -> String {
    let mut out = String::with_capacity(ids.len());
    for &i in ids {
        match i {
            PAD | BOS => continue,
            EOS => break,
            9 | 10 => out.push(i as u8 as char),
            32..=126 => out.push(i as u8 as char),
            _ => out.push('?'),
        }
    }
    out
}

pub fn is_valid(id: i32) -> bool {
    (0..VOCAB as i32).contains(&id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_ascii() {
        let s = "User: hi\nAssistant: 1 + 2 = 3\t(ok)";
        assert_eq!(decode(&encode(s, false)), s);
    }

    #[test]
    fn bos_skipped_eos_stops() {
        let mut ids = encode("ab", true);
        ids.push(EOS);
        ids.extend(encode("zz", false));
        assert_eq!(decode(&ids), "ab");
    }

    #[test]
    fn non_ascii_to_unk() {
        let ids = encode("héllo", false);
        assert!(ids.contains(&UNK));
        assert_eq!(decode(&ids), "h?llo");
    }

    #[test]
    fn prop_roundtrip_printable() {
        prop::check(
            "tokenizer roundtrip on printable ascii",
            |r| {
                (0..r.gen_range(60))
                    .map(|_| (32 + r.gen_range(95)) as u8 as char)
                    .collect::<String>()
            },
            |s| {
                let back = decode(&encode(s, false));
                if back == *s {
                    Ok(())
                } else {
                    Err(format!("{s:?} -> {back:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_all_ids_in_vocab() {
        prop::check(
            "encoded ids within vocab",
            |r| {
                (0..r.gen_range(40))
                    .map(|_| char::from_u32(r.gen_range(300) as u32).unwrap_or('x'))
                    .collect::<String>()
            },
            |s| {
                if encode(s, true).iter().all(|&i| is_valid(i)) {
                    Ok(())
                } else {
                    Err("id out of vocab".into())
                }
            },
        );
    }
}
