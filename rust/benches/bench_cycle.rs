//! Micro-benchmarks of the L3 hot path (perf-pass instrumentation):
//! tree build/rerank/mask-pack, sampling transforms, and — when artifacts
//! are present — the real per-graph call latencies that dominate a
//! drafting-verification cycle.
//!
//! `cargo bench --bench bench_cycle`

use std::rc::Rc;

use hass::bench::bench;
use hass::engine::build_method;
use hass::runtime::Runtime;
use hass::sampling::{process_logits, SampleParams};
use hass::spec::{GenRequest, MethodCfg};
use hass::tokenizer;
use hass::tree::Tree;
use hass::util::rng::Rng;

fn random_tree(rng: &mut Rng, levels: usize, beam: usize) -> Tree {
    let mut t = Tree::new(1);
    let mut frontier = vec![0usize];
    for _ in 0..levels {
        let mut next = Vec::new();
        for &p in frontier.iter().take(beam) {
            for _ in 0..beam {
                let lp = -(rng.next_f32() * 3.0 + 0.01);
                next.push(t.add_child(p, rng.gen_range(128) as i32, lp));
            }
        }
        frontier = next;
    }
    t
}

fn main() {
    println!("== L3 micro-benchmarks ==");
    let mut rng = Rng::new(7);
    let tree = random_tree(&mut rng, 6, 10);
    println!("tree nodes: {}", tree.nodes.len());

    bench("tree: build (6 levels x beam 10)", 3, 50, || {
        let mut r = Rng::new(7);
        let t = random_tree(&mut r, 6, 10);
        std::hint::black_box(t.nodes.len());
    });
    bench("tree: rerank top-60", 3, 200, || {
        std::hint::black_box(tree.rerank(60).len());
    });
    let plan = tree.rerank(60);
    bench("tree: ancestor mask pack (61 rows)", 3, 200, || {
        std::hint::black_box(plan.block_mask().len());
    });

    let logits: Vec<f32> = (0..128).map(|i| ((i * 37) % 97) as f32 / 17.0).collect();
    let p1 = SampleParams { temperature: 1.0, top_p: 0.9, ..Default::default() };
    bench("sampling: process_logits (V=128, top-p)", 10, 2000, || {
        std::hint::black_box(process_logits(&logits, &p1));
    });
    let p0 = SampleParams { temperature: 0.0, ..Default::default() };
    bench("sampling: process_logits greedy", 10, 2000, || {
        std::hint::black_box(process_logits(&logits, &p0));
    });

    // real-graph latencies (skipped without artifacts)
    let dir = hass::artifact_dir();
    if !dir.join("meta.json").exists() || !dir.join("weights/hass.json").exists() {
        println!("(artifacts/weights missing: skipping end-to-end cycle benches)");
        return;
    }
    println!("\n== end-to-end cycle benches (real PJRT graphs) ==");
    let rt = Rc::new(Runtime::new(&dir).expect("runtime"));
    let mut m = build_method(&rt, "hass", &MethodCfg::default()).unwrap();
    let req = GenRequest {
        prompt_tokens: tokenizer::encode(
            "User: Can you tell me about the weather?\nAssistant:", true),
        max_new: 48,
        params: SampleParams { temperature: 0.0, ..Default::default() },
    };
    // warm the compile caches
    let _ = m.generate(&req).unwrap();
    rt.reset_stats();
    let out = m.generate(&req).unwrap();
    println!(
        "hass 48-token request: tau={:.2} cycles={} target_calls={} draft_calls={}",
        out.metrics.tau(), out.metrics.cycles,
        out.metrics.target_calls, out.metrics.draft_calls
    );
    println!("phase split: draft={:.1}ms verify={:.1}ms sample={:.1}ms host={:.1}ms",
        out.metrics.phases.draft_s * 1e3, out.metrics.phases.verify_s * 1e3,
        out.metrics.phases.sample_s * 1e3, out.metrics.phases.host_s * 1e3);
    for (g, s) in rt.call_stats() {
        println!(
            "  {g:<22} calls={:>5} mean={:>8.3}ms total={:>7.3}s",
            s.calls, s.secs / s.calls.max(1) as f64 * 1e3, s.secs
        );
    }
}
