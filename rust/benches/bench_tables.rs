//! End-to-end paper-table benches: regenerates every table/figure the
//! evaluation section reports (fast settings; `hass table N --prompts 16
//! --tokens 64` for the full runs).  One bench per table per DESIGN.md §5.
//!
//! `cargo bench --bench bench_tables`          (tables 1, 2 + figure 5)
//! `cargo bench --bench bench_tables -- all`   (every table, incl. 9)

use std::rc::Rc;

use hass::runtime::Runtime;
use hass::tables::{run_figure, run_table, Harness};
use hass::workload::Workloads;

fn main() -> anyhow::Result<()> {
    let all = std::env::args().any(|a| a == "all");
    let dir = hass::artifact_dir();
    if !dir.join("meta.json").exists() || !dir.join("weights/target.json").exists() {
        println!("bench_tables: artifacts/weights missing — run `make artifacts train` first");
        return Ok(());
    }
    let rt = Rc::new(Runtime::new(&dir)?);
    let wl = Workloads::load(&dir).unwrap_or_else(|_| Workloads::embedded());
    // fast bench settings: 3 prompts x 24 tokens per combo
    let mut h = Harness::new(rt, wl, 3, 24)?;

    let tables: &[&str] = if all {
        &["1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11"]
    } else {
        &["1", "2"]
    };
    for t in tables {
        if let Err(e) = run_table(&mut h, t) {
            println!("table {t}: {e:#}");
        }
    }
    for f in ["1", "5"] {
        if let Err(e) = run_figure(&mut h, f) {
            println!("figure {f}: {e:#}");
        }
    }
    Ok(())
}
